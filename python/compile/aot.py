"""AOT lowering (build-time only — Python is never on the Rust request path).

Lowers every CATALOG entry to **HLO text** and writes `manifest.json`.

HLO *text* (not `lowered.compile()` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids which
the Rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the XLA
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts [--only NAME]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

from jax._src.lib import xla_client as xc

from .model import CATALOG


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sources_fingerprint() -> str:
    """Hash of every python source in compile/ — drives the no-op rebuild."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for fname in sorted(files):
            if fname.endswith(".py"):
                with open(os.path.join(dirpath, fname), "rb") as f:
                    h.update(fname.encode())
                    h.update(f.read())
    return h.hexdigest()


def build(out_dir: str, only: str | None = None, force: bool = False) -> int:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fingerprint = _sources_fingerprint()

    if not force and not only and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fingerprint and all(
                os.path.exists(os.path.join(out_dir, e["file"]))
                for e in old.get("entries", [])
            ):
                print(f"artifacts up to date ({len(old['entries'])} entries)")
                return 0
        except (json.JSONDecodeError, KeyError):
            pass  # corrupt manifest -> rebuild

    entries = []
    t0 = time.time()
    for e in CATALOG:
        if only and e.name != only:
            continue
        t1 = time.time()
        text = to_hlo_text(e.lower())
        fname = f"{e.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": e.name,
                "family": e.family,
                "variant": e.variant,
                "file": fname,
                "ref": e.ref_name,
                "buggy": e.buggy,
                "tol": e.tol,
                "inputs": [s.to_json() for s in e.inputs],
            }
        )
        print(f"  lowered {e.name:32s} {len(text):>9d} chars {time.time()-t1:5.1f}s")

    manifest = {
        "version": 1,
        "fingerprint": fingerprint,
        "entries": entries,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest in {time.time()-t0:.1f}s")
    return 0


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--only", default=None, help="lower a single catalog entry")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()
    return build(args.out, args.only, args.force)


if __name__ == "__main__":
    sys.exit(main())
