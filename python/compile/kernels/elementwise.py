"""Elementwise-chain family (L1): out = relu(a * x + y) * x.

  unfused  three kernels (axpy, relu, mul) — x re-read twice from HBM.
  fused    one kernel, one pass.

Buggy:
  bug_wrong_const  the scale `a` is perturbed by +0.01 inside the kernel
                   (a transcription bug the correctness stage must catch).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, pallas_call


def _axpy_kernel(x_ref, y_ref, a_ref, o_ref):
    o_ref[...] = a_ref[0, 0] * x_ref[...] + y_ref[...]


def _relu_kernel(z_ref, o_ref):
    o_ref[...] = jnp.maximum(z_ref[...], 0.0)


def _mul_kernel(z_ref, x_ref, o_ref):
    o_ref[...] = z_ref[...] * x_ref[...]


def ew_chain_unfused(x, y, a, br=32):
    r, c = x.shape
    assert r % br == 0
    grid = (r // br,)
    row = pl.BlockSpec((br, c), lambda i: (i, 0))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    z = pallas_call(_axpy_kernel, grid=grid, in_specs=[row, row, scal],
                    out_specs=row, out_shape=f32((r, c)))(x, y, a.reshape(1, 1))
    z = pallas_call(_relu_kernel, grid=grid, in_specs=[row], out_specs=row,
                    out_shape=f32((r, c)))(z)
    return pallas_call(_mul_kernel, grid=grid, in_specs=[row, row],
                       out_specs=row, out_shape=f32((r, c)))(z, x)


def _fused_kernel(x_ref, y_ref, a_ref, o_ref, *, da):
    x = x_ref[...]
    o_ref[...] = jnp.maximum((a_ref[0, 0] + da) * x + y_ref[...], 0.0) * x


def _fused_call(x, y, a, br, da):
    r, c = x.shape
    assert r % br == 0
    return pallas_call(
        functools.partial(_fused_kernel, da=da),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=f32((r, c)),
    )(x, y, a.reshape(1, 1))


def ew_chain_fused(x, y, a, br=32):
    return _fused_call(x, y, a, br, 0.0)


def ew_chain_bug_wrong_const(x, y, a, br=32):
    """BUGGY: scale off by +0.01."""
    return _fused_call(x, y, a, br, 0.01)
