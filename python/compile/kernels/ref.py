"""Pure-jnp correctness oracles for every kernel family (L1 reference).

These never touch Pallas; pytest compares each kernel variant against the
matching oracle, and aot.py lowers each oracle to its own `*_ref` HLO artifact
so the Rust runtime can compare real executions at tolerance 1e-4 (the paper's
correctness criterion, §2.2 "Design of Correctness Tests").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import SQRT_2_OVER_PI


def matmul(x, y):
    return jnp.matmul(x, y)


def matmul_bias_relu(x, y, b):
    return jnp.maximum(jnp.matmul(x, y) + b[None, :], 0.0)


def softmax(x):
    return jax.nn.softmax(x, axis=-1)


def cross_entropy(logits, targets):
    """Per-row CE losses (not the mean, so mismatches localize)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tl = jnp.take_along_axis(logits, targets[:, None].astype(jnp.int32), axis=1)[:, 0]
    return lse - tl


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + 0.044715 * x**3)))


def linear_epilogue(x, w, b):
    y = jnp.matmul(x, w) + b[None, :]
    z = y - jnp.mean(y, axis=1, keepdims=True)
    return gelu(z) + x


def reduce_rows(x):
    return jnp.sum(x, axis=1)


def layernorm(x, gamma, beta, eps=1e-5):
    m = jnp.mean(x, axis=1, keepdims=True)
    v = jnp.mean((x - m) ** 2, axis=1, keepdims=True)
    return (x - m) / jnp.sqrt(v + eps) * gamma[None, :] + beta[None, :]


def ew_chain(x, y, a):
    return jnp.maximum(a * x + y, 0.0) * x


def diag_matmul(a, b):
    return b * a[:, None]


def mini_model_loss(x, w1, b1, w2, b2, gamma, beta, targets):
    """Reference for the L2 mini-model: LN -> Linear+GELU -> Linear -> CE."""
    h = layernorm(x, gamma, beta)
    h = gelu(jnp.matmul(h, w1) + b1[None, :])
    logits = jnp.matmul(h, w2) + b2[None, :]
    return cross_entropy(logits, targets)
