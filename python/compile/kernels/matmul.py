"""Tiled Pallas matmul family (L1).

Variants mirror the optimization ladder a CudaForge Coder walks on a GEMM task:

  naive      one grid cell, whole operands resident — the "first correct kernel".
  tiled      (bm, bn, bk) block decomposition; K is the innermost sequential grid
             dimension and the output block is revisited (accumulator-in-VMEM).
  fused_bias_relu
             tiled matmul whose final K step applies the bias + ReLU epilogue in
             registers — the paper's canonical "operator fusion" suggestion.

Buggy variants (exercise the correction loop with REAL wrong numerics):

  bug_oob    drops the last K tile — the classic boundary off-by-one.
  bug_uninit accumulator "starts from garbage" (modelled as a nonzero init),
             the uninitialized-accumulator bug class from the paper's Fig. 8.

TPU estimate (DESIGN.md §8): 128x128 f32 tiles -> 3*64KiB VMEM per step,
MXU-aligned; expected >=70% MXU utilization at M=N=K>=1024.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, pallas_call


def _naive_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)


def matmul_naive(x, y):
    m, _ = x.shape
    _, n = y.shape
    return pallas_call(_naive_kernel, out_shape=f32((m, n)))(x, y)


def _tiled_kernel(x_ref, y_ref, o_ref, *, init):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.full_like(o_ref, init)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)


def _tiled_call(x, y, bm, bn, bk, *, init=0.0, drop_last_k=False):
    m, k = x.shape
    _, n = y.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk - (1 if drop_last_k else 0)
    grid = (m // bm, n // bn, nk)
    return pallas_call(
        functools.partial(_tiled_kernel, init=init),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=f32((m, n)),
    )(x, y)


def matmul_tiled(x, y, bm=64, bn=64, bk=64):
    return _tiled_call(x, y, bm, bn, bk)


def matmul_tiled_bug_oob(x, y, bm=64, bn=64, bk=64):
    """BUGGY: K loop stops one tile early (out-of-bounds guard overcorrected)."""
    return _tiled_call(x, y, bm, bn, bk, drop_last_k=True)


def matmul_tiled_bug_uninit(x, y, bm=64, bn=64, bk=64):
    """BUGGY: accumulator not zero-initialized (garbage modelled as 0.05)."""
    return _tiled_call(x, y, bm, bn, bk, init=0.05)


def _fused_bias_relu_kernel(x_ref, y_ref, b_ref, o_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = jnp.maximum(o_ref[...] + b_ref[...], 0.0)


def matmul_fused_bias_relu(x, y, b, bm=64, bn=64, bk=64):
    """Tiled matmul with a fused bias+ReLU epilogue applied on the last K step."""
    m, k = x.shape
    _, n = y.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    return pallas_call(
        functools.partial(_fused_bias_relu_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=f32((m, n)),
    )(x, y, b.reshape(1, -1))
