"""Row-reduction family (L1): out[r] = sum_c x[r, c].

  twopass  kernel 1 writes per-column-tile partial sums to an HBM intermediate;
           kernel 2 folds the partials — the CUDA "grid-wide tree reduction
           through global memory" shape.
  onepass  single kernel per row-block; the column walk is a sequential grid
           dimension revisiting the output block (accumulator stays in VMEM).

Buggy:
  bug_off_by_one  the column walk stops one tile early.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, pallas_call


def _partial_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], axis=1, keepdims=True)


def _fold_kernel(p_ref, o_ref):
    o_ref[...] = jnp.sum(p_ref[...], axis=1, keepdims=True)


def reduce_rows_twopass(x, br=32, bc=64):
    r, c = x.shape
    assert r % br == 0 and c % bc == 0
    nc = c // bc
    partials = pallas_call(
        _partial_kernel,
        grid=(r // br, nc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, j)),
        out_shape=f32((r, nc)),
    )(x)
    out = pallas_call(
        _fold_kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, nc), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=f32((r, 1)),
    )(partials)
    return out[:, 0]


def _onepass_kernel(x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], axis=1, keepdims=True)


def _onepass_call(x, br, bc, *, drop_last=False):
    r, c = x.shape
    assert r % br == 0 and c % bc == 0
    nc = c // bc - (1 if drop_last else 0)
    out = pallas_call(
        _onepass_kernel,
        grid=(r // br, nc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        out_shape=f32((r, 1)),
    )(x)
    return out[:, 0]


def reduce_rows_onepass(x, br=32, bc=64):
    return _onepass_call(x, br, bc)


def reduce_rows_bug_off_by_one(x, br=32, bc=64):
    """BUGGY: last column tile never accumulated."""
    return _onepass_call(x, br, bc, drop_last=True)
