"""Pallas kernel library (L1): one module per op family, `ref` is the oracle."""

from . import (  # noqa: F401
    common,
    cross_entropy,
    diag_matmul,
    elementwise,
    fused_epilogue,
    layernorm,
    matmul,
    reduction,
    ref,
    softmax,
)
