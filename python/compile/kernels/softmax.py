"""Row-softmax family (L1).

  naive    three separate Pallas kernels (max / exp-sum / normalize): each pass
           re-reads the logits from HBM — the memory-bound "first version".
  fused    one kernel per row-block: max, exp, sum, divide in a single pass.
  online   single kernel, column-chunked online softmax (running max + rescaled
           running sum) — the "algorithmic change" move from the Coder prompt.

Buggy:
  bug_wrong_axis   reduces over rows instead of columns (classic indexing bug).

TPU estimate: single-pass variants are DRAM-bound; expected >=80% of HBM
roofline for C >= 1024 (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, pallas_call


def _rowmax_kernel(x_ref, o_ref):
    o_ref[...] = jnp.max(x_ref[...], axis=1, keepdims=True)


def _expsum_kernel(x_ref, m_ref, e_ref, s_ref):
    e = jnp.exp(x_ref[...] - m_ref[...])
    e_ref[...] = e
    s_ref[...] = jnp.sum(e, axis=1, keepdims=True)


def _normalize_kernel(e_ref, s_ref, o_ref):
    o_ref[...] = e_ref[...] / s_ref[...]


def softmax_naive(x, br=32):
    """Three kernels, three full passes over the logits."""
    r, c = x.shape
    assert r % br == 0
    grid = (r // br,)
    row_spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    one_spec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    m = pallas_call(
        _rowmax_kernel, grid=grid, in_specs=[row_spec], out_specs=one_spec,
        out_shape=f32((r, 1)),
    )(x)
    e, s = pallas_call(
        _expsum_kernel, grid=grid, in_specs=[row_spec, one_spec],
        out_specs=[row_spec, one_spec], out_shape=[f32((r, c)), f32((r, 1))],
    )(x, m)
    return pallas_call(
        _normalize_kernel, grid=grid, in_specs=[row_spec, one_spec],
        out_specs=row_spec, out_shape=f32((r, c)),
    )(e, s)


def _fused_kernel(x_ref, o_ref, *, axis):
    x = x_ref[...]
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=axis, keepdims=True)


def softmax_fused(x, br=32):
    r, c = x.shape
    assert r % br == 0
    return pallas_call(
        functools.partial(_fused_kernel, axis=1),
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=f32((r, c)),
    )(x)


def softmax_fused_bug_wrong_axis(x, br=32):
    """BUGGY: the reductions run over the row (block) axis, not the lanes."""
    r, c = x.shape
    assert r % br == 0
    return pallas_call(
        functools.partial(_fused_kernel, axis=0),
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=f32((r, c)),
    )(x)


def _online_kernel(x_ref, o_ref, *, c, bc):
    nchunk = c // bc
    x = x_ref[...]

    def body(i, carry):
        m, s = carry
        chunk = jax.lax.dynamic_slice_in_dim(x, i * bc, bc, axis=1)
        m_new = jnp.maximum(m, jnp.max(chunk, axis=1, keepdims=True))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(chunk - m_new), axis=1, keepdims=True
        )
        return m_new, s

    init = (
        jnp.full((x.shape[0], 1), -jnp.inf, jnp.float32),
        jnp.zeros((x.shape[0], 1), jnp.float32),
    )
    m, s = jax.lax.fori_loop(0, nchunk, body, init)
    o_ref[...] = jnp.exp(x - m) / s


def softmax_online(x, br=32, bc=64):
    """Single-pass online softmax over column chunks (running max + sum)."""
    r, c = x.shape
    assert r % br == 0 and c % bc == 0
    return pallas_call(
        functools.partial(_online_kernel, c=c, bc=bc),
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=f32((r, c)),
    )(x)
