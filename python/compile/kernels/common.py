"""Shared helpers for the Pallas kernel library (L1).

Every kernel in this package is lowered with ``interpret=True``: the CPU PJRT
plugin that the Rust runtime embeds cannot execute Mosaic custom-calls, so the
interpret path is the correctness substrate while TPU performance is estimated
structurally (DESIGN.md §8).

Hardware-adaptation convention (DESIGN.md §Hardware-Adaptation):
  CUDA shared-memory staging  -> VMEM tiles expressed via BlockSpec
  threadblock tiling          -> grid + index_map
  warp-shuffle reductions     -> in-block lane-dimension jnp reductions
  tensor-core WMMA            -> MXU-shaped jnp.dot on (8,128)-aligned tiles
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Single switch so tests can flip it if a future backend supports compiled mode.
INTERPRET = True

SQRT_2_OVER_PI = 0.7978845608028654


def pallas_call(kernel, **kwargs):
    """`pl.pallas_call` with the repo-wide interpret default applied."""
    kwargs.setdefault("interpret", INTERPRET)
    return pl.pallas_call(kernel, **kwargs)


def gelu_tanh(x, *, c=SQRT_2_OVER_PI):
    """Tanh-approximated GELU (the approximation KernelBench tasks use)."""
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def row_one_hot(targets, num_classes):
    """One-hot via broadcasted iota (2D iota keeps the TPU lowering legal)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (targets.shape[0], num_classes), 1)
    return (iota == targets[:, None]).astype(jnp.float32)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)
