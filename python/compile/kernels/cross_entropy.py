"""Cross-entropy family (L1) — the paper's Fig. 8 case-study operator
(KernelBench Level-1 task 95).

  block_reduce  two kernels: (max, exp-sum) pass then a loss pass that re-reads
                the logits from HBM — the "second global read of logits" the
                Judge flags in round 7 of the case study.
  lane_reduce   one fused kernel; reductions stay in the lane dimension (the
                warp-shuffle analogue from round 2) and the logits are read
                exactly once.

Buggy:
  bug_uninit_target  the target logit of row 0 is never written (thread-0
                     uninitialized `target_logit`, the exact round-5 bug of
                     Fig. 8); modelled as reading logit column 0 instead.

Per-row losses are returned (not the mean) so mismatches localize.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, pallas_call, row_one_hot


def _maxsum_kernel(l_ref, m_ref, s_ref):
    l = l_ref[...]
    m = jnp.max(l, axis=1, keepdims=True)
    m_ref[...] = m
    s_ref[...] = jnp.sum(jnp.exp(l - m), axis=1, keepdims=True)


def _loss_kernel(l_ref, t_ref, m_ref, s_ref, o_ref, *, c):
    l = l_ref[...]  # second full read of the logits (the round-7 bottleneck)
    tl = jnp.sum(l * row_one_hot(t_ref[...], c), axis=1, keepdims=True)
    o_ref[...] = jnp.log(s_ref[...]) + m_ref[...] - tl


def cross_entropy_block_reduce(logits, targets, br=32):
    """Two-pass cross entropy: logits are read twice from HBM."""
    b, c = logits.shape
    assert b % br == 0
    grid = (b // br,)
    row_spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    one_spec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    t_spec = pl.BlockSpec((br,), lambda i: (i,))
    m, s = pallas_call(
        _maxsum_kernel, grid=grid, in_specs=[row_spec],
        out_specs=[one_spec, one_spec], out_shape=[f32((b, 1)), f32((b, 1))],
    )(logits)
    out = pallas_call(
        functools.partial(_loss_kernel, c=c),
        grid=grid,
        in_specs=[row_spec, t_spec, one_spec, one_spec],
        out_specs=one_spec,
        out_shape=f32((b, 1)),
    )(logits, targets, m, s)
    return out[:, 0]


def _fused_kernel(l_ref, t_ref, o_ref, *, c, bug_row0):
    l = l_ref[...]
    m = jnp.max(l, axis=1, keepdims=True)
    s = jnp.sum(jnp.exp(l - m), axis=1, keepdims=True)
    oh = row_one_hot(t_ref[...], c)
    if bug_row0:
        # BUGGY: block-row 0 "reads" an uninitialized target logit; the stale
        # value resolves to column 0's logit.
        first = pl.program_id(0) == 0
        row = jax.lax.broadcasted_iota(jnp.int32, oh.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, oh.shape, 1)
        oh = jnp.where(first & (row == 0), (col == 0).astype(oh.dtype), oh)
    tl = jnp.sum(l * oh, axis=1, keepdims=True)
    o_ref[...] = jnp.log(s) + m - tl


def cross_entropy_lane_reduce(logits, targets, br=32):
    """Fused single-pass cross entropy (lane-dimension reductions)."""
    b, c = logits.shape
    assert b % br == 0
    out = pallas_call(
        functools.partial(_fused_kernel, c=c, bug_row0=False),
        grid=(b // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=f32((b, 1)),
    )(logits, targets)
    return out[:, 0]


def cross_entropy_bug_uninit_target(logits, targets, br=32):
    """BUGGY: row 0's target_logit is uninitialized (Fig. 8 round-5 bug)."""
    b, c = logits.shape
    assert b % br == 0
    out = pallas_call(
        functools.partial(_fused_kernel, c=c, bug_row0=True),
        grid=(b // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=f32((b, 1)),
    )(logits, targets)
    return out[:, 0]
