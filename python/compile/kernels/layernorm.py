"""LayerNorm family (L1): out = (x - mean) / sqrt(var + eps) * gamma + beta.

  naive  three kernels (mean, variance, normalize) — x read three times.
  fused  one kernel per row-block, statistics kept in VMEM.

Buggy:
  bug_biased_var  variance divides by C-1 (sample variance) instead of C;
                  wrong by ~1/C on every output, beyond 1e-4 for C=256.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, pallas_call

EPS = 1e-5


def _mean_kernel(x_ref, o_ref):
    o_ref[...] = jnp.mean(x_ref[...], axis=1, keepdims=True)


def _var_kernel(x_ref, m_ref, o_ref):
    d = x_ref[...] - m_ref[...]
    o_ref[...] = jnp.mean(d * d, axis=1, keepdims=True)


def _norm_kernel(x_ref, m_ref, v_ref, g_ref, b_ref, o_ref):
    o_ref[...] = (x_ref[...] - m_ref[...]) / jnp.sqrt(v_ref[...] + EPS) * g_ref[
        ...
    ] + b_ref[...]


def layernorm_naive(x, gamma, beta, br=32):
    r, c = x.shape
    assert r % br == 0
    grid = (r // br,)
    row = pl.BlockSpec((br, c), lambda i: (i, 0))
    one = pl.BlockSpec((br, 1), lambda i: (i, 0))
    par = pl.BlockSpec((1, c), lambda i: (0, 0))
    m = pallas_call(_mean_kernel, grid=grid, in_specs=[row], out_specs=one,
                    out_shape=f32((r, 1)))(x)
    v = pallas_call(_var_kernel, grid=grid, in_specs=[row, one], out_specs=one,
                    out_shape=f32((r, 1)))(x, m)
    return pallas_call(
        _norm_kernel, grid=grid, in_specs=[row, one, one, par, par],
        out_specs=row, out_shape=f32((r, c)),
    )(x, m, v, gamma.reshape(1, -1), beta.reshape(1, -1))


def _fused_kernel(x_ref, g_ref, b_ref, o_ref, *, denom_off):
    x = x_ref[...]
    c = x.shape[1]
    m = jnp.mean(x, axis=1, keepdims=True)
    d = x - m
    v = jnp.sum(d * d, axis=1, keepdims=True) / (c - denom_off)
    o_ref[...] = d / jnp.sqrt(v + EPS) * g_ref[...] + b_ref[...]


def _fused_call(x, gamma, beta, br, denom_off):
    r, c = x.shape
    assert r % br == 0
    return pallas_call(
        functools.partial(_fused_kernel, denom_off=denom_off),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=f32((r, c)),
    )(x, gamma.reshape(1, -1), beta.reshape(1, -1))


def layernorm_fused(x, gamma, beta, br=32):
    return _fused_call(x, gamma, beta, br, 0)


def layernorm_bug_biased_var(x, gamma, beta, br=32):
    """BUGGY: sample variance (C-1 denominator)."""
    return _fused_call(x, gamma, beta, br, 1)
