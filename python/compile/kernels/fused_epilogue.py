"""Linear + epilogue family (L1) — the Appendix-B.1 workload (KernelBench
Level-2 task 51 shape): y = x @ W + b; z = y - rowmean(y); g = GELU(z);
out = g + x  (residual over the original activations).

  unfused  matmul kernel, then three separate elementwise/reduction kernels;
           the original activations `x` are re-read from HBM in the final pass
           (the "second pass reading original_x" bottleneck the 24-metric Judge
           correctly identifies in Appendix B.1).
  fused    single kernel per row-block: the GEMM result, the row-mean, the GELU
           and the residual all stay in VMEM; `x` is read exactly once.

Buggy:
  bug_wrong_gelu  tanh-GELU constant 0.70 instead of 0.7978845608 — compiles,
                  runs, and is numerically wrong beyond 1e-4.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, gelu_tanh, pallas_call
from .matmul import matmul_tiled


def _sub_rowmean_kernel(y_ref, o_ref):
    y = y_ref[...]
    o_ref[...] = y - jnp.mean(y, axis=1, keepdims=True)


def _gelu_kernel(z_ref, o_ref, *, c):
    o_ref[...] = gelu_tanh(z_ref[...], c=c)


def _residual_kernel(g_ref, x_ref, o_ref):
    o_ref[...] = g_ref[...] + x_ref[...]  # re-reads original_x from HBM


def linear_epilogue_unfused(x, w, b, br=32):
    """Four kernels, four HBM round-trips (the Coder's first correct attempt)."""
    m, f = x.shape
    assert m % br == 0 and w.shape == (f, f)
    y = matmul_tiled(x, w, bm=min(64, m), bn=min(64, f), bk=min(64, f)) + b[None, :]
    grid = (m // br,)
    spec = pl.BlockSpec((br, f), lambda i: (i, 0))
    z = pallas_call(_sub_rowmean_kernel, grid=grid, in_specs=[spec],
                    out_specs=spec, out_shape=f32((m, f)))(y)
    g = pallas_call(functools.partial(_gelu_kernel, c=None or 0.7978845608028654),
                    grid=grid, in_specs=[spec], out_specs=spec,
                    out_shape=f32((m, f)))(z)
    return pallas_call(_residual_kernel, grid=grid, in_specs=[spec, spec],
                       out_specs=spec, out_shape=f32((m, f)))(g, x)


def gelu_rows(x, br=32):
    """Standalone elementwise GELU kernel (used by the L2 mini-model)."""
    m, f = x.shape
    assert m % br == 0
    spec = pl.BlockSpec((br, f), lambda i: (i, 0))
    return pallas_call(
        functools.partial(_gelu_kernel, c=0.7978845608028654),
        grid=(m // br,), in_specs=[spec], out_specs=spec, out_shape=f32((m, f)),
    )(x)


def _fused_kernel(x_ref, w_ref, b_ref, o_ref, *, c):
    x = x_ref[...]
    y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32) + b_ref[...]
    z = y - jnp.mean(y, axis=1, keepdims=True)
    o_ref[...] = gelu_tanh(z, c=c) + x  # x stays in VMEM; single HBM read


def _fused_call(x, w, b, br, c):
    m, f = x.shape
    assert m % br == 0 and w.shape == (f, f)
    return pallas_call(
        functools.partial(_fused_kernel, c=c),
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, f), lambda i: (i, 0)),
            pl.BlockSpec((f, f), lambda i: (0, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, f), lambda i: (i, 0)),
        out_shape=f32((m, f)),
    )(x, w, b.reshape(1, -1))


def linear_epilogue_fused(x, w, b, br=32):
    """One kernel, one pass: GEMM + rowmean + GELU + residual in VMEM."""
    return _fused_call(x, w, b, br, 0.7978845608028654)


def linear_epilogue_bug_wrong_gelu(x, w, b, br=32):
    """BUGGY: wrong tanh-GELU constant (0.70)."""
    return _fused_call(x, w, b, br, 0.70)
