"""diag(A) @ B family (L1) — KernelBench Level-1 task 12, the example the paper
uses in Appendix C to expose CUDA-L1's "fake kernels".

  full_diag  materializes diag(A) and runs the tiled matmul — the literal
             PyTorch reference (O(N^2) extra traffic + O(N^3) FLOPs).
  broadcast  out = B * A[:, None] — the real optimization, one pass, no GEMM.

Buggy:
  bug_transposed  broadcasts A along the wrong axis (A[None, :]); numerically
                  wrong for any non-symmetric input even on square shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import f32, pallas_call
from .matmul import matmul_tiled


def _diag_kernel(a_ref, o_ref, *, bn):
    i = pl.program_id(0)
    j = pl.program_id(1)
    a = a_ref[...]  # (bn,)
    row = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
    block = jnp.where((row == col) & (i == j), a[:, None] * jnp.ones((1, bn)), 0.0)
    o_ref[...] = block


def diag_matmul_full(a, b, bn=64):
    """Materialize diag(a) (tile by tile), then tiled GEMM."""
    n = a.shape[0]
    assert n % bn == 0 and b.shape[0] == n
    d = pallas_call(
        functools.partial(_diag_kernel, bn=bn),
        grid=(n // bn, n // bn),
        in_specs=[pl.BlockSpec((bn,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
        out_shape=f32((n, n)),
    )(a)
    m = b.shape[1]
    return matmul_tiled(d, b, bm=min(64, n), bn=min(64, m), bk=min(64, n))


def _broadcast_kernel(a_ref, b_ref, o_ref, *, axis):
    a = a_ref[...]
    if axis == 0:
        o_ref[...] = b_ref[...] * a[:, None]
    else:
        o_ref[...] = b_ref[...] * a[None, :]


def _broadcast_call(a, b, br, axis):
    n, m = b.shape
    assert n % br == 0
    # The buggy (axis=1) variant multiplies each row by the whole vector, so
    # it must see all of `a`; the correct variant only needs its row slice.
    a_spec = (
        pl.BlockSpec((br,), lambda i: (i,))
        if axis == 0
        else pl.BlockSpec((n,), lambda i: (0,))
    )
    return pallas_call(
        functools.partial(_broadcast_kernel, axis=axis),
        grid=(n // br,),
        in_specs=[
            a_spec,
            pl.BlockSpec((br, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, m), lambda i: (i, 0)),
        out_shape=f32((n, m)),
    )(a, b)


def diag_matmul_broadcast(a, b, br=32):
    return _broadcast_call(a, b, br, 0)


def diag_matmul_bug_transposed(a, b, br=32):
    """BUGGY: broadcast along columns instead of rows (needs square B)."""
    assert b.shape[0] == b.shape[1], "bug variant defined on square B"
    return _broadcast_call(a, b, br, 1)
