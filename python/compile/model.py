"""L2: compute graphs + the AOT catalog.

Every entry in :data:`CATALOG` is one HLO artifact the Rust runtime can load:
a kernel-variant function (calling the L1 Pallas kernels) or its pure-jnp
reference oracle. The Rust correctness stage executes the variant and the
matching ``*_ref`` artifact on identical inputs and compares at tol 1e-4,
exactly like the paper's compile+execute correctness test (§2.2).

The ``mini_model`` entries are the end-to-end L2 graph (LayerNorm -> Linear +
GELU -> Linear -> CrossEntropy), the real-numerics anchor for KernelBench
Level-3-style tasks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import (
    cross_entropy as ce,
    diag_matmul as dm,
    elementwise as ew,
    fused_epilogue as fe,
    layernorm as ln,
    matmul as mm,
    reduction as rd,
    ref,
    softmax as sm,
)
from .kernels.common import f32, i32

# ---------------------------------------------------------------------------
# Input specs. `gen` tells the Rust side how to synthesize inputs; both the
# variant and its ref artifact receive the *same* literals at runtime.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputSpec:
    shape: tuple
    dtype: str = "f32"       # "f32" | "i32"
    gen: str = "uniform"     # "uniform" | "randint"
    lo: float = -2.0
    hi: float = 2.0
    mod: int = 0             # randint modulus (number of classes)

    def sds(self):
        return i32(self.shape) if self.dtype == "i32" else f32(self.shape)

    def to_json(self):
        d = {"shape": list(self.shape), "dtype": self.dtype, "gen": self.gen}
        if self.gen == "uniform":
            d["lo"], d["hi"] = self.lo, self.hi
        else:
            d["mod"] = self.mod
        return d


@dataclasses.dataclass(frozen=True)
class Entry:
    name: str                # artifact file stem
    family: str              # op family (matches the Rust OpClass binding)
    variant: str             # "naive" | "tiled" | ... | "ref"
    fn: Callable
    inputs: Sequence[InputSpec]
    ref_name: str            # artifact to compare against ("" for refs)
    buggy: bool = False
    tol: float = 1e-4        # the paper's correctness tolerance

    def lower(self):
        """jax.jit(fn).lower over ShapeDtypeStructs (AOT, no concrete data)."""
        args = [s.sds() for s in self.inputs]
        wrapped = lambda *a: (self.fn(*a),)  # noqa: E731 — tuple out (see aot)
        return jax.jit(wrapped).lower(*args)


# ---------------------------------------------------------------------------
# Shapes: modest so interpret-mode stays fast; all tile-divisible.
# ---------------------------------------------------------------------------

MM = (128, 128, 128)      # matmul M, K, N
SM = (64, 256)            # softmax rows, cols
CE_SHAPE = (64, 128)      # batch, classes
EP = (64, 128)            # epilogue batch, features
RD = (64, 256)            # reduction rows, cols
LN_SHAPE = (64, 256)      # layernorm rows, cols
EWS = (64, 256)           # elementwise rows, cols
DM = (128, 128)           # diag-matmul N, M (square for the bug variant)
MINI = (32, 128, 256, 64)  # mini-model B, D, H, C


def _mk(shape):
    return InputSpec(shape)


def mini_model_pallas(x, w1, b1, w2, b2, gamma, beta, targets):
    """L2 mini-model forward loss, composed from L1 Pallas kernels."""
    b, d = x.shape
    h = ln.layernorm_fused(x, gamma, beta, br=32)
    a1 = mm.matmul_tiled(h, w1, bm=32, bn=64, bk=64) + b1[None, :]
    a1 = fe.gelu_rows(a1, br=32)
    logits = mm.matmul_tiled(a1, w2, bm=32, bn=64, bk=64) + b2[None, :]
    return ce.cross_entropy_lane_reduce(logits, targets, br=32)


def _catalog():
    entries = []

    def fam(family, ref_fn, ref_inputs, variants):
        """One family: a `<family>_ref` oracle + each (variant, fn, buggy)."""
        ref_name = f"{family}_ref"
        entries.append(
            Entry(ref_name, family, "ref", ref_fn, ref_inputs, "")
        )
        for variant, fn, buggy in variants:
            entries.append(
                Entry(
                    f"{family}_{variant}", family, variant, fn, ref_inputs,
                    ref_name, buggy=buggy,
                )
            )

    m, k, n = MM
    mm_in = [_mk((m, k)), _mk((k, n))]
    fam(
        "matmul", ref.matmul, mm_in,
        [
            ("naive", mm.matmul_naive, False),
            ("tiled", mm.matmul_tiled, False),
            ("bug_oob", mm.matmul_tiled_bug_oob, True),
            ("bug_uninit", mm.matmul_tiled_bug_uninit, True),
        ],
    )

    fam(
        "matmul_bias_relu", ref.matmul_bias_relu,
        [_mk((m, k)), _mk((k, n)), _mk((n,))],
        [("fused", mm.matmul_fused_bias_relu, False)],
    )

    r, c = SM
    fam(
        "softmax", ref.softmax, [_mk((r, c))],
        [
            ("naive", sm.softmax_naive, False),
            ("fused", sm.softmax_fused, False),
            ("online", sm.softmax_online, False),
            ("bug_wrong_axis", sm.softmax_fused_bug_wrong_axis, True),
        ],
    )

    b_, c_ = CE_SHAPE
    ce_in = [_mk((b_, c_)), InputSpec((b_,), "i32", "randint", mod=c_)]
    fam(
        "cross_entropy", ref.cross_entropy, ce_in,
        [
            ("block_reduce", ce.cross_entropy_block_reduce, False),
            ("lane_reduce", ce.cross_entropy_lane_reduce, False),
            ("bug_uninit_target", ce.cross_entropy_bug_uninit_target, True),
        ],
    )

    eb, ef = EP
    ep_in = [_mk((eb, ef)), InputSpec((ef, ef), lo=-0.3, hi=0.3), _mk((ef,))]
    fam(
        "linear_epilogue", ref.linear_epilogue, ep_in,
        [
            ("unfused", fe.linear_epilogue_unfused, False),
            ("fused", fe.linear_epilogue_fused, False),
            ("bug_wrong_gelu", fe.linear_epilogue_bug_wrong_gelu, True),
        ],
    )

    rr, rc = RD
    fam(
        "reduce_rows", ref.reduce_rows, [_mk((rr, rc))],
        [
            ("twopass", rd.reduce_rows_twopass, False),
            ("onepass", rd.reduce_rows_onepass, False),
            ("bug_off_by_one", rd.reduce_rows_bug_off_by_one, True),
        ],
    )

    lr, lc = LN_SHAPE
    ln_in = [_mk((lr, lc)), InputSpec((lc,), lo=0.5, hi=1.5), _mk((lc,))]
    fam(
        "layernorm", ref.layernorm, ln_in,
        [
            ("naive", ln.layernorm_naive, False),
            ("fused", ln.layernorm_fused, False),
            ("bug_biased_var", ln.layernorm_bug_biased_var, True),
        ],
    )

    er, ec = EWS
    ew_in = [_mk((er, ec)), _mk((er, ec)), InputSpec((), lo=0.5, hi=1.5)]
    fam(
        "ew_chain", ref.ew_chain, ew_in,
        [
            ("unfused", ew.ew_chain_unfused, False),
            ("fused", ew.ew_chain_fused, False),
            ("bug_wrong_const", ew.ew_chain_bug_wrong_const, True),
        ],
    )

    dn, dmm = DM
    fam(
        "diag_matmul", ref.diag_matmul, [_mk((dn,)), _mk((dn, dmm))],
        [
            ("full_diag", dm.diag_matmul_full, False),
            ("broadcast", dm.diag_matmul_broadcast, False),
            ("bug_transposed", dm.diag_matmul_bug_transposed, True),
        ],
    )

    mb, md, mh, mc = MINI
    mini_in = [
        _mk((mb, md)),
        InputSpec((md, mh), lo=-0.2, hi=0.2),
        _mk((mh,)),
        InputSpec((mh, mc), lo=-0.2, hi=0.2),
        _mk((mc,)),
        InputSpec((md,), lo=0.5, hi=1.5),
        _mk((md,)),
        InputSpec((mb,), "i32", "randint", mod=mc),
    ]
    fam(
        "mini_model", ref.mini_model_loss, mini_in,
        [("pallas", mini_model_pallas, False)],
    )

    return entries


CATALOG = _catalog()
