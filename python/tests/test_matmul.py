"""L1 matmul family vs the pure-jnp oracle (hypothesis shape sweep)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm, ref

TILES = st.sampled_from([32, 64])
DIMS = st.integers(min_value=1, max_value=3)


def _rand(rng, *shape):
    return jnp.asarray(rng.uniform(-1, 1, shape), jnp.float32)


@settings(max_examples=12, deadline=None)
@given(mi=DIMS, ni=DIMS, ki=DIMS, bt=TILES)
def test_tiled_matches_ref(mi, ni, ki, bt):
    rng = np.random.default_rng(mi * 100 + ni * 10 + ki + bt)
    m, n, k = mi * bt, ni * bt, ki * bt
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    got = mm.matmul_tiled(x, y, bm=bt, bn=bt, bk=bt)
    np.testing.assert_allclose(got, ref.matmul(x, y), atol=1e-4, rtol=1e-4)


@settings(max_examples=6, deadline=None)
@given(mi=DIMS, ni=DIMS)
def test_naive_matches_ref(mi, ni):
    rng = np.random.default_rng(mi * 10 + ni)
    m, n, k = mi * 32, ni * 32, 64
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    got = mm.matmul_naive(x, y)
    np.testing.assert_allclose(got, ref.matmul(x, y), atol=1e-4, rtol=1e-4)


@settings(max_examples=6, deadline=None)
@given(ni=DIMS)
def test_fused_bias_relu(ni):
    rng = np.random.default_rng(ni)
    m, n, k = 64, ni * 64, 128
    x, y, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = mm.matmul_fused_bias_relu(x, y, b)
    np.testing.assert_allclose(
        got, ref.matmul_bias_relu(x, y, b), atol=1e-4, rtol=1e-4
    )
    assert float(jnp.min(got)) >= 0.0  # ReLU postcondition


def test_bug_oob_detected(rng):
    x, y = _rand(rng, 128, 128), _rand(rng, 128, 128)
    got = mm.matmul_tiled_bug_oob(x, y)
    assert not np.allclose(got, ref.matmul(x, y), atol=1e-4, rtol=1e-4)


def test_bug_uninit_detected(rng):
    x, y = _rand(rng, 128, 128), _rand(rng, 128, 128)
    got = mm.matmul_tiled_bug_uninit(x, y)
    assert not np.allclose(got, ref.matmul(x, y), atol=1e-4, rtol=1e-4)
