"""L1 reduction, layernorm, diag-matmul families vs oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import diag_matmul as dm, layernorm as ln, reduction as rd, ref


@settings(max_examples=10, deadline=None)
@given(ri=st.integers(1, 4), ci=st.integers(1, 4))
def test_reduce_onepass(ri, ci):
    rng = np.random.default_rng(ri * 10 + ci)
    x = jnp.asarray(rng.uniform(-2, 2, (ri * 32, ci * 64)), jnp.float32)
    np.testing.assert_allclose(
        rd.reduce_rows_onepass(x), ref.reduce_rows(x), atol=1e-4, rtol=1e-4
    )


@settings(max_examples=6, deadline=None)
@given(ci=st.integers(1, 4))
def test_reduce_twopass(ci):
    rng = np.random.default_rng(ci)
    x = jnp.asarray(rng.uniform(-2, 2, (64, ci * 64)), jnp.float32)
    np.testing.assert_allclose(
        rd.reduce_rows_twopass(x), ref.reduce_rows(x), atol=1e-4, rtol=1e-4
    )


def test_reduce_bug_off_by_one():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.uniform(1, 2, (64, 256)), jnp.float32)  # positive -> bias
    got = rd.reduce_rows_bug_off_by_one(x)
    assert not np.allclose(got, ref.reduce_rows(x), atol=1e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(ri=st.integers(1, 3), c=st.sampled_from([128, 256]))
def test_layernorm_fused(ri, c):
    rng = np.random.default_rng(ri * 100 + c)
    x = jnp.asarray(rng.uniform(-3, 3, (ri * 32, c)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.5, 1.5, (c,)), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, (c,)), jnp.float32)
    np.testing.assert_allclose(
        ln.layernorm_fused(x, g, b), ref.layernorm(x, g, b), atol=1e-4, rtol=1e-3
    )


def test_layernorm_naive_and_bug():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.uniform(-3, 3, (64, 256)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.5, 1.5, (256,)), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, (256,)), jnp.float32)
    np.testing.assert_allclose(
        ln.layernorm_naive(x, g, b), ref.layernorm(x, g, b), atol=1e-4, rtol=1e-3
    )
    bad = ln.layernorm_bug_biased_var(x, g, b)
    assert not np.allclose(bad, ref.layernorm(x, g, b), atol=1e-4, rtol=1e-4)


def test_layernorm_output_stats():
    # gamma=1, beta=0 -> rows ~ zero mean, unit variance.
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.uniform(-3, 3, (32, 256)), jnp.float32)
    out = np.asarray(ln.layernorm_fused(x, jnp.ones(256), jnp.zeros(256)))
    np.testing.assert_allclose(out.mean(axis=1), np.zeros(32), atol=1e-4)
    np.testing.assert_allclose(out.var(axis=1), np.ones(32), atol=1e-2)


@settings(max_examples=8, deadline=None)
@given(ni=st.integers(1, 4), mi=st.integers(1, 4))
def test_diag_broadcast(ni, mi):
    rng = np.random.default_rng(ni * 10 + mi)
    a = jnp.asarray(rng.uniform(-2, 2, (ni * 32,)), jnp.float32)
    b = jnp.asarray(rng.uniform(-2, 2, (ni * 32, mi * 32)), jnp.float32)
    np.testing.assert_allclose(
        dm.diag_matmul_broadcast(a, b), ref.diag_matmul(a, b), atol=1e-4, rtol=1e-4
    )


def test_diag_full_matches_broadcast():
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.uniform(-2, 2, (128,)), jnp.float32)
    b = jnp.asarray(rng.uniform(-2, 2, (128, 128)), jnp.float32)
    np.testing.assert_allclose(
        dm.diag_matmul_full(a, b), ref.diag_matmul(a, b), atol=1e-4, rtol=1e-4
    )


def test_diag_bug_transposed_detected():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.uniform(-2, 2, (128,)), jnp.float32)
    b = jnp.asarray(rng.uniform(-2, 2, (128, 128)), jnp.float32)
    got = dm.diag_matmul_bug_transposed(a, b)
    assert not np.allclose(got, ref.diag_matmul(a, b), atol=1e-4, rtol=1e-4)
