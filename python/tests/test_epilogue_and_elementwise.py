"""L1 linear-epilogue (Appendix B.1 workload) + elementwise chain vs oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise as ew, fused_epilogue as fe, ref


def _ep_inputs(rng, m, f):
    x = jnp.asarray(rng.uniform(-2, 2, (m, f)), jnp.float32)
    w = jnp.asarray(rng.uniform(-0.3, 0.3, (f, f)), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, (f,)), jnp.float32)
    return x, w, b


@settings(max_examples=8, deadline=None)
@given(mi=st.integers(1, 3), f=st.sampled_from([64, 128]))
def test_epilogue_fused_matches_ref(mi, f):
    rng = np.random.default_rng(mi * 10 + f)
    x, w, b = _ep_inputs(rng, mi * 32, f)
    np.testing.assert_allclose(
        fe.linear_epilogue_fused(x, w, b),
        ref.linear_epilogue(x, w, b),
        atol=1e-4, rtol=1e-4,
    )


@settings(max_examples=4, deadline=None)
@given(mi=st.integers(1, 2))
def test_epilogue_unfused_matches_fused(mi):
    rng = np.random.default_rng(mi)
    x, w, b = _ep_inputs(rng, 64, 128)
    np.testing.assert_allclose(
        fe.linear_epilogue_unfused(x, w, b),
        fe.linear_epilogue_fused(x, w, b),
        atol=1e-4, rtol=1e-4,
    )


def test_epilogue_bug_wrong_gelu_detected():
    rng = np.random.default_rng(11)
    x, w, b = _ep_inputs(rng, 64, 128)
    got = fe.linear_epilogue_bug_wrong_gelu(x, w, b)
    assert not np.allclose(got, ref.linear_epilogue(x, w, b), atol=1e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(ri=st.integers(1, 4), c=st.sampled_from([64, 256]))
def test_ew_chain_fused_matches_ref(ri, c):
    rng = np.random.default_rng(ri * 100 + c)
    x = jnp.asarray(rng.uniform(-2, 2, (ri * 32, c)), jnp.float32)
    y = jnp.asarray(rng.uniform(-2, 2, (ri * 32, c)), jnp.float32)
    a = jnp.float32(1.3)
    np.testing.assert_allclose(
        ew.ew_chain_fused(x, y, a), ref.ew_chain(x, y, a), atol=1e-4, rtol=1e-4
    )


def test_ew_chain_unfused_and_bug():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(-2, 2, (64, 256)), jnp.float32)
    y = jnp.asarray(rng.uniform(-2, 2, (64, 256)), jnp.float32)
    a = jnp.float32(0.9)
    np.testing.assert_allclose(
        ew.ew_chain_unfused(x, y, a), ref.ew_chain(x, y, a), atol=1e-4, rtol=1e-4
    )
    bad = ew.ew_chain_bug_wrong_const(x, y, a)
    assert not np.allclose(bad, ref.ew_chain(x, y, a), atol=1e-4, rtol=1e-4)
