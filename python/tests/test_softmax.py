"""L1 softmax family vs oracle, including the online single-pass variant."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import softmax as sm, ref


def _rand(rng, r, c, scale=1.0):
    return jnp.asarray(rng.uniform(-scale, scale, (r, c)), jnp.float32)


@settings(max_examples=10, deadline=None)
@given(
    ri=st.integers(1, 4),
    c=st.sampled_from([64, 128, 192, 256]),
    scale=st.sampled_from([1.0, 10.0, 50.0]),
)
def test_fused_matches_ref(ri, c, scale):
    rng = np.random.default_rng(ri * 1000 + c)
    x = _rand(rng, ri * 32, c, scale)
    np.testing.assert_allclose(
        sm.softmax_fused(x), ref.softmax(x), atol=1e-4, rtol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(ri=st.integers(1, 4), ci=st.integers(1, 4), scale=st.sampled_from([1.0, 30.0]))
def test_online_matches_ref(ri, ci, scale):
    rng = np.random.default_rng(ri * 10 + ci)
    x = _rand(rng, ri * 32, ci * 64, scale)
    np.testing.assert_allclose(
        sm.softmax_online(x), ref.softmax(x), atol=1e-4, rtol=1e-4
    )


@settings(max_examples=6, deadline=None)
@given(ri=st.integers(1, 3))
def test_naive_matches_ref(ri):
    rng = np.random.default_rng(ri)
    x = _rand(rng, ri * 32, 128)
    np.testing.assert_allclose(
        sm.softmax_naive(x), ref.softmax(x), atol=1e-4, rtol=1e-4
    )


def test_rows_sum_to_one(rng):
    x = _rand(np.random.default_rng(7), 64, 256, 20.0)
    s = jnp.sum(sm.softmax_online(x), axis=1)
    np.testing.assert_allclose(s, np.ones(64), atol=1e-5)


def test_bug_wrong_axis_detected(rng):
    x = _rand(np.random.default_rng(9), 64, 256)
    got = sm.softmax_fused_bug_wrong_axis(x)
    assert not np.allclose(got, ref.softmax(x), atol=1e-4, rtol=1e-4)
