import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def gen_input(rng, spec):
    """Materialize one InputSpec exactly like the Rust runtime does."""
    import jax.numpy as jnp

    if spec.dtype == "i32":
        return jnp.asarray(rng.integers(0, spec.mod, spec.shape), jnp.int32)
    return jnp.asarray(rng.uniform(spec.lo, spec.hi, spec.shape), jnp.float32)
