"""L2 catalog integrity + AOT lowering round-trip."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import CATALOG, mini_model_pallas
from compile.kernels import ref

from conftest import gen_input


def test_catalog_shape():
    names = [e.name for e in CATALOG]
    assert len(names) == len(set(names)), "artifact names must be unique"
    families = {e.family for e in CATALOG}
    assert {
        "matmul", "softmax", "cross_entropy", "linear_epilogue",
        "reduce_rows", "layernorm", "ew_chain", "diag_matmul", "mini_model",
    } <= families
    for e in CATALOG:
        if e.variant != "ref":
            assert e.ref_name in names, f"{e.name}: missing ref {e.ref_name}"
        assert e.tol == pytest.approx(1e-4)
    buggy = [e for e in CATALOG if e.buggy]
    assert len(buggy) >= 7, "need buggy variants to exercise the correction loop"


def test_mini_model_matches_ref(rng):
    entry = next(e for e in CATALOG if e.name == "mini_model_pallas")
    inputs = [gen_input(rng, s) for s in entry.inputs]
    got = mini_model_pallas(*inputs)
    want = ref.mini_model_loss(*inputs)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


def test_lowering_produces_parseable_hlo(tmp_path):
    # Lower a cheap entry end-to-end and sanity-check the HLO text.
    rc = aot.build(str(tmp_path), only="ew_chain_fused")
    assert rc == 0
    text = (tmp_path / "ew_chain_fused.hlo.txt").read_text()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # jax >= 0.5 64-bit-id proto issue is avoided by using text — make sure we
    # did not accidentally serialize a proto.
    assert "\x00" not in text


def test_manifest_written_and_fingerprint_noop(tmp_path, capsys):
    aot.build(str(tmp_path), only="ew_chain_fused")
    # `only` builds don't write a usable full manifest -> simulate a full one
    manifest = {
        "version": 1,
        "fingerprint": aot._sources_fingerprint(),
        "entries": [
            {
                "name": "ew_chain_fused",
                "file": "ew_chain_fused.hlo.txt",
                "family": "ew_chain",
                "variant": "fused",
                "ref": "ew_chain_ref",
                "buggy": False,
                "tol": 1e-4,
                "inputs": [],
            }
        ],
    }
    with open(os.path.join(tmp_path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    rc = aot.build(str(tmp_path))  # should no-op: fingerprint matches
    assert rc == 0
    assert "up to date" in capsys.readouterr().out


def test_input_specs_are_rust_consumable():
    for e in CATALOG:
        for s in e.inputs:
            d = s.to_json()
            assert d["dtype"] in ("f32", "i32")
            assert d["gen"] in ("uniform", "randint")
            if d["gen"] == "randint":
                assert d["mod"] > 0
            assert all(isinstance(x, int) and x > 0 for x in d["shape"]) or d[
                "shape"
            ] == []
