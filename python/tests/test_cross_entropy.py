"""L1 cross-entropy family (the Fig. 8 case-study operator) vs oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import cross_entropy as ce, ref


def _inputs(rng, b, c, scale=2.0):
    logits = jnp.asarray(rng.uniform(-scale, scale, (b, c)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, c, (b,)), jnp.int32)
    return logits, targets


@settings(max_examples=10, deadline=None)
@given(bi=st.integers(1, 4), c=st.sampled_from([32, 64, 128, 256]))
def test_lane_reduce_matches_ref(bi, c):
    rng = np.random.default_rng(bi * 1000 + c)
    logits, targets = _inputs(rng, bi * 32, c)
    np.testing.assert_allclose(
        ce.cross_entropy_lane_reduce(logits, targets),
        ref.cross_entropy(logits, targets),
        atol=1e-4, rtol=1e-4,
    )


@settings(max_examples=10, deadline=None)
@given(bi=st.integers(1, 4), c=st.sampled_from([32, 128]))
def test_block_reduce_matches_ref(bi, c):
    rng = np.random.default_rng(bi * 100 + c)
    logits, targets = _inputs(rng, bi * 32, c)
    np.testing.assert_allclose(
        ce.cross_entropy_block_reduce(logits, targets),
        ref.cross_entropy(logits, targets),
        atol=1e-4, rtol=1e-4,
    )


def test_losses_nonnegative_lower_bound():
    # CE loss >= -log(1) = 0 only for perfect one-hot; general bound: >= 0
    # when compared against log-sum-exp >= target logit.
    rng = np.random.default_rng(3)
    logits, targets = _inputs(rng, 64, 128)
    losses = np.asarray(ce.cross_entropy_lane_reduce(logits, targets))
    assert (losses >= -1e-5).all()


def test_bug_uninit_target_detected_and_localized():
    rng = np.random.default_rng(5)
    logits, targets = _inputs(rng, 64, 128)
    got = np.asarray(ce.cross_entropy_bug_uninit_target(logits, targets))
    want = np.asarray(ref.cross_entropy(logits, targets))
    # Row 0 wrong (unless target happens to be 0), every other row correct —
    # the exact "thread-0 uninitialized target_logit" signature from Fig. 8.
    np.testing.assert_allclose(got[1:], want[1:], atol=1e-4, rtol=1e-4)
    assert int(targets[0]) == 0 or abs(got[0] - want[0]) > 1e-4
