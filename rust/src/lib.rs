//! # CudaForge reproduction
//!
//! A Rust + JAX + Pallas (three-layer, AOT via PJRT) reproduction of
//! *CudaForge: An Agent Framework with Hardware Feedback for CUDA Kernel
//! Optimization* (2025). See DESIGN.md for the system inventory, the
//! substitution table (no GPUs / LLM APIs / NCU in this environment), and
//! the experiment index mapping every paper table and figure to a command.
//!
//! Layer map:
//! - L5 (`cluster`): the sharded multi-tenant cluster simulation — a
//!   rendezvous-hash router over N simulated nodes, each owning its own
//!   cache shard / single-flight queue / GPU-fleet slice, with weighted
//!   per-tenant fair-share quotas under overload, elastic membership
//!   (scheduled node failures *and* joins with planned-rebalance
//!   accounting, epoch-versioned), shard-aware snapshot/restore, and
//!   locality-aware cross-node warm-start routing.
//! - L4 (`service`): the kernel-optimization service layer (one node of
//!   the cluster) — content-addressed result cache, single-flight job
//!   queue, warm-start scheduling, and a discrete-event queueing simulation
//!   of Zipf traffic over a finite simulated GPU fleet (per-priority SLOs,
//!   admission control) — the first subsystem aimed at serving repeated
//!   multi-user traffic rather than reproducing paper tables.
//! - L3 (this crate): the CudaForge workflow — Coder/Judge agents, hardware
//!   feedback, the GPU/NCU simulator, the KernelBench-sim suite, baselines,
//!   the metric-selection pipeline, cost model, coordinator and reports.
//! - L2/L1 (`python/compile/`): JAX graphs + Pallas kernels, AOT-lowered to
//!   `artifacts/*.hlo.txt`; the `runtime` module executes them via PJRT for
//!   real-numerics correctness checks on the bound anchor tasks (requires
//!   the `pjrt` cargo feature + the vendored `xla` crate).

pub mod agents;
#[warn(missing_docs)]
pub mod analysis;
// The two production-facing subsystems keep their rustdoc complete — every
// public item documented — so `docs/` and the operator surface never drift
// from the code.
#[warn(missing_docs)]
pub mod cluster;
pub mod coordinator;
pub mod cost;
pub mod gpu;
pub mod kernel;
pub mod metrics;
pub mod report;
pub mod runtime;
#[warn(missing_docs)]
pub mod service;
pub mod sim;
pub mod tasks;
#[warn(missing_docs)]
pub mod trace;
pub mod util;
pub mod workflow;

/// Crate version (the `cudaforge version` stamp, also embedded in trace
/// headers and snapshot manifests).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Cargo features this binary was built with (empty on a default build).
pub fn features() -> Vec<&'static str> {
    let mut out = Vec::new();
    if cfg!(feature = "pjrt") {
        out.push("pjrt");
    }
    out
}
