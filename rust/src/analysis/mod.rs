//! Kernel static analysis — a deterministic linter over
//! `(TaskSpec, GpuSpec, KernelConfig)`.
//!
//! Real expert loops run static tools (compute-sanitizer's static checks,
//! clang-tidy CUDA rules) *before* paying for a compile+run; this module is
//! that feedback channel for the config IR. Each rule produces structured
//! [`Diagnostic`]s: a stable rule id, severity, a documented confidence, the
//! suspected [`Bug`] class (correctness rules) or a suggested catalog move
//! (perf-smell rules), and a human-readable message in the style of
//! [`Bug::error_log`].
//!
//! ## Determinism and the detection gates
//!
//! The Coder injects bugs *stochastically and independently of structure*
//! (`agents::coder`), so most defects are invisible to a purely structural
//! rule — exactly as in real CUDA, where the IR-level footprint of, say, a
//! race is only sometimes legible to a linter. We model that legibility with
//! deterministic hash gates: `gate(cfg, salt, k)` hashes the config
//! fingerprint and fires for one config in `k`. A rule "sees" a present bug
//! when its structural predicate holds and its miss-gate does not fire, and
//! emits a false positive when its (documented) FP-mode predicate and FP-gate
//! both hold. This is the static-analysis analogue of the Judge's rng-based
//! diagnosis — except *replayable*: the same config always lints the same
//! way, across threads, windows and runs, which is what lets the evaluation
//! layer measure per-rule precision/recall on a seeded corpus
//! ([`corpus`] / [`evaluate`], rendered by `report::lint_report`).
//!
//! Everything in this module is pure: no rng, no clocks, no IO.

use crate::agents::profiles::O3;
use crate::agents::Coder;
use crate::gpu::GpuSpec;
use crate::kernel::{Bug, KernelConfig, Opt};
use crate::tasks::{OpClass, TaskSpec};
use crate::util::rng::Rng;
use crate::workflow::fnv;

/// Diagnostic severity. `Error` means "this kernel will fail the correctness
/// stage"; `Warning` is a performance smell that costs rounds, not
/// correctness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Suspected correctness defect (maps to a [`Bug`] class).
    Error,
    /// Performance smell (maps to a catalog [`Opt`] where one applies).
    Warning,
}

impl Severity {
    /// Lowercase label used in rendered diagnostics and JSON.
    pub fn name(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// Stable identifier for one lint rule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum RuleId {
    /// Compile-class defects the front end would reject (missing header,
    /// syntax, wrong API overload).
    #[default]
    FrontEndParse,
    /// Launch geometry inconsistent with the task's output domain or the
    /// device launch limits.
    LaunchDomain,
    /// Shared-memory staging written and read without an intervening
    /// barrier.
    SmemRace,
    /// Tail-tile subscripts that can exceed the output extent.
    OobTail,
    /// Reads of lane-private values before their first write.
    UninitRead,
    /// Reduction axis inconsistent with the task's shape contract.
    AxisShape,
    /// Theoretical occupancy below half the device ceiling.
    OccupancyCeiling,
    /// Block size not a warp multiple (pre-legalization input only).
    BlockWarpMultiple,
    /// Reuse-heavy operator streaming from global memory with no staging.
    UnstagedReuse,
    /// Redundant full passes over the input.
    WastedPasses,
}

/// Every rule, in evaluation/report order.
pub const ALL_RULES: [RuleId; 10] = [
    RuleId::FrontEndParse,
    RuleId::LaunchDomain,
    RuleId::SmemRace,
    RuleId::OobTail,
    RuleId::UninitRead,
    RuleId::AxisShape,
    RuleId::OccupancyCeiling,
    RuleId::BlockWarpMultiple,
    RuleId::UnstagedReuse,
    RuleId::WastedPasses,
];

/// Bug classes no structural rule can suspect. `WrongConstant` is a wrong
/// scalar literal — bit-identical structure, so a config-level linter is
/// blind to it by construction (only the execution-stage diff catches it).
/// The exhaustiveness test pins this list: adding a `Bug` without either a
/// rule or an entry here fails CI.
pub const LINT_BLIND_SPOTS: [Bug; 1] = [Bug::WrongConstant];

impl RuleId {
    /// Stable kebab-case rule name (CLI/JSON/report key).
    pub fn name(self) -> &'static str {
        match self {
            RuleId::FrontEndParse => "front-end-parse",
            RuleId::LaunchDomain => "launch-domain",
            RuleId::SmemRace => "smem-race",
            RuleId::OobTail => "oob-tail",
            RuleId::UninitRead => "uninit-read",
            RuleId::AxisShape => "axis-shape",
            RuleId::OccupancyCeiling => "occupancy-ceiling",
            RuleId::BlockWarpMultiple => "block-warp-multiple",
            RuleId::UnstagedReuse => "unstaged-reuse",
            RuleId::WastedPasses => "wasted-passes",
        }
    }

    /// Inverse of `name()`.
    pub fn by_name(name: &str) -> Option<RuleId> {
        ALL_RULES.iter().copied().find(|r| r.name() == name)
    }

    /// Severity class of everything this rule emits.
    pub fn severity(self) -> Severity {
        if self.is_correctness() {
            Severity::Error
        } else {
            Severity::Warning
        }
    }

    /// Correctness rules suspect `Bug` classes; the rest are perf smells.
    pub fn is_correctness(self) -> bool {
        matches!(
            self,
            RuleId::FrontEndParse
                | RuleId::LaunchDomain
                | RuleId::SmemRace
                | RuleId::OobTail
                | RuleId::UninitRead
                | RuleId::AxisShape
        )
    }

    /// Documented confidence: a lower bound on the rule's measured precision
    /// over the seeded corpus (`report::lint_report` regenerates the
    /// evidence; the precision test enforces the bound for firing rules).
    /// The workflow's lint gate only spends a repair on diagnostics at or
    /// above its threshold.
    pub fn confidence(self) -> f64 {
        match self {
            RuleId::FrontEndParse => 0.96,
            RuleId::LaunchDomain => 0.94,
            RuleId::SmemRace => 0.90,
            RuleId::OobTail => 0.80,
            RuleId::UninitRead => 0.80,
            RuleId::AxisShape => 0.80,
            RuleId::OccupancyCeiling => 0.65,
            RuleId::BlockWarpMultiple => 0.90,
            RuleId::UnstagedReuse => 0.60,
            RuleId::WastedPasses => 0.60,
        }
    }

    /// Bug classes this rule can suspect (empty for perf smells).
    pub fn targets(self) -> &'static [Bug] {
        match self {
            RuleId::FrontEndParse => {
                &[Bug::CompileMissingHeader, Bug::CompileSyntax, Bug::CompileWrongApi]
            }
            RuleId::LaunchDomain => &[Bug::LaunchMisconfig],
            RuleId::SmemRace => &[Bug::RaceCondition],
            RuleId::OobTail => &[Bug::OobIndex],
            RuleId::UninitRead => &[Bug::UninitValue],
            RuleId::AxisShape => &[Bug::WrongAxis],
            _ => &[],
        }
    }

    /// The documented false-positive mode: when this rule fires on a healthy
    /// kernel, this is why.
    pub fn false_positive_mode(self) -> &'static str {
        match self {
            RuleId::FrontEndParse => {
                "intrinsics pulled in via transitive includes the scanner does \
                 not walk (e.g. warp-shuffle headers); extreme unrolling that \
                 defeats the brace matcher"
            }
            RuleId::LaunchDomain => {
                "hand-written launch geometry that intentionally exceeds the \
                 datasheet envelope (linted before legalization)"
            }
            RuleId::SmemRace => {
                "barrier-free staging that is actually safe because every lane \
                 only ever reads its own slot"
            }
            RuleId::OobTail => {
                "float4 tails on a ragged output that are in fact guarded by a \
                 predicated epilogue the rule cannot see"
            }
            RuleId::UninitRead => {
                "shuffle/double-buffer dataflow that initializes lanes through \
                 a path the def-use scan does not follow"
            }
            RuleId::AxisShape => {
                "asymmetric tiles over an axis reduction that are legitimate \
                 (the stride order merely looks transposed)"
            }
            RuleId::OccupancyCeiling => {
                "deliberate register blocking: low occupancy compensated by \
                 instruction-level parallelism"
            }
            RuleId::BlockWarpMultiple => {
                "cooperative sub-warp launches that never run full warps"
            }
            RuleId::UnstagedReuse => {
                "working sets small enough to live in L2, where staging buys \
                 nothing"
            }
            RuleId::WastedPasses => {
                "multi-pass algorithms kept for numerical accuracy (e.g. \
                 two-pass variance)"
            }
        }
    }
}

/// One structured finding.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    /// The rule that fired.
    pub rule: RuleId,
    /// Severity (always `rule.severity()`).
    pub severity: Severity,
    /// Confidence (always `rule.confidence()`).
    pub confidence: f64,
    /// Suspected defect class (correctness rules only).
    pub suspect: Option<Bug>,
    /// Suggested catalog move (perf rules, where one applies).
    pub suggestion: Option<Opt>,
    /// Human-readable message in the style of `Bug::error_log`.
    pub message: String,
}

impl Diagnostic {
    fn error(rule: RuleId, suspect: Bug, message: String) -> Diagnostic {
        Diagnostic {
            rule,
            severity: Severity::Error,
            confidence: rule.confidence(),
            suspect: Some(suspect),
            suggestion: None,
            message,
        }
    }

    fn warning(rule: RuleId, suggestion: Option<Opt>, message: String) -> Diagnostic {
        Diagnostic {
            rule,
            severity: Severity::Warning,
            confidence: rule.confidence(),
            suspect: None,
            suggestion,
            message,
        }
    }

    /// One-line rendering, greppable by rule id:
    /// `lint[smem-race] error: ... (confidence 0.90, suspect race_condition)`.
    pub fn render(&self) -> String {
        let tail = match (self.suspect, self.suggestion) {
            (Some(b), _) => format!(", suspect {}", b.name()),
            (None, Some(o)) => format!(", try {}", o.name()),
            (None, None) => String::new(),
        };
        format!(
            "lint[{}] {}: {} (confidence {:.2}{})",
            self.rule.name(),
            self.severity.name(),
            self.message,
            self.confidence,
            tail
        )
    }

    /// JSON form (the `cudaforge lint --json` wire format).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("rule", Json::str(self.rule.name())),
            ("severity", Json::str(self.severity.name())),
            ("confidence", Json::num(self.confidence)),
            (
                "suspect",
                self.suspect.map(|b| Json::str(b.name())).unwrap_or(Json::Null),
            ),
            (
                "suggestion",
                self.suggestion.map(|o| Json::str(o.name())).unwrap_or(Json::Null),
            ),
            ("message", Json::str(self.message.clone())),
        ])
    }

    /// Would the workflow's lint gate spend a pre-compile repair on this?
    /// (High-confidence correctness findings only.)
    pub fn triggers_repair(&self, threshold: f64) -> bool {
        self.severity == Severity::Error
            && self.suspect.is_some()
            && self.confidence >= threshold
    }
}

/// Deterministic legibility gate: true for one config in `one_in`, keyed on
/// the config fingerprint plus a per-rule salt. See the module docs for why
/// this replaces rng.
fn gate(cfg: &KernelConfig, salt: &str, one_in: u64) -> bool {
    fnv(&format!("{}#{salt}", cfg.describe())) % one_in == 0
}

fn axis_family(op: OpClass) -> bool {
    matches!(
        op,
        OpClass::Reduction | OpClass::Softmax | OpClass::Norm | OpClass::Scan | OpClass::Pool
    )
}

/// Theoretical blocks-per-SM and the limiting resource, from the datasheet
/// numbers the Judge also sees.
fn occupancy(gpu: &GpuSpec, cfg: &KernelConfig) -> (u32, &'static str) {
    let by_regs = gpu.regs_per_sm / (cfg.regs_per_thread * cfg.block_threads).max(1);
    let smem = cfg.smem_bytes();
    let by_smem = if smem > 0.0 {
        (gpu.smem_per_sm_kb * 1024.0 / smem) as u32
    } else {
        u32::MAX
    };
    let blocks = by_regs.min(by_smem).min(gpu.max_blocks_per_sm);
    let limiter = if blocks == gpu.max_blocks_per_sm {
        "block slots"
    } else if by_regs <= by_smem {
        "registers"
    } else {
        "shared memory"
    };
    (blocks, limiter)
}

/// Lint one candidate. Pure and deterministic: the same `(task, gpu, cfg)`
/// always yields the same diagnostics, in [`ALL_RULES`] order.
pub fn lint(task: &TaskSpec, gpu: &GpuSpec, cfg: &KernelConfig) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let has = |b: Bug| cfg.bugs.contains(&b);

    // --- front-end-parse: compile-class defects -------------------------
    for b in [Bug::CompileMissingHeader, Bug::CompileSyntax, Bug::CompileWrongApi] {
        if has(b) {
            let msg = match b {
                Bug::CompileMissingHeader => {
                    "declaration of \"__shfl_down_sync\" not found in any included header"
                }
                Bug::CompileSyntax => {
                    "unbalanced braces near the kernel body; parse stops before launch bounds"
                }
                _ => "call-site argument types match no visible overload",
            };
            out.push(Diagnostic::error(RuleId::FrontEndParse, b, msg.to_string()));
        }
    }
    if !cfg.has_compile_error() {
        if cfg.warp_shuffle && gate(cfg, "include", 28) {
            out.push(Diagnostic::error(
                RuleId::FrontEndParse,
                Bug::CompileMissingHeader,
                "warp intrinsic used but its header is not visible on the include path"
                    .to_string(),
            ));
        } else if cfg.unroll >= 16 && gate(cfg, "parse", 24) {
            out.push(Diagnostic::error(
                RuleId::FrontEndParse,
                Bug::CompileSyntax,
                "fully-unrolled body defeats the brace matcher; parse is ambiguous"
                    .to_string(),
            ));
        }
    }

    // --- launch-domain: geometry vs task domain and device limits -------
    if has(Bug::LaunchMisconfig) {
        out.push(Diagnostic::error(
            RuleId::LaunchDomain,
            Bug::LaunchMisconfig,
            format!(
                "grid x block ({} threads/block) does not cover the declared {} -element \
                 output domain",
                cfg.block_threads, task.out_elems as u64
            ),
        ));
    } else if !cfg.is_legal(gpu) {
        out.push(Diagnostic::error(
            RuleId::LaunchDomain,
            Bug::LaunchMisconfig,
            format!(
                "launch geometry violates device limits (block={} threads, smem={} B/block)",
                cfg.block_threads,
                cfg.smem_bytes() as u64
            ),
        ));
    }

    // --- smem-race: staging without barriers ----------------------------
    let race_visible = cfg.use_smem || cfg.fused_stages > 1 || cfg.warp_shuffle;
    if has(Bug::RaceCondition) && race_visible {
        out.push(Diagnostic::error(
            RuleId::SmemRace,
            Bug::RaceCondition,
            "shared staging is written and read with no dominating barrier; \
             interleavings may diverge run to run"
                .to_string(),
        ));
    } else if !has(Bug::RaceCondition) && cfg.use_smem && cfg.syncs_per_tile == 0 {
        out.push(Diagnostic::error(
            RuleId::SmemRace,
            Bug::RaceCondition,
            "shared-memory tile reused across iterations with zero __syncthreads() \
             per tile"
                .to_string(),
        ));
    }

    // --- oob-tail: tail tiles vs output extent --------------------------
    let tile_elems = (cfg.tile_m as u64 * cfg.tile_n as u64).max(1);
    let ragged = (task.out_elems as u64) % tile_elems != 0;
    if has(Bug::OobIndex) {
        if !gate(cfg, "oob-miss", 5) {
            out.push(Diagnostic::error(
                RuleId::OobTail,
                Bug::OobIndex,
                format!(
                    "tail-tile subscript can exceed the output extent ({} elements, \
                     {}x{} tiles)",
                    task.out_elems as u64, cfg.tile_m, cfg.tile_n
                ),
            ));
        }
    } else if cfg.vector_width == 4 && !cfg.grid_stride && ragged && gate(cfg, "oob-fp", 36)
    {
        out.push(Diagnostic::error(
            RuleId::OobTail,
            Bug::OobIndex,
            "float4 tail of a ragged output appears unguarded".to_string(),
        ));
    }

    // --- uninit-read: reads before first write --------------------------
    if has(Bug::UninitValue) {
        if !gate(cfg, "uninit-miss", 4) {
            out.push(Diagnostic::error(
                RuleId::UninitRead,
                Bug::UninitValue,
                "a lane-private accumulator may be read before its first write"
                    .to_string(),
            ));
        }
    } else if (cfg.warp_shuffle || cfg.double_buffer) && gate(cfg, "uninit-fp", 44) {
        out.push(Diagnostic::error(
            RuleId::UninitRead,
            Bug::UninitValue,
            "value crosses lanes before any visible initialization on this path"
                .to_string(),
        ));
    }

    // --- axis-shape: reduction axis vs task shape -----------------------
    if axis_family(task.op_class) {
        if has(Bug::WrongAxis) {
            out.push(Diagnostic::error(
                RuleId::AxisShape,
                Bug::WrongAxis,
                "reduction axis disagrees with the task's shape contract (rows vs \
                 columns)"
                    .to_string(),
            ));
        } else if cfg.tile_m != cfg.tile_n && gate(cfg, "axis-fp", 16) {
            out.push(Diagnostic::error(
                RuleId::AxisShape,
                Bug::WrongAxis,
                format!(
                    "asymmetric {}x{} tile over an axis reduction; stride order looks \
                     transposed",
                    cfg.tile_m, cfg.tile_n
                ),
            ));
        }
    }

    // --- occupancy-ceiling (perf) ---------------------------------------
    let warps_per_block = cfg.block_threads / gpu.warp_size.max(1);
    let (blocks, limiter) = occupancy(gpu, cfg);
    let warps = (blocks * warps_per_block).min(gpu.max_warps_per_sm);
    if warps * 2 < gpu.max_warps_per_sm {
        let suggestion = match limiter {
            "registers" if Opt::ReduceRegisterPressure.applicable(task, cfg) => {
                Some(Opt::ReduceRegisterPressure)
            }
            "shared memory" if Opt::ShrinkBlock.applicable(task, cfg) => {
                Some(Opt::ShrinkBlock)
            }
            _ => None,
        };
        out.push(Diagnostic::warning(
            RuleId::OccupancyCeiling,
            suggestion,
            format!(
                "theoretical occupancy {}/{} warps per SM, limited by {}",
                warps, gpu.max_warps_per_sm, limiter
            ),
        ));
    }

    // --- block-warp-multiple (perf; pre-legalization input only) --------
    if cfg.block_threads % gpu.warp_size != 0 || cfg.block_threads < gpu.warp_size {
        out.push(Diagnostic::warning(
            RuleId::BlockWarpMultiple,
            None,
            format!(
                "block of {} threads is not a multiple of the warp size ({}); the \
                 trailing partial warp is dead lanes",
                cfg.block_threads, gpu.warp_size
            ),
        ));
    }

    // --- unstaged-reuse (perf) ------------------------------------------
    if Opt::UseSharedMemoryTiling.applicable(task, cfg) {
        out.push(Diagnostic::warning(
            RuleId::UnstagedReuse,
            Some(Opt::UseSharedMemoryTiling),
            "reuse-heavy operator streams operands from global memory with no \
             shared-memory staging"
                .to_string(),
        ));
    }

    // --- wasted-passes (perf) -------------------------------------------
    if cfg.extra_global_passes >= 1 {
        let suggestion = if Opt::OnlineAlgorithm.applicable(task, cfg) {
            Some(Opt::OnlineAlgorithm)
        } else if Opt::CacheInRegisters.applicable(task, cfg) {
            Some(Opt::CacheInRegisters)
        } else {
            None
        };
        if cfg.extra_global_passes >= 2 || suggestion == Some(Opt::OnlineAlgorithm) {
            out.push(Diagnostic::warning(
                RuleId::WastedPasses,
                suggestion,
                format!(
                    "{} redundant full pass(es) over the input",
                    cfg.extra_global_passes
                ),
            ));
        }
    }

    out
}

/// The candidate a fresh workflow run would lint first: the Coder's initial
/// config under the workflow's own per-task seed derivation
/// (`seed ^ fnv(task.id())`), ground-truth bugs included. The `cudaforge
/// lint` subcommand lints exactly this, so its output lines up with what
/// `run --task ... --lint` gates on in round 1.
pub fn round_one_candidate(
    coder: crate::agents::ModelProfile,
    task: &TaskSpec,
    gpu: &GpuSpec,
    seed: u64,
) -> KernelConfig {
    let mut rng = Rng::new(seed ^ fnv(&task.id()));
    let (cfg, _) = Coder::new(coder).initial(task, gpu, &mut rng);
    cfg
}

/// A seeded evaluation corpus: `n` Coder-generated candidates (with their
/// ground-truth injected bugs) over the KernelBench suite, diversified by a
/// few catalog transforms — which never touch `bugs`, so the ground truth
/// stays exactly what the Coder injected.
pub fn corpus(gpu: &GpuSpec, seed: u64, n: usize) -> Vec<(TaskSpec, KernelConfig)> {
    let tasks = crate::tasks::kernelbench();
    let coder = Coder::new(O3);
    (0..n)
        .map(|i| {
            let task = tasks[i % tasks.len()].clone();
            let mut rng = Rng::new(
                seed ^ fnv(&task.id())
                    ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            let (mut cfg, _) = coder.initial(&task, gpu, &mut rng);
            for _ in 0..rng.below(4) {
                if let Some(o) = crate::agents::coder::random_applicable(&task, &cfg, &mut rng)
                {
                    o.apply(&mut cfg, &task, gpu);
                }
            }
            (task, cfg)
        })
        .collect()
}

/// Per-rule confusion counts over a corpus.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RuleScore {
    /// The rule being scored.
    pub rule: RuleId,
    /// Diagnostics emitted.
    pub fired: usize,
    /// Correctness rules: suspect bug actually present. Perf rules: the
    /// named move is applicable per the catalog's own guard (or the smell
    /// predicate holds when no move is named).
    pub tp: usize,
    /// Fired without ground truth behind it.
    pub fp: usize,
    /// Ground truth present (target bug injected / named move applicable)
    /// but the rule stayed silent. Perf rules without a target predicate
    /// report 0.
    pub missed: usize,
}

impl RuleScore {
    /// tp / (tp + fp); `None` when the rule never fired.
    pub fn precision(&self) -> Option<f64> {
        let d = self.tp + self.fp;
        (d > 0).then(|| self.tp as f64 / d as f64)
    }

    /// tp / (tp + missed); `None` when there was no ground truth to find.
    pub fn recall(&self) -> Option<f64> {
        let d = self.tp + self.missed;
        (d > 0).then(|| self.tp as f64 / d as f64)
    }

    /// Harmonic mean of precision and recall, when both exist.
    pub fn f1(&self) -> Option<f64> {
        let (p, r) = (self.precision()?, self.recall()?);
        ((p + r) > 0.0).then(|| 2.0 * p * r / (p + r))
    }
}

/// Score every rule against the corpus ground truth. Correctness rules are
/// scored against the injected `Bug`s; perf rules against the catalog's own
/// applicability guards.
pub fn evaluate(gpu: &GpuSpec, corpus: &[(TaskSpec, KernelConfig)]) -> Vec<RuleScore> {
    let mut scores: Vec<RuleScore> = ALL_RULES
        .iter()
        .map(|&rule| RuleScore { rule, ..RuleScore::default() })
        .collect();
    for (task, cfg) in corpus {
        let diags = lint(task, gpu, cfg);
        for score in scores.iter_mut() {
            let mine: Vec<&Diagnostic> =
                diags.iter().filter(|d| d.rule == score.rule).collect();
            score.fired += mine.len();
            if score.rule.is_correctness() {
                for d in &mine {
                    let b = d.suspect.expect("correctness diagnostics carry a suspect");
                    if cfg.bugs.contains(&b) {
                        score.tp += 1;
                    } else {
                        score.fp += 1;
                    }
                }
                for &b in score.rule.targets() {
                    if cfg.bugs.contains(&b) && !mine.iter().any(|d| d.suspect == Some(b)) {
                        score.missed += 1;
                    }
                }
            } else {
                for d in &mine {
                    match d.suggestion {
                        Some(o) if !o.applicable(task, cfg) => score.fp += 1,
                        _ => score.tp += 1,
                    }
                }
                // Target predicate for the two smells that name one move.
                let wanted = match score.rule {
                    RuleId::UnstagedReuse => {
                        Opt::UseSharedMemoryTiling.applicable(task, cfg)
                    }
                    RuleId::WastedPasses => Opt::OnlineAlgorithm.applicable(task, cfg),
                    _ => false,
                };
                if wanted && mine.is_empty() {
                    score.missed += 1;
                }
            }
        }
    }
    scores
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;
    use crate::kernel::ALL_BUGS;
    use crate::tasks::by_id;

    fn reuse_task() -> TaskSpec {
        by_id("L1-1").unwrap() // matmul anchor: data reuse
    }

    fn axis_task() -> TaskSpec {
        let tasks = crate::tasks::kernelbench();
        tasks
            .iter()
            .find(|t| axis_family(t.op_class))
            .expect("suite has axis-family tasks")
            .clone()
    }

    #[test]
    fn rule_names_round_trip_and_metadata_is_total() {
        for r in ALL_RULES {
            assert_eq!(RuleId::by_name(r.name()), Some(r));
            assert!(!r.false_positive_mode().is_empty());
            assert!((0.0..=1.0).contains(&r.confidence()));
            assert_eq!(r.is_correctness(), !r.targets().is_empty());
        }
        assert_eq!(RuleId::by_name("no-such-rule"), None);
    }

    /// The ISSUE-7 exhaustiveness contract: every bug class round-trips its
    /// name, surfaces a non-empty error log, and is either covered by a lint
    /// rule or explicitly documented as a blind spot. A new `Bug` variant
    /// without analyzer/feedback coverage fails here.
    #[test]
    fn every_bug_is_named_logged_and_covered_or_documented_blind() {
        for b in ALL_BUGS {
            assert_eq!(Bug::by_name(b.name()), Some(b), "{} round trip", b.name());
            assert!(!b.error_log().is_empty(), "{} has no error log", b.name());
            let covered = ALL_RULES.iter().any(|r| r.targets().contains(&b));
            let blind = LINT_BLIND_SPOTS.contains(&b);
            assert!(
                covered ^ blind,
                "{} must be covered by exactly one of: a lint rule, LINT_BLIND_SPOTS",
                b.name()
            );
        }
        assert!(Bug::by_name("not_a_bug").is_none());
    }

    #[test]
    fn lint_is_deterministic() {
        let task = reuse_task();
        let mut cfg = KernelConfig::naive();
        cfg.bugs.push(Bug::CompileSyntax);
        let a = lint(&task, &RTX6000_ADA, &cfg);
        let b = lint(&task, &RTX6000_ADA, &cfg);
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn compile_bugs_are_always_caught() {
        let task = reuse_task();
        for b in [Bug::CompileMissingHeader, Bug::CompileSyntax, Bug::CompileWrongApi] {
            let mut cfg = KernelConfig::naive();
            cfg.bugs.push(b);
            let diags = lint(&task, &RTX6000_ADA, &cfg);
            assert!(
                diags
                    .iter()
                    .any(|d| d.rule == RuleId::FrontEndParse && d.suspect == Some(b)),
                "{} not caught",
                b.name()
            );
        }
    }

    #[test]
    fn smem_race_fires_when_staging_is_visible() {
        let task = reuse_task();
        let mut cfg = KernelConfig::naive();
        cfg.use_smem = true;
        cfg.syncs_per_tile = 2;
        cfg.bugs.push(Bug::RaceCondition);
        let diags = lint(&task, &RTX6000_ADA, &cfg);
        assert!(diags.iter().any(|d| d.suspect == Some(Bug::RaceCondition)));

        // Invisible race: no staging, no fusion, no shuffle.
        let mut plain = KernelConfig::naive();
        plain.bugs.push(Bug::RaceCondition);
        let diags = lint(&task, &RTX6000_ADA, &plain);
        assert!(!diags.iter().any(|d| d.suspect == Some(Bug::RaceCondition)));
    }

    /// Each correctness rule's documented FP mode is demonstrable on a
    /// hand-built healthy config.
    #[test]
    fn documented_false_positive_modes_are_reachable() {
        let task = reuse_task();

        // smem-race FP: staging with zero barriers, no actual race bug.
        let mut cfg = KernelConfig::naive();
        cfg.use_smem = true;
        cfg.syncs_per_tile = 0;
        let diags = lint(&task, &RTX6000_ADA, &cfg);
        assert!(
            diags
                .iter()
                .any(|d| d.rule == RuleId::SmemRace && d.suspect == Some(Bug::RaceCondition)),
            "smem-race FP mode unreachable"
        );

        // launch-domain FP: illegal geometry, no launch bug.
        let mut cfg = KernelConfig::naive();
        cfg.block_threads = 1000; // not a warp multiple
        let diags = lint(&task, &RTX6000_ADA, &cfg);
        assert!(diags.iter().any(|d| d.rule == RuleId::LaunchDomain));
        assert!(diags.iter().any(|d| d.rule == RuleId::BlockWarpMultiple));

        // axis-shape FP: asymmetric tile on an axis task (hash-gated; scan
        // tile shapes until the gate opens to prove reachability).
        let at = axis_task();
        let mut hit = false;
        for tm in 1..200u32 {
            let mut cfg = KernelConfig::naive();
            cfg.tile_m = tm;
            cfg.tile_n = tm + 1;
            if lint(&at, &RTX6000_ADA, &cfg).iter().any(|d| d.rule == RuleId::AxisShape) {
                hit = true;
                break;
            }
        }
        assert!(hit, "axis-shape FP mode unreachable");
    }

    #[test]
    fn perf_smells_fire_and_name_applicable_moves() {
        let task = reuse_task();
        let cfg = KernelConfig::naive(); // no staging on a reuse task
        let diags = lint(&task, &RTX6000_ADA, &cfg);
        let reuse =
            diags.iter().find(|d| d.rule == RuleId::UnstagedReuse).expect("smell fires");
        assert_eq!(reuse.suggestion, Some(Opt::UseSharedMemoryTiling));
        assert!(Opt::UseSharedMemoryTiling.applicable(&task, &cfg));

        // Occupancy: huge register footprint on a big block.
        let mut fat = KernelConfig::naive();
        fat.block_threads = 512;
        fat.regs_per_thread = 120;
        let diags = lint(&task, &RTX6000_ADA, &fat);
        let occ = diags
            .iter()
            .find(|d| d.rule == RuleId::OccupancyCeiling)
            .expect("occupancy smell fires");
        assert_eq!(occ.suggestion, Some(Opt::ReduceRegisterPressure));
    }

    #[test]
    fn corpus_is_seeded_and_deterministic() {
        let a = corpus(&RTX6000_ADA, 2024, 40);
        let b = corpus(&RTX6000_ADA, 2024, 40);
        assert_eq!(a.len(), 40);
        for ((ta, ca), (tb, cb)) in a.iter().zip(&b) {
            assert_eq!(ta.id(), tb.id());
            assert_eq!(ca, cb);
        }
        let c = corpus(&RTX6000_ADA, 2025, 40);
        assert!(a.iter().zip(&c).any(|((_, x), (_, y))| x != y));
    }

    /// The acceptance bar: on the default seeded corpus every correctness
    /// rule that fires has precision >= 0.8 (its documented confidence is a
    /// lower bound), and the analyzer as a whole catches a useful share of
    /// the injected defects.
    #[test]
    fn correctness_rules_hold_their_documented_precision() {
        let corpus = corpus(&RTX6000_ADA, 2024, 250);
        assert!(corpus.len() >= 200);
        let scores = evaluate(&RTX6000_ADA, &corpus);
        let mut fired_any = 0;
        for s in scores.iter().filter(|s| s.rule.is_correctness()) {
            if let Some(p) = s.precision() {
                fired_any += 1;
                assert!(
                    p >= 0.8,
                    "{}: precision {:.2} < 0.8 (tp={} fp={})",
                    s.rule.name(),
                    p,
                    s.tp,
                    s.fp
                );
            }
        }
        assert!(fired_any >= 4, "most correctness rules should fire on the corpus");
        let tp: usize =
            scores.iter().filter(|s| s.rule.is_correctness()).map(|s| s.tp).sum();
        let missed: usize =
            scores.iter().filter(|s| s.rule.is_correctness()).map(|s| s.missed).sum();
        let recall = tp as f64 / (tp + missed).max(1) as f64;
        assert!(recall > 0.45, "overall correctness recall {recall:.2} too low");
    }

    #[test]
    fn diagnostics_render_and_serialize() {
        let task = reuse_task();
        let mut cfg = KernelConfig::naive();
        cfg.bugs.push(Bug::CompileSyntax);
        let diags = lint(&task, &RTX6000_ADA, &cfg);
        let d = &diags[0];
        let line = d.render();
        assert!(line.starts_with("lint[front-end-parse] error:"), "{line}");
        assert!(line.contains("suspect syntax_error"), "{line}");
        let j = d.to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("rule").and_then(|x| x.as_str()), Some("front-end-parse"));
        assert_eq!(v.get("suspect").and_then(|x| x.as_str()), Some("syntax_error"));
    }

    #[test]
    fn repair_trigger_respects_threshold_and_severity() {
        let task = reuse_task();
        let mut cfg = KernelConfig::naive();
        cfg.bugs.push(Bug::CompileSyntax);
        let diags = lint(&task, &RTX6000_ADA, &cfg);
        assert!(diags[0].triggers_repair(0.9));
        assert!(!diags[0].triggers_repair(0.99));
        // Perf warnings never trigger repairs.
        let healthy = KernelConfig::naive();
        for d in lint(&task, &RTX6000_ADA, &healthy) {
            assert!(!d.triggers_repair(0.0));
        }
    }
}
