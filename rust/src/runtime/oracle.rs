//! Real-numerics correctness oracle.
//!
//! At startup, `VerificationMatrix::build` executes **every** kernel-variant
//! artifact against its pure-jnp reference on the PJRT CPU client and records
//! the verdicts. During workflow runs the oracle maps an agent-generated
//! kernel configuration onto the matching artifact variant for the task's
//! bound family and reports that artifact's *measured* verdict — so the
//! correction loop's pass/fail signals on anchor tasks come from genuine
//! executions of genuine (sometimes genuinely buggy) kernels, not from the
//! bug model.

use std::collections::HashMap;

use crate::kernel::{Bug, KernelConfig};
use crate::tasks::TaskSpec;
use crate::workflow::{CheckOutcome, CorrectnessOracle};

/// Measured verdict for one artifact.
#[derive(Clone, Debug)]
pub struct Verdict {
    pub passes: bool,
    pub max_abs_diff: f64,
    pub elements: usize,
}

/// All artifact verdicts, measured once on the PJRT client.
#[derive(Clone, Debug, Default)]
pub struct VerificationMatrix {
    pub verdicts: HashMap<String, Verdict>,
    /// family -> variant names present (non-ref).
    pub by_family: HashMap<String, Vec<String>>,
}

impl VerificationMatrix {
    /// Execute every non-reference artifact against its reference.
    #[cfg(feature = "pjrt")]
    pub fn build(
        engine: &mut crate::runtime::Engine,
        seed: u64,
    ) -> anyhow::Result<VerificationMatrix> {
        let names: Vec<(String, String)> = engine
            .manifest()
            .entries
            .iter()
            .filter(|e| !e.reference.is_empty())
            .map(|e| (e.name.clone(), e.family.clone()))
            .collect();
        let mut m = VerificationMatrix::default();
        for (name, family) in names {
            let (passes, max_abs_diff, elements) = engine.check_against_ref(&name, seed)?;
            m.verdicts.insert(name.clone(), Verdict { passes, max_abs_diff, elements });
            m.by_family.entry(family).or_default().push(name);
        }
        Ok(m)
    }

    /// Sanity: every `bug_*` artifact must actually fail, every other variant
    /// must actually pass (this is asserted in the integration tests — if a
    /// "buggy" kernel passes tolerance the whole correction-loop story would
    /// be fake).
    pub fn is_consistent(&self) -> bool {
        self.verdicts.iter().all(|(name, v)| {
            let should_fail = name.contains("bug_");
            should_fail != v.passes
        })
    }
}

/// Maps a workflow (task, config) onto the artifact realizing it.
pub fn artifact_for(family: &str, cfg: &KernelConfig) -> Option<String> {
    // Runtime-buggy config -> the family's matching buggy artifact.
    let runtime_bug = cfg.bugs.iter().copied().find(|b| !b.is_compile_error());
    if let Some(bug) = runtime_bug {
        let name = match (family, bug) {
            ("matmul", Bug::OobIndex) => "matmul_bug_oob",
            ("matmul", _) => "matmul_bug_uninit",
            ("softmax", _) => "softmax_bug_wrong_axis",
            ("cross_entropy", _) => "cross_entropy_bug_uninit_target",
            ("linear_epilogue", _) => "linear_epilogue_bug_wrong_gelu",
            ("reduce_rows", _) => "reduce_rows_bug_off_by_one",
            ("layernorm", _) => "layernorm_bug_biased_var",
            ("ew_chain", _) => "ew_chain_bug_wrong_const",
            ("diag_matmul", _) => "diag_matmul_bug_transposed",
            _ => return None,
        };
        return Some(name.to_string());
    }
    // Clean config -> the variant expressing its optimization state.
    let name = match family {
        "matmul" => {
            if cfg.fused_stages > 1 {
                "matmul_bias_relu_fused" // fused epilogue variant
            } else if cfg.use_smem {
                "matmul_tiled"
            } else {
                "matmul_naive"
            }
        }
        "softmax" => {
            if cfg.online_algorithm {
                "softmax_online"
            } else if cfg.extra_global_passes == 0 {
                "softmax_fused"
            } else {
                "softmax_naive"
            }
        }
        "cross_entropy" => {
            if cfg.warp_shuffle || cfg.extra_global_passes == 0 {
                "cross_entropy_lane_reduce"
            } else {
                "cross_entropy_block_reduce"
            }
        }
        "linear_epilogue" => {
            if cfg.fused_stages >= 2 {
                "linear_epilogue_fused"
            } else {
                "linear_epilogue_unfused"
            }
        }
        "reduce_rows" => {
            if cfg.extra_global_passes == 0 {
                "reduce_rows_onepass"
            } else {
                "reduce_rows_twopass"
            }
        }
        "layernorm" => {
            if cfg.fused_stages >= 2 || cfg.extra_global_passes == 0 {
                "layernorm_fused"
            } else {
                "layernorm_naive"
            }
        }
        "ew_chain" => {
            if cfg.fused_stages >= 2 {
                "ew_chain_fused"
            } else {
                "ew_chain_unfused"
            }
        }
        "diag_matmul" => {
            if cfg.algo_optimal {
                "diag_matmul_broadcast"
            } else {
                "diag_matmul_full_diag"
            }
        }
        "matmul_bias_relu" => "matmul_bias_relu_fused",
        "mini_model" => "mini_model_pallas",
        _ => return None,
    };
    Some(name.to_string())
}

/// The oracle handed to the workflow: pure data (Sync), built once.
pub struct RealOracle {
    matrix: VerificationMatrix,
}

impl RealOracle {
    pub fn new(matrix: VerificationMatrix) -> RealOracle {
        RealOracle { matrix }
    }

    pub fn matrix(&self) -> &VerificationMatrix {
        &self.matrix
    }
}

impl CorrectnessOracle for RealOracle {
    fn check(&self, task: &TaskSpec, cfg: &KernelConfig) -> Option<CheckOutcome> {
        let family = task.binding?;
        // Compile errors never reach execution; the artifact layer has
        // nothing to say about them.
        if let Some(b) = cfg.bugs.iter().find(|b| b.is_compile_error()) {
            return Some(CheckOutcome::CompileError(b.error_log().to_string()));
        }
        let name = artifact_for(family, cfg)?;
        let verdict = self.matrix.verdicts.get(&name)?;
        if verdict.passes {
            Some(CheckOutcome::Pass)
        } else {
            Some(CheckOutcome::Mismatch(format!(
                "Outputs are not close: artifact {} max|diff|={:.3e} over {} elements \
                 (tolerance 1e-4)",
                name, verdict.max_abs_diff, verdict.elements
            )))
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn artifact_mapping_covers_families() {
        let mut cfg = KernelConfig::naive();
        assert_eq!(artifact_for("matmul", &cfg).unwrap(), "matmul_naive");
        cfg.use_smem = true;
        assert_eq!(artifact_for("matmul", &cfg).unwrap(), "matmul_tiled");
        cfg.bugs.push(Bug::OobIndex);
        assert_eq!(artifact_for("matmul", &cfg).unwrap(), "matmul_bug_oob");
        cfg.bugs.clear();
        cfg.online_algorithm = true;
        assert_eq!(artifact_for("softmax", &cfg).unwrap(), "softmax_online");
        cfg.algo_optimal = true;
        assert_eq!(artifact_for("diag_matmul", &cfg).unwrap(), "diag_matmul_broadcast");
        assert!(artifact_for("unknown_family", &cfg).is_none());
    }

    #[test]
    fn compile_errors_short_circuit() {
        let matrix = VerificationMatrix::default();
        let oracle = RealOracle::new(matrix);
        let task = crate::tasks::by_id("L1-95").unwrap();
        let mut cfg = KernelConfig::naive();
        cfg.bugs.push(Bug::CompileSyntax);
        match oracle.check(&task, &cfg) {
            Some(CheckOutcome::CompileError(_)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unbound_tasks_defer_to_model() {
        let oracle = RealOracle::new(VerificationMatrix::default());
        let task = crate::tasks::by_id("L1-2").unwrap(); // no binding
        assert!(oracle.check(&task, &KernelConfig::naive()).is_none());
    }
}
