//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text — see /opt/xla-example/README.md for why text, not protos) and
//! executes them on the PJRT CPU client via the `xla` crate.
//!
//! This is where the three layers compose: the Pallas kernels (L1) lowered
//! through JAX (L2) run under the Rust coordinator (L3), giving the workflow
//! *real numerics* for the artifact-bound anchor tasks — the correctness
//! stage genuinely executes a kernel variant against its pure-jnp reference
//! at the paper's tolerance (1e-4), including intentionally-buggy variants
//! that produce genuinely wrong outputs.
//!
//! The `xla`-backed engine is gated behind the `pjrt` cargo feature so the
//! crate builds offline without the vendored `xla` crate; manifest parsing
//! and the oracle types stay available either way.

pub mod oracle;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[cfg(feature = "pjrt")]
use anyhow::bail;
#[cfg(feature = "pjrt")]
use crate::util::rng::Rng;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;

/// Input generator spec from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub enum GenSpec {
    /// Uniform f32 in [lo, hi).
    Uniform { lo: f32, hi: f32 },
    /// Uniform i32 in [0, mod).
    RandInt { modulus: i32 },
}

/// One input tensor spec.
#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
    pub gen: GenSpec,
}

impl InputSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One artifact catalog entry.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub family: String,
    pub variant: String,
    pub file: String,
    pub reference: String,
    pub buggy: bool,
    pub tol: f64,
    pub inputs: Vec<InputSpec>,
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        let mut out = Vec::with_capacity(entries.len());
        for e in entries {
            let field = |k: &str| -> Result<&Json> {
                e.get(k).ok_or_else(|| anyhow!("entry missing {k}"))
            };
            let mut inputs = Vec::new();
            for i in field("inputs")?.as_arr().unwrap_or(&[]) {
                let shape = i
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default();
                let dtype = i
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("f32")
                    .to_string();
                let gen = match i.get("gen").and_then(|g| g.as_str()) {
                    Some("randint") => GenSpec::RandInt {
                        modulus: i.get("mod").and_then(|m| m.as_f64()).unwrap_or(2.0) as i32,
                    },
                    _ => GenSpec::Uniform {
                        lo: i.get("lo").and_then(|x| x.as_f64()).unwrap_or(-1.0) as f32,
                        hi: i.get("hi").and_then(|x| x.as_f64()).unwrap_or(1.0) as f32,
                    },
                };
                inputs.push(InputSpec { shape, dtype, gen });
            }
            out.push(ManifestEntry {
                name: field("name")?.as_str().unwrap_or("").to_string(),
                family: field("family")?.as_str().unwrap_or("").to_string(),
                variant: field("variant")?.as_str().unwrap_or("").to_string(),
                file: field("file")?.as_str().unwrap_or("").to_string(),
                reference: field("ref")?.as_str().unwrap_or("").to_string(),
                buggy: field("buggy")?.as_bool().unwrap_or(false),
                tol: field("tol")?.as_f64().unwrap_or(1e-4),
                inputs: out_inputs(inputs),
            });
        }
        Ok(Manifest { dir, entries: out })
    }

    pub fn by_name(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn families(&self) -> Vec<&str> {
        let mut f: Vec<&str> = self.entries.iter().map(|e| e.family.as_str()).collect();
        f.sort_unstable();
        f.dedup();
        f
    }
}

fn out_inputs(v: Vec<InputSpec>) -> Vec<InputSpec> {
    v
}

/// Build the real-numerics oracle: compiles + executes every artifact
/// variant against its reference and records the verdicts. Returns `None`
/// when the engine is unavailable (artifacts missing, or the crate was built
/// without the `pjrt` feature).
#[cfg(feature = "pjrt")]
pub fn try_real_oracle(dir: &str, seed: u64) -> Option<oracle::RealOracle> {
    match Engine::new(dir).and_then(|mut e| oracle::VerificationMatrix::build(&mut e, seed)) {
        Ok(m) => Some(oracle::RealOracle::new(m)),
        Err(e) => {
            eprintln!("[real-numerics oracle unavailable: {e}]");
            None
        }
    }
}

/// Without the `pjrt` feature there is no execution engine; callers fall
/// back to the modelled correctness check.
#[cfg(not(feature = "pjrt"))]
pub fn try_real_oracle(_dir: &str, _seed: u64) -> Option<oracle::RealOracle> {
    None
}

/// The PJRT execution engine: a CPU client plus a compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, compiled: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (and cache) one artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Generate deterministic inputs for an entry (both the variant and its
    /// reference receive the *same* literals — the paper's "same inputs").
    pub fn gen_inputs(&self, entry: &ManifestEntry, seed: u64) -> Result<Vec<xla::Literal>> {
        let mut rng = Rng::new(seed);
        let mut lits = Vec::with_capacity(entry.inputs.len());
        for spec in &entry.inputs {
            let n = spec.elems();
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (&spec.gen, spec.dtype.as_str()) {
                (GenSpec::Uniform { lo, hi }, _) => {
                    let data: Vec<f32> =
                        (0..n).map(|_| rng.uniform_f32(*lo, *hi)).collect();
                    if spec.shape.is_empty() {
                        xla::Literal::from(data[0])
                    } else {
                        xla::Literal::vec1(&data)
                            .reshape(&dims)
                            .map_err(|e| anyhow!("reshape: {e:?}"))?
                    }
                }
                (GenSpec::RandInt { modulus }, _) => {
                    let data: Vec<i32> =
                        (0..n).map(|_| rng.below(*modulus as usize) as i32).collect();
                    if spec.shape.is_empty() {
                        xla::Literal::from(data[0])
                    } else {
                        xla::Literal::vec1(&data)
                            .reshape(&dims)
                            .map_err(|e| anyhow!("reshape: {e:?}"))?
                    }
                }
            };
            lits.push(lit);
        }
        Ok(lits)
    }

    /// Execute an artifact on inputs, returning the flattened f32 output.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        self.compile(name)?;
        let exe = self.compiled.get(name).expect("compile(name) just populated the entry");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Run variant-vs-reference on identical inputs and compare at the
    /// manifest tolerance. Returns (passes, max_abs_diff, n_elements).
    pub fn check_against_ref(&mut self, name: &str, seed: u64) -> Result<(bool, f64, usize)> {
        let entry = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        if entry.reference.is_empty() {
            bail!("{name} is itself a reference artifact");
        }
        let inputs = self.gen_inputs(&entry, seed)?;
        let got = self.execute(&entry.name, &inputs)?;
        let want = self.execute(&entry.reference, &inputs)?;
        if got.len() != want.len() {
            bail!("{name}: output length {} vs ref {}", got.len(), want.len());
        }
        let tol = entry.tol;
        let mut max_diff = 0.0f64;
        let mut ok = true;
        for (a, b) in got.iter().zip(&want) {
            let diff = (a - b).abs() as f64;
            max_diff = max_diff.max(diff);
            if diff > tol + tol * (b.abs() as f64) {
                ok = false;
            }
        }
        Ok((ok, max_diff, got.len()))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses_and_is_complete() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.entries.len() >= 30, "{} entries", m.entries.len());
        for e in &m.entries {
            assert!(m.dir.join(&e.file).exists(), "{} file missing", e.name);
            if !e.reference.is_empty() {
                assert!(m.by_name(&e.reference).is_some(), "{} dangling ref", e.name);
            }
        }
        let fams = m.families();
        for f in [
            "matmul", "softmax", "cross_entropy", "linear_epilogue", "reduce_rows",
            "layernorm", "ew_chain", "diag_matmul", "mini_model",
        ] {
            assert!(fams.contains(&f), "missing family {f}");
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn input_specs_materialize() {
        if !have_artifacts() {
            return;
        }
        let engine = Engine::new(artifacts_dir()).unwrap();
        let entry = engine.manifest().by_name("cross_entropy_lane_reduce").unwrap().clone();
        let lits = engine.gen_inputs(&entry, 7).unwrap();
        assert_eq!(lits.len(), 2); // logits + targets
    }
}
