//! KernelBench-sim: the 250-task workload suite (DESIGN.md §2).
//!
//! Each task is a *workload descriptor* — FLOPs, minimum HBM traffic,
//! fusable-stage structure, tensor-core eligibility, and the quality/waste of
//! its PyTorch reference — which is exactly the information KernelBench tasks
//! contribute to the paper's evaluation. Levels follow Appendix D.1:
//! L1 = 100 basic operators, L2 = 100 multi-step fusions, L3 = 50 full
//! architectures. Named anchors pin the tasks the paper singles out
//! (L1-95 CrossEntropyLoss, L2-51, L1-12 diag-matmul, Conv2D, SpMM, ...) and
//! carry `binding`s onto the real Pallas artifact families so the correctness
//! stage can run genuine numerics for them.

use crate::util::rng::Rng;

/// Operator class — drives the simulator's traffic/compute model and the
/// applicability of transformations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Dense GEMM-like: high data reuse, tensor-core eligible.
    MatMul,
    /// Convolutions: reuse class, tensor-core eligible via implicit GEMM.
    Conv,
    /// Sparse matmul: irregular access, latency-sensitive.
    SpMM,
    /// Pure elementwise / activation / scaling maps.
    Elementwise,
    /// Row/axis reductions (sum, max, mean).
    Reduction,
    /// Softmax-family: reduction + map, online-algorithm eligible.
    Softmax,
    /// Normalization layers (LayerNorm/GroupNorm/BatchNorm inference).
    Norm,
    /// Pooling / windowed ops.
    Pool,
    /// Scan / cumulative ops.
    Scan,
    /// Embedding gather / scatter.
    Embedding,
    /// L2-style multi-op fused chains.
    FusedChain,
    /// L3-style full architectures.
    FullNetwork,
}

impl OpClass {
    /// Classes whose arithmetic intensity grows with staged tiling.
    pub fn has_data_reuse(self) -> bool {
        matches!(
            self,
            OpClass::MatMul | OpClass::Conv | OpClass::FusedChain | OpClass::FullNetwork
        )
    }

    /// Classes where a single-pass online algorithm removes one input pass.
    pub fn online_eligible(self) -> bool {
        matches!(self, OpClass::Softmax | OpClass::Norm | OpClass::Reduction)
    }

    pub fn name(self) -> &'static str {
        match self {
            OpClass::MatMul => "matmul",
            OpClass::Conv => "conv",
            OpClass::SpMM => "spmm",
            OpClass::Elementwise => "elementwise",
            OpClass::Reduction => "reduction",
            OpClass::Softmax => "softmax",
            OpClass::Norm => "norm",
            OpClass::Pool => "pool",
            OpClass::Scan => "scan",
            OpClass::Embedding => "embedding",
            OpClass::FusedChain => "fused_chain",
            OpClass::FullNetwork => "full_network",
        }
    }
}

/// KernelBench level (Appendix D.1).
pub type Level = u8;

/// One KernelBench-sim task.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub level: Level,
    pub index: u32,
    pub name: String,
    pub op_class: OpClass,
    /// Useful FLOPs of the *optimal* algorithm.
    pub flops: f64,
    /// Minimum HBM traffic of the optimal single-pass algorithm (bytes).
    pub ideal_bytes: f64,
    /// Output elements (drives grid sizing).
    pub out_elems: f64,
    /// Bytes crossing each unfused stage boundary (intermediates).
    pub intermediate_bytes: f64,
    /// Number of fusable stages in the reference graph (>= 1).
    pub stages: u32,
    /// Tensor-core eligibility.
    pub tc_eligible: bool,
    /// Task difficulty in [0,1] — scales bug-injection and fix hardness.
    pub difficulty: f64,
    /// Roofline efficiency of the PyTorch reference library kernels [0,1].
    pub baseline_quality: f64,
    /// Algorithmic waste of the reference (1 = optimal; diag-matmul ~ 40x).
    pub baseline_waste: f64,
    /// Real Pallas artifact family exercised for this task (anchors only).
    pub binding: Option<&'static str>,
}

impl TaskSpec {
    pub fn id(&self) -> String {
        format!("L{}-{}", self.level, self.index)
    }

    /// Ideal arithmetic intensity (flops/byte) of the optimal algorithm.
    pub fn ideal_intensity(&self) -> f64 {
        self.flops / self.ideal_bytes.max(1.0)
    }
}

/// Seed that defines the canonical suite (fixed so every experiment sees the
/// same 250 tasks, like the fixed KernelBench release the paper evaluates).
pub const SUITE_SEED: u64 = 20_251;

/// The full Level 1–3 suite (100 + 100 + 50 tasks).
pub fn kernelbench() -> Vec<TaskSpec> {
    let mut rng = Rng::new(SUITE_SEED);
    let mut tasks = Vec::with_capacity(250);
    for i in 1..=100u32 {
        tasks.push(gen_level1(i, &mut rng));
    }
    for i in 1..=100u32 {
        tasks.push(gen_level2(i, &mut rng));
    }
    for i in 1..=50u32 {
        tasks.push(gen_level3(i, &mut rng));
    }
    tasks
}

/// The paper's stratified 10% subset D* (Appendix D.2, exact ids).
pub const DSTAR_L1: [u32; 10] = [13, 10, 16, 29, 35, 72, 7, 89, 93, 34];
pub const DSTAR_L2: [u32; 10] = [17, 19, 40, 3, 13, 21, 38, 28, 26, 34];
pub const DSTAR_L3: [u32; 5] = [5, 18, 32, 41, 21];

pub fn dstar() -> Vec<TaskSpec> {
    let all = kernelbench();
    let pick = |level: Level, ids: &[u32]| -> Vec<TaskSpec> {
        ids.iter()
            .map(|&i| {
                all.iter()
                    .find(|t| t.level == level && t.index == i)
                    .expect("D* id in suite")
                    .clone()
            })
            .collect()
    };
    let mut v = pick(1, &DSTAR_L1);
    v.extend(pick(2, &DSTAR_L2));
    v.extend(pick(3, &DSTAR_L3));
    v
}

/// Find a task by "L<level>-<index>" id.
pub fn by_id(id: &str) -> Option<TaskSpec> {
    let rest = id.strip_prefix('L')?;
    let (lvl, idx) = rest.split_once('-')?;
    let level: Level = lvl.parse().ok()?;
    let index: u32 = idx.parse().ok()?;
    kernelbench()
        .into_iter()
        .find(|t| t.level == level && t.index == index)
}

// ---------------------------------------------------------------------------
// Level 1: basic operators.
// ---------------------------------------------------------------------------

/// Anchors: (index, name, class, binding, baseline_waste).
/// L1-12 is the paper's Appendix-C diag-matmul (waste ~ materializing diag);
/// L1-95 is the Fig. 8 CrossEntropyLoss case study.
const L1_ANCHORS: &[(u32, &str, OpClass, Option<&str>, f64)] = &[
    (1, "Square_matrix_multiplication", OpClass::MatMul, Some("matmul"), 1.0),
    (3, "Batched_matrix_multiplication", OpClass::MatMul, Some("matmul"), 1.0),
    (7, "Matmul_with_small_K_dimension", OpClass::MatMul, None, 1.0),
    (12, "Matmul_with_diagonal_matrices", OpClass::MatMul, Some("diag_matmul"), 48.0),
    (24, "Softmax", OpClass::Softmax, Some("softmax"), 1.0),
    (40, "LayerNorm", OpClass::Norm, Some("layernorm"), 1.0),
    (47, "Sum_reduction_over_a_dimension", OpClass::Reduction, Some("reduce_rows"), 1.0),
    (54, "Conv2D_standard", OpClass::Conv, None, 1.0),
    (62, "SpMM_CSR", OpClass::SpMM, None, 1.0),
    (95, "CrossEntropyLoss", OpClass::Softmax, Some("cross_entropy"), 1.0),
];

fn gen_level1(index: u32, rng: &mut Rng) -> TaskSpec {
    let mut rng = rng.fork(index as u64);
    let anchor = L1_ANCHORS.iter().find(|a| a.0 == index);
    let op_class = match anchor {
        Some(a) => a.2,
        None => *rng.choice(&[
            OpClass::MatMul,
            OpClass::MatMul,
            OpClass::Conv,
            OpClass::Conv,
            OpClass::Elementwise,
            OpClass::Elementwise,
            OpClass::Elementwise,
            OpClass::Reduction,
            OpClass::Reduction,
            OpClass::Softmax,
            OpClass::Norm,
            OpClass::Pool,
            OpClass::Scan,
            OpClass::Embedding,
            OpClass::SpMM,
        ]),
    };
    let name = anchor
        .map(|a| a.1.to_string())
        .unwrap_or_else(|| format!("{}_{}", op_class.name(), index));

    // Workload scale: reuse classes are compute-rich, maps are traffic-bound.
    let (flops, bytes) = match op_class {
        OpClass::MatMul | OpClass::Conv => {
            let b = 10f64.powf(rng.range_f64(7.2, 8.6)); // 16 MB .. 400 MB
            (b * rng.range_f64(24.0, 220.0), b)
        }
        OpClass::SpMM => {
            let b = 10f64.powf(rng.range_f64(7.0, 8.2));
            (b * rng.range_f64(2.0, 8.0), b)
        }
        OpClass::Elementwise | OpClass::Pool | OpClass::Embedding => {
            let b = 10f64.powf(rng.range_f64(7.5, 9.0));
            (b * rng.range_f64(0.25, 1.5), b)
        }
        _ => {
            // reductions / softmax / norm / scan
            let b = 10f64.powf(rng.range_f64(7.3, 8.8));
            (b * rng.range_f64(0.5, 3.0), b)
        }
    };
    // ~15% of non-anchor L1 references carry algorithmic waste (the fat tail
    // of KernelBench speedups — diag-matmul-like tasks).
    let waste = match anchor {
        Some(a) => a.4,
        None => {
            if rng.chance(0.08) {
                10f64.powf(rng.range_f64(0.3, 1.4)) // 2x .. 25x
            } else {
                1.0
            }
        }
    };
    TaskSpec {
        level: 1,
        index,
        name,
        op_class,
        flops,
        ideal_bytes: bytes,
        out_elems: bytes / 8.0,
        intermediate_bytes: bytes * 0.5,
        stages: 1,
        tc_eligible: matches!(op_class, OpClass::MatMul | OpClass::Conv),
        difficulty: rng.range_f64(0.15, 0.5),
        baseline_quality: if waste > 1.0 {
            rng.range_f64(0.55, 0.8)
        } else {
            rng.range_f64(0.72, 0.95)
        },
        baseline_waste: waste,
        binding: anchor.and_then(|a| a.3),
    }
}

// ---------------------------------------------------------------------------
// Level 2: multi-step operator combinations.
// ---------------------------------------------------------------------------

/// L2-51 is the Appendix-B.1 case study (Linear + subtract-mean + GELU +
/// residual); L2-83 is the CUDA-L1 Appendix-C example; L2-14 binds the
/// elementwise chain family.
const L2_ANCHORS: &[(u32, &str, Option<&str>)] = &[
    (14, "Scale_Add_ReLU_Mul", Some("ew_chain")),
    (51, "Gemm_Subtract_GlobalAvg_GELU_ResidualAdd", Some("linear_epilogue")),
    (83, "Conv3d_GroupNorm_Min_Clamp_Dropout", None),
];

fn gen_level2(index: u32, rng: &mut Rng) -> TaskSpec {
    let mut rng = rng.fork(1_000 + index as u64);
    let anchor = L2_ANCHORS.iter().find(|a| a.0 == index);
    let stages = rng.range_usize(3, 8) as u32;
    let has_gemm = rng.chance(0.6);
    let b = 10f64.powf(rng.range_f64(7.0, 8.4));
    let flops = if has_gemm {
        b * rng.range_f64(8.0, 80.0)
    } else {
        b * rng.range_f64(0.5, 3.0)
    };
    let name = anchor.map(|a| a.1.to_string()).unwrap_or_else(|| {
        format!("fused_chain_{}ops_{}", stages, index)
    });
    TaskSpec {
        level: 2,
        index,
        name,
        op_class: OpClass::FusedChain,
        flops,
        ideal_bytes: b,
        out_elems: b / 8.0,
        // Each unfused boundary round-trips an intermediate tensor.
        intermediate_bytes: b * rng.range_f64(0.15, 0.32),
        stages,
        tc_eligible: has_gemm,
        difficulty: rng.range_f64(0.35, 0.7),
        baseline_quality: rng.range_f64(0.7, 0.92),
        baseline_waste: 1.0,
        binding: anchor.and_then(|a| a.2),
    }
}

// ---------------------------------------------------------------------------
// Level 3: full architectures.
// ---------------------------------------------------------------------------

const L3_ANCHORS: &[(u32, &str, Option<&str>)] = &[
    (1, "AlexNet", None),
    (5, "MLP_Mixer_Block", Some("mini_model")),
    (11, "VGG16", None),
    (18, "ResNet_BasicBlock_Stack", None),
    (21, "EfficientNet_MBConv", None),
    (32, "ConvLSTM_Cell", None),
    (41, "MiniGPT_Block", None),
];

fn gen_level3(index: u32, rng: &mut Rng) -> TaskSpec {
    let mut rng = rng.fork(2_000 + index as u64);
    let anchor = L3_ANCHORS.iter().find(|a| a.0 == index);
    let stages = rng.range_usize(16, 80) as u32;
    let b = 10f64.powf(rng.range_f64(7.8, 9.0));
    let flops = b * rng.range_f64(20.0, 260.0);
    let name = anchor
        .map(|a| a.1.to_string())
        .unwrap_or_else(|| format!("network_{}layers_{}", stages, index));
    TaskSpec {
        level: 3,
        index,
        name,
        op_class: OpClass::FullNetwork,
        flops,
        ideal_bytes: b,
        out_elems: b / 16.0,
        intermediate_bytes: b * rng.range_f64(0.12, 0.35),
        stages,
        tc_eligible: true,
        difficulty: rng.range_f64(0.55, 0.9),
        // Library-backed conv/matmul blocks: strong per-stage baselines, but
        // many launches (the custom-kernel win on L3 is fusion + overhead).
        baseline_quality: rng.range_f64(0.78, 0.95),
        baseline_waste: 1.0,
        binding: anchor.and_then(|a| a.2),
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_paper_shape() {
        let tasks = kernelbench();
        assert_eq!(tasks.len(), 250);
        assert_eq!(tasks.iter().filter(|t| t.level == 1).count(), 100);
        assert_eq!(tasks.iter().filter(|t| t.level == 2).count(), 100);
        assert_eq!(tasks.iter().filter(|t| t.level == 3).count(), 50);
    }

    #[test]
    fn suite_is_deterministic() {
        let a = kernelbench();
        let b = kernelbench();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.flops, y.flops);
            assert_eq!(x.baseline_waste, y.baseline_waste);
        }
    }

    #[test]
    fn anchors_present_with_bindings() {
        let t = by_id("L1-95").unwrap();
        assert_eq!(t.name, "CrossEntropyLoss");
        assert_eq!(t.binding, Some("cross_entropy"));
        let t = by_id("L2-51").unwrap();
        assert_eq!(t.binding, Some("linear_epilogue"));
        let t = by_id("L1-12").unwrap();
        assert!(t.baseline_waste > 10.0, "diag-matmul reference is wasteful");
        let t = by_id("L3-5").unwrap();
        assert_eq!(t.binding, Some("mini_model"));
        assert!(by_id("L4-1").is_none());
    }

    #[test]
    fn dstar_matches_appendix_d2() {
        let d = dstar();
        assert_eq!(d.len(), 25);
        assert_eq!(d.iter().filter(|t| t.level == 1).count(), 10);
        assert_eq!(d.iter().filter(|t| t.level == 2).count(), 10);
        assert_eq!(d.iter().filter(|t| t.level == 3).count(), 5);
        // Appendix D.2 exact membership
        assert!(d.iter().any(|t| t.level == 1 && t.index == 72));
        assert!(d.iter().any(|t| t.level == 2 && t.index == 3));
        assert!(!d.iter().any(|t| t.level == 2 && t.index == 51));
    }

    #[test]
    fn workloads_physically_sane() {
        for t in kernelbench() {
            assert!(t.flops > 0.0 && t.ideal_bytes > 0.0, "{}", t.id());
            assert!(t.stages >= 1);
            assert!(t.baseline_waste >= 1.0);
            assert!((0.0..=1.0).contains(&t.difficulty));
            assert!((0.0..=1.0).contains(&t.baseline_quality));
            assert!(t.ideal_intensity() > 0.1, "{}", t.id());
        }
    }
}
