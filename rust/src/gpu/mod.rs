//! GPU specification database.
//!
//! These are the *static GPU specifications* half of the paper's hardware
//! feedback (§2.3): architecture, peak bandwidth/compute, per-SM register and
//! shared-memory capacities, occupancy ceilings. The Judge receives them as
//! text alongside the NCU metrics; the simulator uses them as the physical
//! constants of its roofline + occupancy + stall model.
//!
//! Values are the public datasheet numbers for each part (dense, no
//! sparsity); they only need to be *relatively* right for the paper's
//! cross-GPU claims (Table 4) to be meaningful.

/// Vendor architecture generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Ampere,
    Ada,
    Hopper,
}

impl Arch {
    pub fn name(self) -> &'static str {
        match self {
            Arch::Ampere => "Ampere",
            Arch::Ada => "Ada Lovelace",
            Arch::Hopper => "Hopper",
        }
    }
}

/// Market tier (the paper distinguishes data-center vs desktop parts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    DataCenter,
    Desktop,
}

/// Static spec sheet for one GPU model.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub key: &'static str,
    pub name: &'static str,
    pub arch: Arch,
    pub tier: Tier,
    pub sms: u32,
    pub clock_ghz: f64,
    pub fp32_tflops: f64,
    /// Dense fp16/bf16 tensor-pipe TFLOPS.
    pub tensor_tflops: f64,
    pub dram_gbps: f64,
    pub l2_mb: f64,
    /// Max shared memory per SM (KiB).
    pub smem_per_sm_kb: f64,
    /// Max shared memory per block (KiB).
    pub smem_per_block_kb: f64,
    pub regs_per_sm: u32,
    pub max_warps_per_sm: u32,
    pub max_blocks_per_sm: u32,
    pub max_threads_per_block: u32,
    pub warp_size: u32,
}

impl GpuSpec {
    pub fn max_threads_per_sm(&self) -> u32 {
        self.max_warps_per_sm * self.warp_size
    }

    /// Peak DRAM bytes/cycle-second used by the metric emitter.
    pub fn dram_bytes_per_sec(&self) -> f64 {
        self.dram_gbps * 1e9
    }

    /// Cached spec sheet (perf: the Judge/Coder render this block on every
    /// optimization call — twice per round; see EXPERIMENTS.md §Perf).
    pub fn spec_sheet_cached(&self) -> &'static str {
        use std::sync::OnceLock;
        static CACHE: OnceLock<std::sync::Mutex<std::collections::HashMap<&'static str, &'static str>>> =
            OnceLock::new();
        let cache = CACHE.get_or_init(Default::default);
        let mut map = cache.lock().expect("spec-sheet cache never poisoned");
        map.entry(self.key)
            .or_insert_with(|| Box::leak(self.spec_sheet().into_boxed_str()))
    }

    /// Render the "Target GPU" block of the Judge prompt (Appendix A).
    pub fn spec_sheet(&self) -> String {
        format!(
            "GPU Name: {}\nArchitecture: {}\nDetails:\n\
             - SMs: {}\n- Boost clock: {:.2} GHz\n- FP32 peak: {:.1} TFLOPS\n\
             - Tensor peak (dense bf16): {:.1} TFLOPS\n- DRAM bandwidth: {:.0} GB/s\n\
             - L2 cache: {:.0} MiB\n- Shared memory per SM: {:.0} KiB\n\
             - Shared memory per block: {:.0} KiB\n- Registers per SM: {}\n\
             - Max warps per SM: {}\n- Max threads per block: {}",
            self.name,
            self.arch.name(),
            self.sms,
            self.clock_ghz,
            self.fp32_tflops,
            self.tensor_tflops,
            self.dram_gbps,
            self.l2_mb,
            self.smem_per_sm_kb,
            self.smem_per_block_kb,
            self.regs_per_sm,
            self.max_warps_per_sm,
            self.max_threads_per_block,
        )
    }
}

/// RTX 6000 Ada Generation — the paper's default evaluation GPU (Table 1).
pub const RTX6000_ADA: GpuSpec = GpuSpec {
    key: "rtx6000",
    name: "NVIDIA RTX 6000 Ada Generation",
    arch: Arch::Ada,
    tier: Tier::DataCenter,
    sms: 142,
    clock_ghz: 2.505,
    fp32_tflops: 91.1,
    tensor_tflops: 182.1,
    dram_gbps: 960.0,
    l2_mb: 96.0,
    smem_per_sm_kb: 100.0,
    smem_per_block_kb: 99.0,
    regs_per_sm: 65536,
    max_warps_per_sm: 48,
    max_blocks_per_sm: 24,
    max_threads_per_block: 1024,
    warp_size: 32,
};

pub const RTX4090: GpuSpec = GpuSpec {
    key: "rtx4090",
    name: "NVIDIA GeForce RTX 4090",
    arch: Arch::Ada,
    tier: Tier::Desktop,
    sms: 128,
    clock_ghz: 2.52,
    fp32_tflops: 82.6,
    tensor_tflops: 165.2,
    dram_gbps: 1008.0,
    l2_mb: 72.0,
    smem_per_sm_kb: 100.0,
    smem_per_block_kb: 99.0,
    regs_per_sm: 65536,
    max_warps_per_sm: 48,
    max_blocks_per_sm: 24,
    max_threads_per_block: 1024,
    warp_size: 32,
};

pub const RTX3090: GpuSpec = GpuSpec {
    key: "rtx3090",
    name: "NVIDIA GeForce RTX 3090",
    arch: Arch::Ampere,
    tier: Tier::Desktop,
    sms: 82,
    clock_ghz: 1.695,
    fp32_tflops: 35.6,
    tensor_tflops: 71.0,
    dram_gbps: 936.0,
    l2_mb: 6.0,
    smem_per_sm_kb: 100.0,
    smem_per_block_kb: 99.0,
    regs_per_sm: 65536,
    max_warps_per_sm: 48,
    max_blocks_per_sm: 16,
    max_threads_per_block: 1024,
    warp_size: 32,
};

pub const A100: GpuSpec = GpuSpec {
    key: "a100",
    name: "NVIDIA A100-SXM4-80GB",
    arch: Arch::Ampere,
    tier: Tier::DataCenter,
    sms: 108,
    clock_ghz: 1.41,
    fp32_tflops: 19.5,
    tensor_tflops: 312.0,
    dram_gbps: 2039.0,
    l2_mb: 40.0,
    smem_per_sm_kb: 164.0,
    smem_per_block_kb: 163.0,
    regs_per_sm: 65536,
    max_warps_per_sm: 64,
    max_blocks_per_sm: 32,
    max_threads_per_block: 1024,
    warp_size: 32,
};

pub const H100: GpuSpec = GpuSpec {
    key: "h100",
    name: "NVIDIA H100-SXM5-80GB",
    arch: Arch::Hopper,
    tier: Tier::DataCenter,
    sms: 132,
    clock_ghz: 1.98,
    fp32_tflops: 66.9,
    tensor_tflops: 989.4,
    dram_gbps: 3352.0,
    l2_mb: 50.0,
    smem_per_sm_kb: 228.0,
    smem_per_block_kb: 227.0,
    regs_per_sm: 65536,
    max_warps_per_sm: 64,
    max_blocks_per_sm: 32,
    max_threads_per_block: 1024,
    warp_size: 32,
};

/// H200 — the Kevin-32B comparison hardware (Fig. 5).
pub const H200: GpuSpec = GpuSpec {
    key: "h200",
    name: "NVIDIA H200-SXM-141GB",
    arch: Arch::Hopper,
    tier: Tier::DataCenter,
    sms: 132,
    clock_ghz: 1.98,
    fp32_tflops: 66.9,
    tensor_tflops: 989.4,
    dram_gbps: 4800.0,
    l2_mb: 50.0,
    smem_per_sm_kb: 228.0,
    smem_per_block_kb: 227.0,
    regs_per_sm: 65536,
    max_warps_per_sm: 64,
    max_blocks_per_sm: 32,
    max_threads_per_block: 1024,
    warp_size: 32,
};

pub const ALL: [&GpuSpec; 6] = [&RTX6000_ADA, &RTX4090, &RTX3090, &A100, &H100, &H200];

/// Lookup by CLI key ("rtx6000", "a100", ...).
pub fn by_key(key: &str) -> Option<&'static GpuSpec> {
    ALL.iter().copied().find(|g| g.key == key)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_covers_paper_gpus() {
        for key in ["rtx6000", "rtx4090", "rtx3090", "a100", "h200"] {
            assert!(by_key(key).is_some(), "missing {key}");
        }
        assert!(by_key("tpu-v4").is_none());
    }

    #[test]
    fn spec_sheet_mentions_key_fields() {
        let s = RTX6000_ADA.spec_sheet();
        assert!(s.contains("Ada"));
        assert!(s.contains("DRAM bandwidth: 960"));
        assert!(s.contains("Registers per SM: 65536"));
    }

    #[test]
    fn relative_ordering_sane() {
        // Datasheet sanity: H200 has the most bandwidth, A100 beats 3090 in
        // bandwidth but not fp32, Ada parts lead fp32.
        assert!(H200.dram_gbps > A100.dram_gbps);
        assert!(A100.dram_gbps > RTX3090.dram_gbps);
        assert!(A100.fp32_tflops < RTX3090.fp32_tflops);
        assert!(RTX6000_ADA.fp32_tflops > RTX4090.fp32_tflops);
    }
}
