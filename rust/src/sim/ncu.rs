//! Nsight-Compute metric emission.
//!
//! Turns simulator internals into the named metric vector the Judge reads.
//! The catalog is a ~64-metric superset of the paper's 24-metric key subset
//! (Appendix B.3, Table 8) plus the extra names appearing in the per-task
//! Top-20 tables (Tables 6–7), plus aliases and weakly-informative metrics —
//! the redundancy that "overwhelms" the full-metrics Judge (§3.6, App. B.1).
//!
//! Metrics are indexed positionally (`CATALOG[i]`), so the hot path never
//! touches strings; names only matter for prompts, reports and the
//! metric-selection pipeline output.

use crate::gpu::GpuSpec;
use crate::kernel::KernelConfig;
use crate::sim::SimOutput;
use crate::tasks::TaskSpec;
use crate::util::rng::Rng;

/// The paper's 24-metric key subset (Appendix B.3 Table 8, exact names).
pub const KEY_SUBSET: [&str; 24] = [
    "sm__cycles_active.avg",
    "sm__warps_active.avg.pct_of_peak_sustained_active",
    "launch__occupancy_limit_blocks",
    "launch__occupancy_limit_registers",
    "launch__occupancy_limit_shared_mem",
    "launch__registers_per_thread",
    "sm__inst_executed.sum",
    "sm__inst_executed_pipe_fp32.avg.pct_of_peak_sustained_active",
    "sm__inst_executed_pipe_tensor.avg.pct_of_peak_sustained_active",
    "dram__bytes_read.sum",
    "dram__bytes_write.sum",
    "dram__throughput.avg.pct_of_peak_sustained_elapsed",
    "dram__bytes.sum.per_second",
    "gpu__dram_throughput.avg.pct_of_peak_sustained_elapsed",
    "l1tex__t_sector_hit_rate.pct",
    "l1tex__throughput.avg.pct_of_peak_sustained_active",
    "lts__t_sector_hit_rate.pct",
    "lts__throughput.avg.pct_of_peak_sustained_active",
    "smsp__warp_issue_stalled_memory_dependency_per_warp_active.pct",
    "smsp__warp_issue_stalled_short_scoreboard_per_warp_active.pct",
    "smsp__warp_issue_stalled_long_scoreboard_per_warp_active.pct",
    "smsp__warp_issue_stalled_barrier_per_warp_active.pct",
    "smsp__warp_issue_stalled_branch_resolving_per_warp_active.pct",
    "smsp__sass_average_branch_targets_threads_uniform.pct",
];

/// Full catalog: the key subset first (indices 0..24), then the Tables-6/7
/// extras, aliases, and weak/noise metrics.
pub const CATALOG: [&str; 64] = [
    // 0..24 — key subset (order matches KEY_SUBSET)
    "sm__cycles_active.avg",
    "sm__warps_active.avg.pct_of_peak_sustained_active",
    "launch__occupancy_limit_blocks",
    "launch__occupancy_limit_registers",
    "launch__occupancy_limit_shared_mem",
    "launch__registers_per_thread",
    "sm__inst_executed.sum",
    "sm__inst_executed_pipe_fp32.avg.pct_of_peak_sustained_active",
    "sm__inst_executed_pipe_tensor.avg.pct_of_peak_sustained_active",
    "dram__bytes_read.sum",
    "dram__bytes_write.sum",
    "dram__throughput.avg.pct_of_peak_sustained_elapsed",
    "dram__bytes.sum.per_second",
    "gpu__dram_throughput.avg.pct_of_peak_sustained_elapsed",
    "l1tex__t_sector_hit_rate.pct",
    "l1tex__throughput.avg.pct_of_peak_sustained_active",
    "lts__t_sector_hit_rate.pct",
    "lts__throughput.avg.pct_of_peak_sustained_active",
    "smsp__warp_issue_stalled_memory_dependency_per_warp_active.pct",
    "smsp__warp_issue_stalled_short_scoreboard_per_warp_active.pct",
    "smsp__warp_issue_stalled_long_scoreboard_per_warp_active.pct",
    "smsp__warp_issue_stalled_barrier_per_warp_active.pct",
    "smsp__warp_issue_stalled_branch_resolving_per_warp_active.pct",
    "smsp__sass_average_branch_targets_threads_uniform.pct",
    // 24.. — cycles/launch extras (Tables 6-7)
    "gpc__cycles_elapsed.max",
    "gpc__cycles_elapsed.avg.per_second",
    "dram__cycles_elapsed.avg.per_second",
    "launch__grid_size",
    "launch__thread_count",
    "launch__waves_per_multiprocessor",
    "launch__shared_mem_per_block_static",
    "launch__block_size",
    // instruction aliases (collinear cluster around inst_executed)
    "smsp__inst_executed.avg",
    "smsp__inst_executed.sum",
    "smsp__inst_issued.avg",
    "smsp__inst_issued.sum",
    "sm__inst_executed.avg.per_cycle_elapsed",
    "sm__inst_executed.avg.per_cycle_active",
    "sm__inst_issued.avg.per_cycle_active",
    "sm__inst_issued.avg.pct_of_peak_sustained_active",
    "sm__instruction_throughput.avg.pct_of_peak_sustained_active",
    // issue metrics
    "smsp__issue_active.avg.pct_of_peak_sustained",
    "smsp__issue_active.avg.per_cycle_active",
    "smsp__issue_inst0.avg.pct_of_peak_sustained_active",
    "smsp__average_warp_latency_per_inst_issued.ratio",
    "smsp__average_warps_active_per_inst_executed.ratio",
    "smsp__warps_eligible.avg.per_cycle_active",
    // branch
    "smsp__inst_executed_op_branch.sum",
    "derived__smsp__inst_executed_op_branch_pct",
    // compound throughputs
    "gpu__compute_memory_request_throughput.avg.pct_of_peak_sustained_elapsed",
    "gpu__compute_memory_throughput.avg.pct_of_peak_sustained_elapsed",
    "sm__throughput.avg.pct_of_peak_sustained_elapsed",
    // shared-memory detail
    "l1tex__data_bank_conflicts_pipe_lsu.sum",
    "l1tex__data_pipe_lsu_wavefronts_mem_shared.sum",
    // sass op counts (flops aliases)
    "sm__sass_thread_inst_executed_op_fadd_pred_on.sum",
    "sm__sass_thread_inst_executed_op_ffma_pred_on.sum",
    "sm__sass_thread_inst_executed_op_fmul_pred_on.sum",
    "smsp__thread_inst_executed_per_inst_executed.ratio",
    // timing aliases
    "gpu__time_duration.sum",
    "sm__cycles_elapsed.avg",
    // weak / noise metrics (real NCU names that rarely explain runtime)
    "idc__request_cycles_active.avg.pct_of_peak_sustained_active",
    "sm__mio2rf_writeback_active.avg.pct_of_peak_sustained_active",
    "l1tex__m_xbar2l1tex_read_sectors.sum",
    "lts__t_sectors_srcunit_tex_op_read.sum",
];

pub const N_METRICS: usize = CATALOG.len();

/// Index of a metric name in the catalog.
pub fn index_of(name: &str) -> Option<usize> {
    CATALOG.iter().position(|&n| n == name)
}

/// Indices of the key subset (0..24 by construction; asserted in tests).
pub fn key_subset_indices() -> Vec<usize> {
    KEY_SUBSET.iter().map(|n| index_of(n).expect("subset names come from CATALOG")).collect()
}

/// Named metric ids used by the Judge's diagnosis rules (hot path avoids
/// string lookups).
pub mod id {
    pub const CYCLES_ACTIVE: usize = 0;
    pub const WARPS_ACTIVE_PCT: usize = 1;
    pub const OCC_LIMIT_BLOCKS: usize = 2;
    pub const OCC_LIMIT_REGISTERS: usize = 3;
    pub const OCC_LIMIT_SHARED_MEM: usize = 4;
    pub const REGISTERS_PER_THREAD: usize = 5;
    pub const INST_EXECUTED: usize = 6;
    pub const PIPE_FP32_PCT: usize = 7;
    pub const PIPE_TENSOR_PCT: usize = 8;
    pub const DRAM_BYTES_READ: usize = 9;
    pub const DRAM_BYTES_WRITE: usize = 10;
    pub const DRAM_THROUGHPUT_PCT: usize = 11;
    pub const DRAM_BYTES_PER_SEC: usize = 12;
    pub const GPU_DRAM_THROUGHPUT_PCT: usize = 13;
    pub const L1_HIT_PCT: usize = 14;
    pub const L1_THROUGHPUT_PCT: usize = 15;
    pub const L2_HIT_PCT: usize = 16;
    pub const L2_THROUGHPUT_PCT: usize = 17;
    pub const STALL_MEM_DEP_PCT: usize = 18;
    pub const STALL_SHORT_SB_PCT: usize = 19;
    pub const STALL_LONG_SB_PCT: usize = 20;
    pub const STALL_BARRIER_PCT: usize = 21;
    pub const STALL_BRANCH_PCT: usize = 22;
    pub const BRANCH_UNIFORM_PCT: usize = 23;
}

/// Profile one kernel: emit the full metric vector with NCU-like run-to-run
/// observation noise (~1.5% on dynamic counters; static launch metrics are
/// exact).
pub fn profile(
    gpu: &GpuSpec,
    task: &TaskSpec,
    cfg: &KernelConfig,
    out: &SimOutput,
    rng: &mut Rng,
) -> Vec<f64> {
    let i = &out.internals;
    // NCU profiles the custom kernel itself, not the eager remainder.
    let kt_us = i.kernel_time_us.max(1e-3);
    let kt_s = kt_us * 1e-6;
    let cycles = kt_us * gpu.clock_ghz * 1e3; // per-SM active cycles
    let warps_per_block = (cfg.block_threads / gpu.warp_size).max(1) as f64;
    let occ_pct = i.occupancy * 100.0;
    let dram_bps = i.dram_traffic / kt_s;
    let dram_pct = (dram_bps / gpu.dram_bytes_per_sec() * 100.0).min(108.0);
    // Occupancy-limit block counts per limiter (what launch__occupancy_limit_*
    // reports): how many blocks each resource alone would allow.
    let lim_blocks = gpu.max_blocks_per_sm as f64;
    let lim_regs = (gpu.regs_per_sm as f64
        / (cfg.regs_per_thread as f64 * cfg.block_threads as f64))
        .floor()
        .min(99.0);
    let lim_smem = if cfg.smem_bytes() > 0.0 {
        (gpu.smem_per_sm_kb * 1024.0 / cfg.smem_bytes()).floor().min(99.0)
    } else {
        99.0 // NCU reports a large sentinel when smem is not limiting
    };
    let inst = i.inst_executed;
    let inst_per_cycle = inst / (cycles * gpu.sms as f64).max(1.0);
    let issue_pct = i.issue_frac * 100.0;
    let branch_inst = inst * if cfg.grid_stride { 0.035 } else { 0.018 };
    let flops = task.flops * if cfg.algo_optimal { 1.0 } else { task.baseline_waste };
    let branch_uniform =
        (97.5 - 6.0 * (cfg.grid_stride as u8 as f64)
            - 5.0 * (!cfg.coalesced as u8 as f64))
            .clamp(60.0, 100.0);
    let bank_conflicts = if cfg.use_smem && !cfg.smem_padded {
        inst * 0.04
    } else {
        0.0
    };
    let smem_wavefronts = if cfg.use_smem { inst * 0.3 } else { 0.0 };
    let l1_pct = (i.l1_hit * 100.0).min(99.0);
    let l2_pct = (i.l2_hit * 100.0).min(99.0);
    let warp_latency = 1.0 / i.issue_frac.max(0.05) * 12.0;

    let mut v = vec![0.0; N_METRICS];
    v[id::CYCLES_ACTIVE] = cycles;
    v[id::WARPS_ACTIVE_PCT] = occ_pct;
    v[id::OCC_LIMIT_BLOCKS] = lim_blocks;
    v[id::OCC_LIMIT_REGISTERS] = lim_regs;
    v[id::OCC_LIMIT_SHARED_MEM] = lim_smem;
    v[id::REGISTERS_PER_THREAD] = cfg.regs_per_thread as f64;
    v[id::INST_EXECUTED] = inst;
    v[id::PIPE_FP32_PCT] = i.fp32_pipe * 100.0;
    v[id::PIPE_TENSOR_PCT] = i.tensor_pipe * 100.0;
    // Read/write mix depends on the kernel's structure (redundant passes
    // re-read; fused kernels avoid intermediate writes) — this is what keeps
    // the DRAM metric family from being perfectly collinear, as in real NCU
    // data.
    let write_frac = (0.34 - 0.05 * cfg.extra_global_passes as f64
        + 0.04 * (cfg.fused_stages == 1) as u8 as f64)
        .clamp(0.15, 0.45);
    v[id::DRAM_BYTES_READ] = i.dram_traffic * (1.0 - write_frac);
    v[id::DRAM_BYTES_WRITE] = i.dram_traffic * write_frac;
    v[id::DRAM_THROUGHPUT_PCT] = dram_pct;
    v[id::DRAM_BYTES_PER_SEC] = dram_bps;
    v[id::GPU_DRAM_THROUGHPUT_PCT] = dram_pct * 0.995;
    v[id::L1_HIT_PCT] = l1_pct;
    v[id::L1_THROUGHPUT_PCT] = (i.bw_frac * 70.0 + i.l1_hit * 25.0).min(98.0);
    v[id::L2_HIT_PCT] = l2_pct;
    v[id::L2_THROUGHPUT_PCT] = (dram_pct * 0.8 + l2_pct * 0.15).min(98.0);
    v[id::STALL_MEM_DEP_PCT] = i.stall_mem_dep * 100.0;
    v[id::STALL_SHORT_SB_PCT] = i.stall_short_sb * 100.0;
    v[id::STALL_LONG_SB_PCT] = i.stall_long_sb * 100.0;
    v[id::STALL_BARRIER_PCT] = i.stall_barrier * 100.0;
    v[id::STALL_BRANCH_PCT] = i.stall_branch * 100.0;
    v[id::BRANCH_UNIFORM_PCT] = branch_uniform;
    // extras
    let mut k = 24;
    let set = |v: &mut Vec<f64>, k: &mut usize, x: f64| {
        v[*k] = x;
        *k += 1;
    };
    set(&mut v, &mut k, cycles * 1.012); // gpc__cycles_elapsed.max
    set(&mut v, &mut k, gpu.clock_ghz * 1e9 * 0.99); // gpc cycles/sec (clock)
    set(&mut v, &mut k, gpu.dram_gbps * 1e6 / 2.0); // dram cycles/sec (const)
    set(&mut v, &mut k, i.grid_blocks); // launch__grid_size
    set(&mut v, &mut k, i.grid_blocks * cfg.block_threads as f64); // thread_count
    set(&mut v, &mut k, i.waves); // waves_per_multiprocessor
    set(&mut v, &mut k, cfg.smem_bytes()); // shared_mem_per_block_static
    set(&mut v, &mut k, cfg.block_threads as f64); // block_size
    // instruction aliases
    let smsp_inst = inst / (gpu.sms as f64 * 4.0);
    set(&mut v, &mut k, smsp_inst); // smsp inst_executed.avg
    set(&mut v, &mut k, inst); // smsp inst_executed.sum
    set(&mut v, &mut k, smsp_inst * 1.02); // smsp inst_issued.avg
    set(&mut v, &mut k, inst * 1.02); // smsp inst_issued.sum
    set(&mut v, &mut k, inst_per_cycle * 0.97); // per_cycle_elapsed
    set(&mut v, &mut k, inst_per_cycle); // per_cycle_active
    set(&mut v, &mut k, inst_per_cycle * 1.02); // issued per cycle
    set(&mut v, &mut k, issue_pct * 0.98); // issued pct of peak
    set(&mut v, &mut k, issue_pct * 0.95); // instruction_throughput pct
    // issue metrics
    set(&mut v, &mut k, issue_pct); // issue_active pct
    set(&mut v, &mut k, i.issue_frac); // issue_active per cycle
    set(&mut v, &mut k, 100.0 - issue_pct); // issue_inst0 pct
    set(&mut v, &mut k, warp_latency); // avg warp latency / inst issued
    set(&mut v, &mut k, warp_latency * 0.99); // warps active / inst executed
    set(&mut v, &mut k, (i.issue_frac * warps_per_block).min(16.0)); // eligible
    // branch
    set(&mut v, &mut k, branch_inst);
    set(&mut v, &mut k, branch_inst / inst.max(1.0) * 100.0);
    // compound throughput: max of compute/memory utilization
    let compute_pct = (i.fp32_pipe + i.tensor_pipe) * 100.0;
    set(&mut v, &mut k, dram_pct.max(compute_pct) * 0.97);
    set(&mut v, &mut k, dram_pct.max(compute_pct));
    set(&mut v, &mut k, compute_pct.max(issue_pct * 0.6));
    // shared-memory detail
    set(&mut v, &mut k, bank_conflicts);
    set(&mut v, &mut k, smem_wavefronts);
    // sass flop aliases
    set(&mut v, &mut k, flops * 0.18);
    set(&mut v, &mut k, flops * 0.41);
    set(&mut v, &mut k, flops * 0.12);
    set(&mut v, &mut k, 31.2); // threads per inst (near-constant)
    // timing aliases
    set(&mut v, &mut k, kt_us * 1e3); // gpu__time_duration.sum (ns)
    set(&mut v, &mut k, cycles * 1.006); // sm__cycles_elapsed.avg
    // weak/noise metrics
    set(&mut v, &mut k, 3.0); // idc
    set(&mut v, &mut k, 8.0); // mio2rf
    set(&mut v, &mut k, i.dram_traffic / 32.0 * 1.1); // xbar sectors alias
    set(&mut v, &mut k, i.dram_traffic * 0.68 / 32.0); // lts sectors alias
    debug_assert_eq!(k, N_METRICS);

    // Observation noise: dynamic counters wobble run to run; launch statics
    // (indices of launch__* and registers) are exact.
    const EXACT: [usize; 8] = [2, 3, 4, 5, 28, 30, 31, 27];
    for (idx, x) in v.iter_mut().enumerate() {
        if !EXACT.contains(&idx) {
            *x *= rng.lognormal_noise(0.015);
        }
    }
    v
}

/// Display adapter for the Judge-prompt metric block (name: value lines) —
/// the same bytes [`render_block`] returns, streamed without materialising
/// the block. The token accountant renders it straight into a counting
/// writer (see `agents::prompts::LenWriter`), so the per-round metric block
/// costs no allocation on the replay hot path.
#[derive(Clone, Copy)]
pub struct MetricBlock<'a> {
    pub indices: &'a [usize],
    pub values: &'a [f64],
}

impl std::fmt::Display for MetricBlock<'_> {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for &i in self.indices {
            writeln!(w, "{}: {:.4}", CATALOG[i], self.values[i])?;
        }
        Ok(())
    }
}

/// Render a metric block for the Judge prompt (name: value lines).
pub fn render_block(indices: &[usize], values: &[f64]) -> String {
    MetricBlock { indices, values }.to_string()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;
    use crate::kernel::KernelConfig;
    use crate::sim::{simulate, SimParams};
    use crate::tasks::by_id;

    #[test]
    fn catalog_well_formed() {
        assert_eq!(N_METRICS, 64);
        // key subset occupies the first 24 slots in order
        for (j, name) in KEY_SUBSET.iter().enumerate() {
            assert_eq!(index_of(name), Some(j), "{name}");
        }
        // no duplicate names
        let mut names: Vec<&str> = CATALOG.to_vec();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), N_METRICS);
    }

    #[test]
    fn profile_emits_consistent_signals() {
        let task = by_id("L1-95").unwrap();
        let gpu = &RTX6000_ADA;
        let mut cfg = KernelConfig::naive();
        cfg.syncs_per_tile = 16;
        cfg.legalize(gpu);
        let out = simulate(gpu, &task, &cfg, &SimParams::default(), 1.0);
        let mut rng = Rng::new(1);
        let v = profile(gpu, &task, &cfg, &out, &mut rng);
        assert_eq!(v.len(), N_METRICS);
        assert!(v.iter().all(|x| x.is_finite()));
        // barrier-heavy kernel shows barrier stalls
        assert!(v[id::STALL_BARRIER_PCT] > 10.0, "{}", v[id::STALL_BARRIER_PCT]);
        // registers metric is exact
        assert_eq!(v[id::REGISTERS_PER_THREAD], cfg.regs_per_thread as f64);
        // read+write split sums to ~traffic
        let t = v[id::DRAM_BYTES_READ] + v[id::DRAM_BYTES_WRITE];
        assert!((t / out.internals.dram_traffic - 1.0).abs() < 0.1);
    }

    #[test]
    fn noise_differs_across_profiles_but_statics_exact() {
        let task = by_id("L1-1").unwrap();
        let gpu = &RTX6000_ADA;
        let mut cfg = KernelConfig::naive();
        cfg.legalize(gpu);
        let out = simulate(gpu, &task, &cfg, &SimParams::default(), 1.0);
        let a = profile(gpu, &task, &cfg, &out, &mut Rng::new(1));
        let b = profile(gpu, &task, &cfg, &out, &mut Rng::new(2));
        assert_ne!(a[id::CYCLES_ACTIVE], b[id::CYCLES_ACTIVE]);
        assert_eq!(a[id::REGISTERS_PER_THREAD], b[id::REGISTERS_PER_THREAD]);
        assert_eq!(a[id::OCC_LIMIT_SHARED_MEM], b[id::OCC_LIMIT_SHARED_MEM]);
    }

    #[test]
    fn render_block_lists_names() {
        let s = render_block(&[0, 5], &vec![1.5; N_METRICS]);
        assert!(s.contains("sm__cycles_active.avg: 1.5"));
        assert!(s.contains("launch__registers_per_thread: 1.5"));
    }
}
