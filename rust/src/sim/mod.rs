//! Analytical GPU performance simulator (DESIGN.md §2).
//!
//! Substitutes for the paper's physical GPUs + Nsight Compute: given a
//! (GPU spec, task workload, kernel configuration) triple it produces a
//! latency estimate plus the internal state (occupancy, traffic, stall
//! decomposition, pipe utilizations) that `ncu` turns into the metric vector
//! the Judge reads. The model is a roofline (memory vs compute ceiling)
//! composed with an occupancy model (register/smem/block limits), a
//! warp-stall overhead model (barrier / long+short scoreboard / latency
//! hiding), launch/tail effects, and the eager-stage cost of everything the
//! custom kernel has not fused.
//!
//! The causal structure is what matters (DESIGN.md §2 table, row 3): each
//! config lever moves exactly the metrics a CUDA expert would expect, so the
//! Judge's metric-driven diagnosis loop is exercised faithfully.

pub mod ncu;

use crate::gpu::GpuSpec;
use crate::kernel::transform::Bottleneck;
use crate::kernel::KernelConfig;
use crate::tasks::TaskSpec;

/// Tunable physical constants. Defaults are calibrated once against the
/// paper's Table 1 (CudaForge + o3 one-shot rows) and then frozen for every
/// other experiment (DESIGN.md §6).
#[derive(Clone, Debug)]
pub struct SimParams {
    /// Kernel-launch latency (us) per eager stage / kernel.
    pub launch_us: f64,
    /// Baseline DRAM efficiency of an uncoalesced scalar kernel.
    pub bw_base: f64,
    /// Extra DRAM efficiency from coalescing.
    pub bw_coalesced: f64,
    /// Extra DRAM efficiency from float4 loads.
    pub bw_vec4: f64,
    /// Sector-waste multiplier for uncoalesced access.
    pub uncoalesced_waste: f64,
    /// Fraction of input re-read per redundant pass.
    pub pass_traffic: f64,
    /// DRAM efficiency of library/eager elementwise stages.
    pub eager_bw_frac: f64,
    /// Pipe efficiency of library compute stages (cuBLAS-like).
    pub lib_pipe: f64,
    /// Barrier stall cost per sync per tile.
    pub sync_cost: f64,
    /// Shared-memory bank-conflict overhead when unpadded.
    pub bank_conflict_cost: f64,
    /// PyTorch eager dispatch overhead per stage (us) — framework cost the
    /// custom kernel avoids (why one-shot kernels sometimes beat the
    /// reference on small L1 workloads).
    pub dispatch_us: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            launch_us: 4.5,
            bw_base: 0.40,
            bw_coalesced: 0.27,
            bw_vec4: 0.09,
            uncoalesced_waste: 2.6,
            pass_traffic: 0.8,
            eager_bw_frac: 0.72,
            lib_pipe: 0.62,
            sync_cost: 0.016,
            bank_conflict_cost: 0.07,
            dispatch_us: 8.0,
        }
    }
}

/// What capped occupancy (mirrors NCU's launch__occupancy_limit_*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OccLimit {
    Warps,
    Registers,
    SharedMem,
    Blocks,
}

/// Simulator internals — everything the NCU emitter needs.
#[derive(Clone, Debug)]
pub struct Internals {
    pub occupancy: f64,
    pub occ_limit: OccLimit,
    pub blocks_per_sm: f64,
    pub grid_blocks: f64,
    pub waves: f64,
    pub dram_traffic: f64,
    pub useful_bytes: f64,
    pub mem_time_us: f64,
    pub compute_time_us: f64,
    pub kernel_time_us: f64,
    pub eager_time_us: f64,
    pub launch_time_us: f64,
    pub bw_frac: f64,
    pub mem_share: f64,
    pub stall_barrier: f64,
    pub stall_long_sb: f64,
    pub stall_short_sb: f64,
    pub stall_mem_dep: f64,
    pub stall_branch: f64,
    pub l1_hit: f64,
    pub l2_hit: f64,
    pub issue_frac: f64,
    pub fp32_pipe: f64,
    pub tensor_pipe: f64,
    pub inst_executed: f64,
    pub bottleneck: Bottleneck,
}

/// Simulation result for one kernel candidate on one task + GPU.
#[derive(Clone, Debug)]
pub struct SimOutput {
    /// End-to-end task latency (custom kernel + unfused eager remainder).
    pub runtime_us: f64,
    pub internals: Internals,
}

impl SimOutput {
    pub fn bottleneck(&self) -> Bottleneck {
        self.internals.bottleneck
    }
}

fn log2f(x: f64) -> f64 {
    x.max(1.0).ln() / std::f64::consts::LN_2
}

/// Price a kernel configuration. `quality` scales the *kernel's* achieved
/// efficiency (1.0 for agent-generated kernels; `task.baseline_quality` when
/// pricing the PyTorch reference through `baseline_time`).
pub fn simulate(
    gpu: &GpuSpec,
    task: &TaskSpec,
    cfg: &KernelConfig,
    params: &SimParams,
    quality: f64,
) -> SimOutput {
    debug_assert!(cfg.is_legal(gpu), "simulate() requires a legalized config");
    let waste = if cfg.algo_optimal { 1.0 } else { task.baseline_waste };
    let work_flops = task.flops * waste;
    let work_bytes = task.ideal_bytes * waste.sqrt(); // waste moves bytes too,
                                                      // sublinearly (diag-matmul
                                                      // wastes flops more)
    let stages = task.stages as f64;
    let fused = cfg.fused_stages.min(task.stages) as f64;
    // A KernelBench submission replaces the *hot* operators first: for full
    // networks the fused portion carries a disproportionate share of the
    // work (so a slow custom kernel genuinely drags L3 tasks below 1.0x).
    let heavy = if task.op_class == crate::tasks::OpClass::FullNetwork { 3.5 } else { 1.0 };
    let frac_fused = (fused / stages * heavy).min(1.0);
    let kernel_flops = work_flops * frac_fused;
    let kernel_bytes_min = work_bytes * frac_fused;

    // ----- occupancy ------------------------------------------------------
    let warps_per_block = (cfg.block_threads / gpu.warp_size).max(1) as f64;
    let by_warps = (gpu.max_warps_per_sm as f64 / warps_per_block).floor();
    let by_regs = (gpu.regs_per_sm as f64
        / (cfg.regs_per_thread as f64 * cfg.block_threads as f64))
        .floor();
    let by_smem = if cfg.smem_bytes() > 0.0 {
        (gpu.smem_per_sm_kb * 1024.0 / cfg.smem_bytes()).floor()
    } else {
        f64::INFINITY
    };
    let by_blocks = gpu.max_blocks_per_sm as f64;
    let mut blocks_per_sm = by_warps.min(by_regs).min(by_smem).min(by_blocks);
    let occ_limit = if blocks_per_sm == by_regs && by_regs <= by_warps {
        OccLimit::Registers
    } else if blocks_per_sm == by_smem && by_smem <= by_warps {
        OccLimit::SharedMem
    } else if blocks_per_sm == by_blocks && by_blocks < by_warps {
        OccLimit::Blocks
    } else {
        OccLimit::Warps
    };
    blocks_per_sm = blocks_per_sm.max(1.0);
    let occupancy = (blocks_per_sm * warps_per_block / gpu.max_warps_per_sm as f64)
        .min(1.0);

    // ----- grid / tail ----------------------------------------------------
    let tile_elems = (cfg.tile_m as f64) * (cfg.tile_n as f64);
    let mut grid_blocks = (task.out_elems * frac_fused / tile_elems).ceil().max(1.0);
    if cfg.grid_stride {
        grid_blocks = grid_blocks.min(blocks_per_sm * gpu.sms as f64 * 8.0);
    }
    let concurrent = blocks_per_sm * gpu.sms as f64;
    let waves = grid_blocks / concurrent;
    let tail_factor = if waves >= 1.0 {
        let t = waves.ceil() / waves;
        if cfg.grid_stride {
            1.0 + (t - 1.0) * 0.25
        } else {
            t
        }
    } else {
        // Partial wave: the machine is underfilled.
        (1.0 / waves).min(6.0).max(1.0)
    };

    // ----- memory traffic -------------------------------------------------
    let passes = cfg.extra_global_passes as f64;
    let mut useful_bytes = kernel_bytes_min * (1.0 + params.pass_traffic * passes);
    // L2 absorbs part of the re-referenced traffic when the working set fits;
    // the hit rate also reflects the access pattern (coalesced bursts and
    // smem-staged tiles are L2-friendlier; redundant passes thrash).
    let l2_hit = (0.18
        + 0.55 * (gpu.l2_mb * 1e6 / kernel_bytes_min.max(1.0)).min(1.0)
        + 0.05 * (cfg.coalesced as u8 as f64)
        + 0.04 * (cfg.use_smem as u8 as f64)
        - 0.04 * (cfg.extra_global_passes.min(2) as f64))
        .clamp(0.05, 0.88);
    if task.op_class.has_data_reuse() {
        // Arithmetic intensity achievable with this staging scheme: smem tile
        // reuse (~min(tile)/2 flops per DRAM byte for f32 GEMM tiles),
        // amplified by L2 panel reuse across neighbouring blocks.
        let intensity = if cfg.use_smem {
            let t = cfg.tile_m.min(cfg.tile_n) as f64;
            (t / 2.0) * if cfg.double_buffer { 1.1 } else { 1.0 }
        } else {
            3.0 // register-only blocking
        };
        let intensity =
            (intensity * (1.0 + 2.0 * l2_hit)).min(task.ideal_intensity().max(1.0));
        useful_bytes = useful_bytes.max(kernel_flops / intensity);
    }
    let waste_mult = if cfg.coalesced {
        1.0
    } else {
        (params.uncoalesced_waste - 0.2 * cfg.vector_width as f64).max(1.6)
    };
    let dram_traffic = useful_bytes * waste_mult * (1.0 - 0.35 * l2_hit);

    // ----- memory time ----------------------------------------------------
    let vec_bonus = match cfg.vector_width {
        4 => params.bw_vec4,
        2 => params.bw_vec4 * 0.45,
        _ => 0.0,
    };
    let occ_mem = (occupancy / 0.30).powf(0.6).min(1.0);
    let bw_frac = ((params.bw_base
        + params.bw_coalesced * (cfg.coalesced as u8 as f64)
        + vec_bonus
        + 0.05 * (cfg.double_buffer as u8 as f64))
        * occ_mem)
        .min(0.94);
    let mem_time_us = dram_traffic / (gpu.dram_bytes_per_sec() * bw_frac) * 1e6;

    // ----- compute time ---------------------------------------------------
    let tc_aligned = cfg.tile_m % 16 == 0 && cfg.tile_n % 16 == 0 && cfg.tile_k % 16 == 0;
    let tc_active = cfg.use_tensor_cores && task.tc_eligible && tc_aligned;
    let peak_tflops = if tc_active { gpu.tensor_tflops } else { gpu.fp32_tflops };
    let pipe_base = if tc_active {
        0.40 + 0.20 * (cfg.use_smem as u8 as f64) + 0.08 * (cfg.double_buffer as u8 as f64)
    } else {
        0.50 + 0.08 * (cfg.use_smem as u8 as f64)
    };
    let occ_comp = (occupancy / 0.25).powf(0.5).min(1.0);
    let ilp = (0.72 + 0.09 * log2f(cfg.unroll as f64)).min(1.0);
    let pipe_eff = (pipe_base * occ_comp * ilp).min(0.90);
    let compute_time_us = kernel_flops / (peak_tflops * 1e12 * pipe_eff) * 1e6;

    // ----- stall overheads --------------------------------------------------
    let mem_share = mem_time_us / (mem_time_us + compute_time_us).max(1e-9);
    let stall_barrier = (params.sync_cost
        * cfg.syncs_per_tile as f64
        * (cfg.block_threads as f64 / 128.0).sqrt())
    .min(0.50);
    let stall_short_sb = if cfg.use_smem && !cfg.smem_padded {
        params.bank_conflict_cost
    } else if cfg.use_smem {
        0.015
    } else {
        0.005
    };
    // Long-scoreboard: global latency not hidden — driven by low occupancy on
    // the memory-bound side and by redundant passes (dependent re-reads).
    let stall_long_sb = (mem_share * ((0.55 - occupancy).max(0.0) * 1.2 + 0.10 * passes))
        .min(0.65);
    let overhead = 1.0 + stall_barrier + stall_short_sb + stall_long_sb * 0.6;

    let raw_kernel = mem_time_us.max(compute_time_us);
    let kernel_time_us = raw_kernel * overhead * tail_factor / quality.max(0.05);

    // ----- unfused eager remainder -----------------------------------------
    let eager_stages = stages - fused;
    let (eager_time_us, launch_time_us) = eager_cost(
        gpu,
        task,
        params,
        work_flops * (1.0 - frac_fused),
        work_bytes * (1.0 - frac_fused),
        eager_stages,
    );
    let launch_total = launch_time_us + params.launch_us; // + our own launch

    let runtime_us = kernel_time_us + eager_time_us + launch_total;

    // ----- bottleneck attribution ------------------------------------------
    let bottleneck = attribute_bottleneck(
        task,
        cfg,
        occupancy,
        occ_limit,
        mem_share,
        stall_barrier,
        stall_short_sb,
        stall_long_sb,
        waste_mult,
        tc_active,
        kernel_time_us,
        eager_time_us + launch_total,
        waste,
    );

    // Stall fractions normalized to "percent of active warps" style numbers.
    let stall_mem_dep = (mem_share * 0.18).min(0.4);
    let stall_branch = if cfg.grid_stride { 0.035 } else { 0.015 };
    let issue_frac = (1.0
        - (stall_barrier + stall_short_sb + stall_long_sb + stall_mem_dep + stall_branch))
        .clamp(0.05, 0.95);

    let inst_executed = kernel_flops / (2.0 * cfg.vector_width as f64)
        + useful_bytes / (4.0 * cfg.vector_width as f64);

    SimOutput {
        runtime_us,
        internals: Internals {
            occupancy,
            occ_limit,
            blocks_per_sm,
            grid_blocks,
            waves,
            dram_traffic,
            useful_bytes,
            mem_time_us,
            compute_time_us,
            kernel_time_us,
            eager_time_us,
            launch_time_us: launch_total,
            bw_frac,
            mem_share,
            stall_barrier,
            stall_long_sb,
            stall_short_sb,
            stall_mem_dep,
            stall_branch,
            l1_hit: if cfg.use_smem { 0.55 } else { 0.35 } + 0.2 * (cfg.coalesced as u8 as f64),
            l2_hit,
            issue_frac,
            fp32_pipe: if tc_active { 0.12 } else { pipe_eff * (1.0 - mem_share).max(0.08) },
            tensor_pipe: if tc_active { pipe_eff * (1.0 - mem_share).max(0.10) } else { 0.0 },
            inst_executed,
            bottleneck,
        },
    }
}

/// Cost of the stages the custom kernel did not fuse: each runs as a
/// library/eager kernel, round-tripping its intermediates through HBM.
fn eager_cost(
    gpu: &GpuSpec,
    task: &TaskSpec,
    params: &SimParams,
    work_flops: f64,
    work_bytes: f64,
    eager_stages: f64,
) -> (f64, f64) {
    if eager_stages <= 0.0 {
        return (0.0, 0.0);
    }
    let per_stage_flops = work_flops / eager_stages;
    let per_stage_bytes = work_bytes / eager_stages + 2.0 * task.intermediate_bytes;
    let peak = if task.tc_eligible { gpu.tensor_tflops } else { gpu.fp32_tflops };
    let t_mem = per_stage_bytes / (gpu.dram_bytes_per_sec() * params.eager_bw_frac) * 1e6;
    let t_comp = per_stage_flops / (peak * 1e12 * params.lib_pipe) * 1e6;
    let per_stage = t_mem.max(t_comp) / task.baseline_quality;
    // Unfused stages stay framework ops: launch latency + eager dispatch.
    (
        eager_stages * per_stage,
        eager_stages * (params.launch_us + params.dispatch_us),
    )
}

/// The PyTorch reference latency: the library configuration priced through
/// the same model (fused_stages = 1 — eager dispatch fuses nothing).
pub fn baseline_time(gpu: &GpuSpec, task: &TaskSpec, params: &SimParams) -> f64 {
    let mut cfg = library_config(task);
    cfg.legalize(gpu);
    // The reference's own "kernel" stage is a framework op too.
    simulate(gpu, task, &cfg, params, task.baseline_quality).runtime_us
        + params.dispatch_us
}

/// What a tuned vendor library kernel looks like in configuration space.
pub fn library_config(task: &TaskSpec) -> KernelConfig {
    let mut cfg = KernelConfig::naive();
    cfg.coalesced = true;
    cfg.vector_width = 4;
    cfg.unroll = 4;
    cfg.regs_per_thread = 96;
    cfg.extra_global_passes = 0;
    cfg.fused_stages = 1;
    if task.op_class.has_data_reuse() {
        cfg.use_smem = true;
        cfg.smem_padded = true;
        cfg.double_buffer = true;
        cfg.tile_m = 64;
        cfg.tile_n = 64;
        cfg.tile_k = 32;
        cfg.syncs_per_tile = 2;
    }
    if task.tc_eligible {
        cfg.use_tensor_cores = true;
        cfg.tile_m = 64;
        cfg.tile_n = 64;
        cfg.tile_k = 32;
        cfg.use_smem = true;
        cfg.smem_padded = true;
        cfg.syncs_per_tile = 2;
    }
    // The library does NOT know the task's algebraic shortcut (that is the
    // whole point of KernelBench's wasteful references like diag-matmul).
    cfg.algo_optimal = false;
    cfg
}

#[allow(clippy::too_many_arguments)]
fn attribute_bottleneck(
    task: &TaskSpec,
    cfg: &KernelConfig,
    occupancy: f64,
    occ_limit: OccLimit,
    mem_share: f64,
    stall_barrier: f64,
    stall_short_sb: f64,
    stall_long_sb: f64,
    waste_mult: f64,
    tc_active: bool,
    kernel_time: f64,
    other_time: f64,
    waste: f64,
) -> Bottleneck {
    // Priority order mirrors how an expert reads an NCU report.
    if waste > 4.0 {
        return Bottleneck::AlgorithmicWaste;
    }
    if other_time > kernel_time * 1.5 {
        return Bottleneck::LaunchOverhead;
    }
    if stall_barrier > 0.12 && stall_barrier > stall_long_sb {
        return Bottleneck::BarrierStall;
    }
    if mem_share > 0.55 {
        if waste_mult > 1.5 {
            return Bottleneck::Uncoalesced;
        }
        if occupancy < 0.45 {
            return match occ_limit {
                OccLimit::Registers => Bottleneck::OccupancyRegisters,
                OccLimit::SharedMem => Bottleneck::OccupancySmem,
                _ => Bottleneck::MemLatency,
            };
        }
        if stall_long_sb > 0.25 || cfg.extra_global_passes > 0 {
            return Bottleneck::MemLatency;
        }
        return Bottleneck::MemBandwidth;
    }
    if stall_short_sb > 0.05 {
        return Bottleneck::ShortScoreboard;
    }
    if task.tc_eligible && !tc_active {
        return Bottleneck::ComputeBound;
    }
    if occupancy < 0.30 {
        return match occ_limit {
            OccLimit::Registers => Bottleneck::OccupancyRegisters,
            OccLimit::SharedMem => Bottleneck::OccupancySmem,
            _ => Bottleneck::ComputeBound,
        };
    }
    if mem_share > 0.4 {
        Bottleneck::MemBandwidth
    } else if cfg.unroll < 8 || !tc_active {
        Bottleneck::ComputeBound
    } else {
        Bottleneck::None
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::{A100, H200, RTX3090, RTX6000_ADA};
    use crate::kernel::{Opt, OPT_CATALOG};
    use crate::tasks::{by_id, kernelbench};
    use crate::util::prop;

    fn p() -> SimParams {
        SimParams::default()
    }

    #[test]
    fn baseline_is_positive_and_finite_everywhere() {
        for t in kernelbench() {
            for gpu in [&RTX6000_ADA, &A100, &H200, &RTX3090] {
                let b = baseline_time(gpu, &t, &p());
                assert!(b.is_finite() && b > 0.0, "{} on {}", t.id(), gpu.key);
            }
        }
    }

    #[test]
    fn library_config_beats_naive() {
        // The vendor library should beat a naive kernel on essentially every
        // task (this is why o3 one-shot sits below 1.0x in Table 1).
        let tasks = kernelbench();
        let mut wins = 0;
        for t in &tasks {
            let mut naive = KernelConfig::naive();
            naive.legalize(&RTX6000_ADA);
            let tn = simulate(&RTX6000_ADA, t, &naive, &p(), 1.0).runtime_us;
            let tb = baseline_time(&RTX6000_ADA, t, &p());
            if tb < tn {
                wins += 1;
            }
        }
        assert!(wins > 200, "library won only {wins}/250");
    }

    #[test]
    fn each_transform_helps_its_target_situation() {
        let gpu = &RTX6000_ADA;
        // Coalescing on an uncoalesced memory-bound kernel.
        let t = by_id("L1-24").unwrap(); // Softmax: traffic-bound
        let mut c = KernelConfig::naive();
        c.legalize(gpu);
        let before = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        Opt::CoalesceAccesses.apply(&mut c, &t, gpu);
        let after = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        assert!(after < before * 0.8, "coalesce: {before} -> {after}");

        // Warp shuffle on a barrier-heavy kernel.
        let mut c = KernelConfig::naive();
        c.syncs_per_tile = 16;
        c.coalesced = true;
        c.legalize(gpu);
        let before = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        Opt::WarpShuffleReduction.apply(&mut c, &t, gpu);
        let after = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        assert!(after < before, "shuffle: {before} -> {after}");

        // Tensor cores + larger tiles on an eligible compute-heavy GEMM
        // (controlled task: high arithmetic intensity so compute is the wall).
        let t = TaskSpec {
            level: 1,
            index: 999,
            name: "synthetic_big_gemm".into(),
            op_class: crate::tasks::OpClass::MatMul,
            flops: 2e8 * 256.0,
            ideal_bytes: 2e8,
            out_elems: 2.5e7,
            intermediate_bytes: 1e8,
            stages: 1,
            tc_eligible: true,
            difficulty: 0.3,
            baseline_quality: 0.9,
            baseline_waste: 1.0,
            binding: None,
        };
        let mut c = KernelConfig::naive();
        c.coalesced = true;
        c.use_smem = true;
        c.tile_m = 64;
        c.tile_n = 64;
        c.tile_k = 32;
        c.syncs_per_tile = 2;
        c.legalize(gpu);
        let before = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        Opt::UseTensorCores.apply(&mut c, &t, gpu);
        let mid = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        assert!(mid <= before * 1.001, "tensor cores alone: {before} -> {mid}");
        Opt::IncreaseTileSize.apply(&mut c, &t, gpu);
        let after = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        assert!(after < before * 0.8, "tc + tiles: {before} -> {after}");

        // Fusing stages on an L2 chain.
        let t = by_id("L2-51").unwrap();
        let mut c = library_config(&t);
        c.legalize(gpu);
        let before = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        for _ in 0..(t.stages - 1) {
            Opt::FuseStages.apply(&mut c, &t, gpu);
        }
        let after = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        assert!(after < before * 0.75, "fusion: {before} -> {after}");

        // Algorithmic rewrite on the diag-matmul anchor.
        let t = by_id("L1-12").unwrap();
        let mut c = library_config(&t);
        c.legalize(gpu);
        let before = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        Opt::AlgorithmicRewrite.apply(&mut c, &t, gpu);
        let after = simulate(gpu, &t, &c, &p(), 1.0).runtime_us;
        assert!(after < before * 0.2, "algo rewrite: {before} -> {after}");
    }

    #[test]
    fn occupancy_limits_attributed() {
        let t = by_id("L1-1").unwrap();
        let gpu = &RTX6000_ADA;
        let mut c = KernelConfig::naive();
        c.regs_per_thread = 255;
        c.block_threads = 256;
        c.legalize(gpu);
        let out = simulate(gpu, &t, &c, &p(), 1.0);
        assert_eq!(out.internals.occ_limit, OccLimit::Registers);
        assert!(out.internals.occupancy < 0.5);
    }

    /// Property: runtime is finite/positive and stall fractions bounded for
    /// arbitrary legal configs on arbitrary tasks/GPUs.
    #[test]
    fn prop_simulator_sane() {
        let tasks = kernelbench();
        prop::check("sim-sane", 0x51AB, |rng| {
            let task = &tasks[rng.below(tasks.len())];
            let gpu = crate::gpu::ALL[rng.below(crate::gpu::ALL.len())];
            let mut cfg = KernelConfig::naive();
            // Random walk in config space.
            for _ in 0..rng.range_usize(0, 10) {
                let o = OPT_CATALOG[rng.below(OPT_CATALOG.len())];
                if o.applicable(task, &cfg) {
                    o.apply(&mut cfg, task, gpu);
                }
            }
            cfg.legalize(gpu);
            let out = simulate(gpu, task, &cfg, &p(), 1.0);
            let i = &out.internals;
            prop::ensure(out.runtime_us.is_finite() && out.runtime_us > 0.0, "runtime")?;
            prop::ensure((0.0..=1.0).contains(&i.occupancy), "occupancy")?;
            prop::ensure(i.dram_traffic >= 0.0, "traffic")?;
            let stalls = i.stall_barrier + i.stall_long_sb + i.stall_short_sb
                + i.stall_mem_dep + i.stall_branch;
            prop::ensure(stalls <= 1.8, format!("stall sum {stalls}"))?;
            prop::ensure((0.0..=1.0).contains(&i.issue_frac), "issue")?;
            Ok(())
        });
    }

    /// Property: the simulator is monotone in obvious levers — adding a
    /// redundant pass never speeds the kernel up; removing coalescing never
    /// speeds it up.
    #[test]
    fn prop_monotonicity() {
        let tasks = kernelbench();
        prop::check("sim-monotone", 0x0A70, |rng| {
            let task = &tasks[rng.below(tasks.len())];
            let gpu = &RTX6000_ADA;
            let mut cfg = KernelConfig::naive();
            cfg.coalesced = rng.chance(0.5);
            cfg.legalize(gpu);
            let base = simulate(gpu, task, &cfg, &p(), 1.0).runtime_us;
            let mut worse = cfg.clone();
            worse.extra_global_passes += 1;
            worse.legalize(gpu);
            let slower = simulate(gpu, task, &worse, &p(), 1.0).runtime_us;
            prop::ensure(slower >= base * 0.999, format!("pass: {base} -> {slower}"))?;
            if cfg.coalesced {
                let mut unc = cfg.clone();
                unc.coalesced = false;
                let t2 = simulate(gpu, task, &unc, &p(), 1.0).runtime_us;
                prop::ensure(t2 >= base * 0.999, format!("uncoalesce {base} -> {t2}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn h200_faster_than_rtx3090_on_bandwidth_bound() {
        let t = by_id("L1-24").unwrap();
        let mut c = library_config(&t);
        c.legalize(&H200);
        let fast = simulate(&H200, &t, &c, &p(), 1.0).runtime_us;
        let mut c2 = library_config(&t);
        c2.legalize(&RTX3090);
        let slow = simulate(&RTX3090, &t, &c2, &p(), 1.0).runtime_us;
        assert!(fast < slow, "H200 {fast} vs 3090 {slow}");
    }
}
