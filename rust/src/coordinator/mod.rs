//! Suite coordinator: runs a workflow over a task set on a thread pool and
//! aggregates the paper's evaluation metrics (§3.1): Correct, Median, 75%,
//! Perf (mean), Fast_1 — overall and per level — plus cost averages.
//!
//! Dispatch goes through `service::pool::run_indexed` (shared with the
//! service scheduler). Results are deterministic regardless of scheduling
//! because every task derives its own seed stream.

use crate::service::pool;
use crate::tasks::TaskSpec;
use crate::util::stats::{frac_above, mean, median, percentile};
use crate::workflow::{run_task, CorrectnessOracle, TaskResult, WorkflowConfig};

/// Aggregated evaluation metrics for one method over one task set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub method: String,
    pub n_tasks: usize,
    pub correct: f64,
    pub median: f64,
    pub p75: f64,
    pub perf: f64,
    pub fast1: f64,
    pub avg_cost_usd: f64,
    pub avg_time_min: f64,
}

/// Full suite outcome: per-task results + the overall and per-level rollups.
#[derive(Clone, Debug)]
pub struct SuiteOutcome {
    pub overall: Summary,
    pub per_level: Vec<(u8, Summary)>,
    pub results: Vec<TaskResult>,
}

/// Compute the paper's metrics over a slice of task results.
/// Perf/median/75% use the KernelBench convention: an incorrect task scores 0.
pub fn summarize(method: &str, results: &[TaskResult]) -> Summary {
    let perf_values: Vec<f64> = results.iter().map(|r| r.best_speedup).collect();
    let correct_frac = if results.is_empty() {
        0.0
    } else {
        results.iter().filter(|r| r.correct).count() as f64 / results.len() as f64
    };
    Summary {
        method: method.to_string(),
        n_tasks: results.len(),
        correct: correct_frac,
        median: median(&perf_values),
        p75: percentile(&perf_values, 75.0),
        perf: mean(&perf_values),
        fast1: frac_above(&perf_values, 1.0),
        avg_cost_usd: mean(&results.iter().map(|r| r.ledger.api_usd).collect::<Vec<_>>()),
        avg_time_min: mean(&results.iter().map(|r| r.ledger.wall_min()).collect::<Vec<_>>()),
    }
}

/// Run the workflow over `tasks` on `threads` workers.
pub fn run_suite(
    wf: &WorkflowConfig,
    tasks: &[TaskSpec],
    oracle: &dyn CorrectnessOracle,
    threads: usize,
) -> SuiteOutcome {
    let results: Vec<TaskResult> =
        pool::run_indexed(tasks.len(), threads, |i| run_task(wf, &tasks[i], oracle));

    let method = wf.strategy.name();
    let overall = summarize(method, &results);
    let mut per_level = Vec::new();
    for level in [1u8, 2, 3] {
        let lvl: Vec<TaskResult> =
            results.iter().filter(|r| r.level == level).cloned().collect();
        if !lvl.is_empty() {
            per_level.push((level, summarize(method, &lvl)));
        }
    }
    SuiteOutcome { overall, per_level, results }
}

/// Default worker count: physical parallelism minus headroom.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;
    use crate::tasks::dstar;
    use crate::workflow::{NoOracle, Strategy};

    #[test]
    fn suite_run_deterministic_across_thread_counts() {
        let tasks = dstar();
        let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 99);
        let a = run_suite(&wf, &tasks, &NoOracle, 1);
        let b = run_suite(&wf, &tasks, &NoOracle, 4);
        assert_eq!(a.overall.n_tasks, 25);
        assert!((a.overall.perf - b.overall.perf).abs() < 1e-12);
        assert!((a.overall.correct - b.overall.correct).abs() < 1e-12);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.task_id, y.task_id);
            assert_eq!(x.best_speedup, y.best_speedup);
        }
    }

    #[test]
    fn summary_invariants() {
        let tasks = dstar();
        let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 1);
        let out = run_suite(&wf, &tasks, &NoOracle, 4);
        let s = &out.overall;
        assert!(s.median <= s.p75 + 1e-12);
        assert!((0.0..=1.0).contains(&s.correct));
        assert!((0.0..=1.0).contains(&s.fast1));
        assert!(s.fast1 <= s.correct + 1e-12, "fast1 subset of correct");
        assert_eq!(out.per_level.iter().map(|(_, s)| s.n_tasks).sum::<usize>(), 25);
    }

    #[test]
    fn one_shot_weaker_than_cudaforge() {
        let tasks = dstar();
        let one = run_suite(
            &WorkflowConfig::cudaforge(&RTX6000_ADA, 4).with_strategy(Strategy::OneShot),
            &tasks,
            &NoOracle,
            4,
        );
        let full = run_suite(&WorkflowConfig::cudaforge(&RTX6000_ADA, 4), &tasks, &NoOracle, 4);
        assert!(full.overall.correct > one.overall.correct);
        assert!(full.overall.perf > one.overall.perf);
    }
}
