//! Experiment report generation: every table and figure of the paper's
//! evaluation section, regenerated from the simulation (DESIGN.md §5 maps
//! experiment id -> command). Each experiment prints a paper-layout ASCII
//! table and writes a CSV under `results/` for replotting.

use std::path::Path;

use crate::agents::profiles::{self, ModelProfile, O3};
use crate::coordinator::{default_threads, run_suite, summarize, Summary};
use crate::gpu::{self, GpuSpec};
use crate::metrics;
use crate::sim::SimParams;
use crate::tasks::{self, TaskSpec};
use crate::util::table::{f2, f3, pct, Table};
use crate::workflow::{CorrectnessOracle, NoOracle, Strategy, WorkflowConfig};

/// Shared experiment context.
pub struct Ctx {
    pub seed: u64,
    pub threads: usize,
    pub results_dir: String,
    pub rounds: usize,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            seed: 2024,
            threads: default_threads(),
            results_dir: "results".to_string(),
            rounds: 10,
        }
    }
}

impl Ctx {
    fn wf(&self, strategy: Strategy, gpu: &'static GpuSpec) -> WorkflowConfig {
        WorkflowConfig::cudaforge(gpu, self.seed)
            .with_strategy(strategy)
            .with_rounds(self.rounds)
    }

    fn save(&self, name: &str, t: &Table) {
        let dir = Path::new(&self.results_dir);
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{name}.csv"));
        if let Err(e) = std::fs::write(&path, t.to_csv()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        }
        println!("{}", t.render());
        println!("[csv] {}", path.display());
    }
}

fn summary_row(label: &str, s: &Summary) -> Vec<String> {
    vec![
        label.to_string(),
        pct(s.correct),
        f3(s.median),
        f3(s.p75),
        f3(s.perf),
        pct(s.fast1),
    ]
}

/// Table 1 (+ the data behind Figure 1): main results, all methods.
/// `full` runs methods marked * on D* and the rest on all 250 tasks, like
/// the paper; `quick` confines everything to D*.
pub fn table1(ctx: &Ctx, oracle: &dyn CorrectnessOracle, quick: bool) {
    let all = tasks::kernelbench();
    let dstar = tasks::dstar();
    let gpu = &gpu::RTX6000_ADA;
    let mut t = Table::new(
        "Table 1 — Main results on KernelBench (RTX 6000)",
        &["Method", "Correct", "Median", "75%", "Perf", "Fast1"],
    );
    let big: &[TaskSpec] = if quick { &dstar } else { &all };
    let runs: Vec<(&str, Strategy, &[TaskSpec])> = vec![
        ("OpenAI-o3", Strategy::OneShot, big),
        ("o3-self-refine", Strategy::SelfRefine, big),
        ("o3-correction", Strategy::CorrectionOnly, big),
        ("o3-optimization", Strategy::OptimizationOnly, big),
        ("Agentic Baseline", Strategy::AgenticBaseline, big),
        ("CudaForge(full metrics)*", Strategy::CudaForgeFullMetrics, &dstar),
        ("CudaForge", Strategy::CudaForge, big),
        ("CudaForge*", Strategy::CudaForge, &dstar),
    ];
    let mut cf_l12: Option<Summary> = None;
    for (label, strategy, set) in runs {
        let out = run_suite(&ctx.wf(strategy, gpu), set, oracle, ctx.threads);
        t.row(summary_row(label, &out.overall));
        if strategy == Strategy::CudaForge && set.len() == big.len() {
            // CudaForge(Level 1 & 2) row, per the paper.
            let l12: Vec<_> = out
                .results
                .iter()
                .filter(|r| r.level <= 2)
                .cloned()
                .collect();
            cf_l12 = Some(summarize("CudaForge(Level 1 & 2)", &l12));
        }
    }
    if let Some(s) = cf_l12 {
        t.row(summary_row("CudaForge(Level 1 & 2)", &s));
    }
    // Scaling-up row (N=30 on D*).
    let wf30 = ctx.wf(Strategy::CudaForge, gpu).with_rounds(30);
    let out = run_suite(&wf30, &dstar, oracle, ctx.threads);
    t.row(summary_row("CudaForge-Scaling Up*", &out.overall));
    ctx.save("table1", &t);
}

/// Table 2: CudaForge per level on RTX 6000 (full suite).
pub fn table2(ctx: &Ctx, oracle: &dyn CorrectnessOracle, quick: bool) {
    let all = if quick { tasks::dstar() } else { tasks::kernelbench() };
    let gpu = &gpu::RTX6000_ADA;
    let out = run_suite(&ctx.wf(Strategy::CudaForge, gpu), &all, oracle, ctx.threads);
    let mut t = Table::new(
        "Table 2 — CudaForge per level (RTX 6000)",
        &["Task", "Correct", "Median", "75%", "Perf", "Fast1"],
    );
    for (level, s) in &out.per_level {
        t.row(summary_row(&format!("Level {level}"), s));
    }
    ctx.save("table2", &t);
}

/// Table 3: API cost + wall-clock per kernel, vs the agentic baseline (D*).
pub fn table3(ctx: &Ctx, oracle: &dyn CorrectnessOracle) {
    let dstar = tasks::dstar();
    let gpu = &gpu::RTX6000_ADA;
    let mut t = Table::new(
        "Table 3 — API cost ($) and time (min) per kernel",
        &["Method", "Metric", "Average", "Level 1", "Level 2", "Level 3"],
    );
    for (label, strategy) in [
        ("Agentic Baseline", Strategy::AgenticBaseline),
        ("CudaForge", Strategy::CudaForge),
    ] {
        let out = run_suite(&ctx.wf(strategy, gpu), &dstar, oracle, ctx.threads);
        let by_level = |lvl: u8, f: &dyn Fn(&crate::workflow::TaskResult) -> f64| {
            let v: Vec<f64> =
                out.results.iter().filter(|r| r.level == lvl).map(|r| f(r)).collect();
            crate::util::stats::mean(&v)
        };
        t.row(vec![
            label.into(),
            "API Cost ($)".into(),
            f2(out.overall.avg_cost_usd),
            f2(by_level(1, &|r| r.ledger.api_usd)),
            f2(by_level(2, &|r| r.ledger.api_usd)),
            f2(by_level(3, &|r| r.ledger.api_usd)),
        ]);
        t.row(vec![
            label.into(),
            "Time (min)".into(),
            f2(out.overall.avg_time_min),
            f2(by_level(1, &|r| r.ledger.wall_min())),
            f2(by_level(2, &|r| r.ledger.wall_min())),
            f2(by_level(3, &|r| r.ledger.wall_min())),
        ]);
    }
    ctx.save("table3", &t);
}

/// Table 4: CudaForge across GPUs (D*).
pub fn table4(ctx: &Ctx, oracle: &dyn CorrectnessOracle) {
    let dstar = tasks::dstar();
    let mut t = Table::new(
        "Table 4 — CudaForge across GPUs (D*)",
        &["GPU", "Correct", "Median", "75%", "Perf", "Fast1"],
    );
    for (label, gpu) in [
        ("RTX 6000 (Ada, data center)", &gpu::RTX6000_ADA),
        ("RTX 4090 (Ada, desktop)", &gpu::RTX4090),
        ("A100 (Ampere, data center)", &gpu::A100),
        ("RTX 3090 (Ampere, desktop)", &gpu::RTX3090),
    ] {
        let out = run_suite(&ctx.wf(Strategy::CudaForge, gpu), &dstar, oracle, ctx.threads);
        t.row(summary_row(label, &out.overall));
    }
    ctx.save("table4", &t);
}

/// Table 5: base-model matrix (Coder/Judge combos) on D*.
pub fn table5(ctx: &Ctx, oracle: &dyn CorrectnessOracle) {
    let dstar = tasks::dstar();
    let gpu = &gpu::RTX6000_ADA;
    let combos: Vec<(&str, ModelProfile, ModelProfile)> = vec![
        ("O3 / O3", O3, O3),
        ("O3 / GPT-5", O3, profiles::GPT5),
        ("O3 / Claude-Sonnet-4", O3, profiles::CLAUDE_SONNET_4),
        ("O3 / GPT-OSS-120B", O3, profiles::GPT_OSS_120B),
        ("GPT-5 / O3", profiles::GPT5, O3),
        ("Claude-Sonnet-4 / O3", profiles::CLAUDE_SONNET_4, O3),
        ("GPT-OSS-120B / O3", profiles::GPT_OSS_120B, O3),
        ("QwQ / O3", profiles::QWQ_32B, O3),
    ];
    let mut t = Table::new(
        "Table 5 — Base-model combinations (Coder/Judge, D*)",
        &["Models (Coder/Judge)", "Correct", "Median", "75%", "Perf", "Fast1"],
    );
    for (label, coder, judge) in combos {
        let mut wf = ctx.wf(Strategy::CudaForge, gpu);
        wf.coder = coder;
        wf.judge = judge;
        let out = run_suite(&wf, &dstar, oracle, ctx.threads);
        t.row(summary_row(label, &out.overall));
    }
    ctx.save("table5", &t);
}

/// Figure 4: CudaForge vs Agentic Baseline per level.
pub fn fig4(ctx: &Ctx, oracle: &dyn CorrectnessOracle, quick: bool) {
    let all = if quick { tasks::dstar() } else { tasks::kernelbench() };
    let gpu = &gpu::RTX6000_ADA;
    let mut t = Table::new(
        "Figure 4 — CudaForge vs Agentic Baseline per level (RTX 6000)",
        &["Method", "Level", "Correct", "Perf"],
    );
    for (label, strategy) in [
        ("CudaForge", Strategy::CudaForge),
        ("Agentic Baseline", Strategy::AgenticBaseline),
    ] {
        let out = run_suite(&ctx.wf(strategy, gpu), &all, oracle, ctx.threads);
        for (level, s) in &out.per_level {
            t.row(vec![
                label.into(),
                format!("L{level}"),
                pct(s.correct),
                f3(s.perf),
            ]);
        }
    }
    ctx.save("fig4", &t);
}

/// Figure 5: CudaForge vs Kevin-32B on H200 per level.
pub fn fig5(ctx: &Ctx, oracle: &dyn CorrectnessOracle, quick: bool) {
    let all = if quick { tasks::dstar() } else { tasks::kernelbench() };
    let gpu = &gpu::H200;
    let mut t = Table::new(
        "Figure 5 — CudaForge vs Kevin-32B on H200",
        &["Method", "Level", "Correct", "Perf"],
    );
    for (label, strategy) in
        [("CudaForge", Strategy::CudaForge), ("Kevin-32B", Strategy::Kevin)]
    {
        let out = run_suite(&ctx.wf(strategy, gpu), &all, oracle, ctx.threads);
        for (level, s) in &out.per_level {
            t.row(vec![
                label.into(),
                format!("L{level}"),
                pct(s.correct),
                f3(s.perf),
            ]);
        }
        let l12: Vec<_> = out.results.iter().filter(|r| r.level <= 2).cloned().collect();
        let s = summarize(label, &l12);
        t.row(vec![label.into(), "L1&2".into(), pct(s.correct), f3(s.perf)]);
    }
    ctx.save("fig5", &t);
}

/// Figure 6: performance vs API cost / wall-clock (cost sweep over rounds).
pub fn fig6(ctx: &Ctx, oracle: &dyn CorrectnessOracle) {
    let dstar = tasks::dstar();
    let gpu = &gpu::RTX6000_ADA;
    let mut t = Table::new(
        "Figure 6 — Performance vs cost (CudaForge, D*)",
        &["Rounds", "API cost ($)", "Time (min)", "Perf", "Fast1"],
    );
    for n in [1usize, 2, 3, 4, 6, 8, 10, 14, 20] {
        let wf = ctx.wf(Strategy::CudaForge, gpu).with_rounds(n);
        let out = run_suite(&wf, &dstar, oracle, ctx.threads);
        t.row(vec![
            n.to_string(),
            f2(out.overall.avg_cost_usd),
            f2(out.overall.avg_time_min),
            f3(out.overall.perf),
            pct(out.overall.fast1),
        ]);
    }
    ctx.save("fig6", &t);
}

/// Figure 7: scaling max rounds N from 1 to 30 (D*).
pub fn fig7(ctx: &Ctx, oracle: &dyn CorrectnessOracle) {
    let dstar = tasks::dstar();
    let gpu = &gpu::RTX6000_ADA;
    let mut t = Table::new(
        "Figure 7 — Scaling the number of iteration rounds (D*)",
        &["N", "Correct", "Median", "Perf", "Fast1"],
    );
    for n in [1usize, 2, 4, 6, 8, 10, 15, 20, 25, 30] {
        let wf = ctx.wf(Strategy::CudaForge, gpu).with_rounds(n);
        let out = run_suite(&wf, &dstar, oracle, ctx.threads);
        t.row(vec![
            n.to_string(),
            pct(out.overall.correct),
            f3(out.overall.median),
            f3(out.overall.perf),
            pct(out.overall.fast1),
        ]);
    }
    ctx.save("fig7", &t);
}

/// Figure 8: the L1-95 CrossEntropyLoss case study — Judge outputs and
/// speedup per round.
pub fn fig8(ctx: &Ctx, oracle: &dyn CorrectnessOracle) {
    let task = tasks::by_id("L1-95").expect("case-study task");
    let gpu = &gpu::RTX6000_ADA;
    let wf = ctx.wf(Strategy::CudaForge, gpu);
    let r = crate::workflow::run_task(&wf, &task, oracle);
    let mut t = Table::new(
        "Figure 8 — Case study: L1-95 CrossEntropyLoss, round by round",
        &["Round", "Mode", "Correct", "Speedup", "Judge feedback (JSON)"],
    );
    for round in &r.rounds {
        t.row(vec![
            round.round.to_string(),
            round.mode.into(),
            if round.correct { "yes" } else { "NO" }.into(),
            round.speedup.map(f3).unwrap_or_else(|| "-".into()),
            truncate(&round.feedback_json, 94),
        ]);
    }
    ctx.save("fig8", &t);
    println!(
        "best speedup {:.3}x over PyTorch baseline ({} oracle checks ran real PJRT numerics)",
        r.best_speedup, r.oracle_checks
    );
}

/// Figure 9: full-metrics vs 24-subset Judge on L2-51, per-round speedups.
pub fn fig9(ctx: &Ctx, oracle: &dyn CorrectnessOracle) {
    let task = tasks::by_id("L2-51").expect("appendix B.1 task");
    let gpu = &gpu::RTX6000_ADA;
    let mut t = Table::new(
        "Figure 9 — Full metrics vs 24-metric subset on L2-51",
        &["Round", "Subset speedup", "Full-metrics speedup"],
    );
    let sub = crate::workflow::run_task(&ctx.wf(Strategy::CudaForge, gpu), &task, oracle);
    let full = crate::workflow::run_task(
        &ctx.wf(Strategy::CudaForgeFullMetrics, gpu),
        &task,
        oracle,
    );
    let fmt = |r: &crate::workflow::RoundLog| {
        r.speedup.map(f3).unwrap_or_else(|| "fail".to_string())
    };
    for i in 0..sub.rounds.len().max(full.rounds.len()) {
        t.row(vec![
            (i + 1).to_string(),
            sub.rounds.get(i).map(fmt).unwrap_or_default(),
            full.rounds.get(i).map(fmt).unwrap_or_default(),
        ]);
    }
    ctx.save("fig9", &t);
    println!(
        "best: subset {:.3}x vs full-metrics {:.3}x",
        sub.best_speedup, full.best_speedup
    );
}

/// Tables 6-7: per-task Top-20 Pearson metrics (Conv2D and SpMM).
pub fn table6_7(ctx: &Ctx, iterations: usize) {
    let sel = metrics::select_metrics(&gpu::RTX6000_ADA, &SimParams::default(), iterations, ctx.seed);
    for (tid, label) in [("L1-54", "table6_conv2d"), ("L1-62", "table7_spmm")] {
        let top = sel
            .per_task
            .iter()
            .find(|t| t.task_id == tid)
            .expect("representative task profiled");
        let mut t = Table::new(
            &format!("Top-20 Pearson correlation with runtime — {}", top.task_name),
            &["Metric Name", "Correlation", "Abs Correlation"],
        );
        for (name, r) in &top.ranked {
            t.row(vec![name.clone(), format!("{r:.6}"), format!("{:.6}", r.abs())]);
        }
        ctx.save(label, &t);
    }
}

/// Table 8: the selected key subset from the offline pipeline.
pub fn table8(ctx: &Ctx, iterations: usize) {
    let sel = metrics::select_metrics(&gpu::RTX6000_ADA, &SimParams::default(), iterations, ctx.seed);
    let mut t = Table::new(
        "Table 8 — Selected key metric subset (Algorithms 1-2)",
        &["#", "Metric Name", "Global score S_m", "In paper's 24?"],
    );
    for (i, (name, s)) in sel.selected.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            name.clone(),
            f3(*s),
            if crate::sim::ncu::KEY_SUBSET.contains(&name.as_str()) { "yes" } else { "no" }
                .into(),
        ]);
    }
    ctx.save("table8", &t);
    println!(
        "selected {} metrics; {} of the paper's 24 recovered by exact name",
        sel.selected.len(),
        sel.overlap_with_paper()
    );
}

/// Render a mean rounds-to-best figure; rounds are 1-based, so 0.0 can only
/// mean "no such runs ran" and renders as "-", never as instant convergence.
pub fn mean_rounds(x: f64) -> String {
    if x > 0.0 {
        f2(x)
    } else {
        "-".to_string()
    }
}

/// Service-layer replay report (the `serve` subcommand): throughput, cache
/// effectiveness, queueing-aware latency percentiles, per-priority SLO
/// attainment, admission-control shedding, and the API dollars the cache
/// saved versus serving every request cold. All numbers come from the
/// event-driven replay, where cache refills and warm-start eligibility land
/// at each flight's simulated completion instant — hit rates and warm-start
/// counts respect causality, not admission-batch boundaries.
pub fn service_table(r: &crate::service::ServiceReport) -> Table {
    let mut t = Table::new(
        "Service report — Zipf traffic replay over KernelBench-sim",
        &["Metric", "Value"],
    );
    let mut rows: Vec<(String, String)> = vec![
        ("Requests".into(), r.requests.to_string()),
        ("Workflow runs (cache misses)".into(), r.flights_run.to_string()),
        ("Cache hits".into(), r.cache_hits.to_string()),
        ("Single-flight shared".into(), r.shared.to_string()),
        ("Rejected (admission control)".into(), r.rejected.to_string()),
        ("Rate-limited (front door)".into(), r.rate_limited.to_string()),
        ("Cache evictions".into(), r.evictions.to_string()),
        ("Warm-started runs".into(), r.warm_started.to_string()),
        (
            "Warm-run correctness".into(),
            if r.warm_started == 0 {
                "-".to_string()
            } else {
                pct(r.warm_correct as f64 / r.warm_started as f64)
            },
        ),
        ("Lint short-circuits".into(), r.lint_short_circuits.to_string()),
        ("Hit rate".into(), pct(r.hit_rate)),
        ("p50 latency (min)".into(), f2(r.p50_latency_s / 60.0)),
        ("p95 latency (min)".into(), f2(r.p95_latency_s / 60.0)),
        ("p99 latency (min)".into(), f2(r.p99_latency_s / 60.0)),
        ("Mean latency (min)".into(), f2(r.mean_latency_s / 60.0)),
        ("Mean queue wait (min)".into(), f2(r.mean_queue_wait_s / 60.0)),
        ("Peak backlog depth".into(), r.peak_queue_depth.to_string()),
        ("Fleet utilization".into(), pct(r.utilization)),
        ("API spent ($)".into(), f2(r.api_usd_spent)),
        ("API saved vs cold ($)".into(), f2(r.api_usd_saved)),
        ("API cost if all-cold ($)".into(), f2(r.api_usd_cold)),
        ("Mean rounds-to-best (cold)".into(), mean_rounds(r.mean_rounds_to_best_cold)),
        ("Mean rounds-to-best (warm)".into(), mean_rounds(r.mean_rounds_to_best_warm)),
        ("Simulated GPU-hours".into(), f2(r.gpu_hours)),
        ("Requests / GPU-hour".into(), f2(r.requests_per_gpu_hour)),
    ];
    for c in &r.per_priority {
        let name = c.priority.name();
        rows.push((
            format!("{name}: p50/p95/p99 (min)"),
            format!(
                "{} / {} / {}",
                f2(c.p50_latency_s / 60.0),
                f2(c.p95_latency_s / 60.0),
                f2(c.p99_latency_s / 60.0)
            ),
        ));
        rows.push((
            format!("{name}: SLO <= {}s attainment", c.slo_target_s),
            pct(c.slo_attainment),
        ));
        rows.push((format!("{name}: requests (rejected)"), {
            format!("{} ({})", c.requests, c.rejected)
        }));
    }
    for (k, v) in rows {
        t.row(vec![k, v]);
    }
    t
}

/// Render + persist a service report like the paper experiments do.
pub fn service_report(ctx: &Ctx, r: &crate::service::ServiceReport) {
    ctx.save("service", &service_table(r));
}

/// Cluster replay report (the `cluster` subcommand): the overall
/// service-shaped aggregates, then the sharded deployment's views — per-node
/// hit rate/utilization, per-tenant SLO attainment and shed counts, and the
/// cost of the node-failure rebalance when one was simulated.
pub fn cluster_table(r: &crate::cluster::ClusterReport) -> Table {
    let o = &r.overall;
    let mut t = Table::new(
        "Cluster report — sharded multi-tenant replay",
        &["Metric", "Value"],
    );
    let mut rows: Vec<(String, String)> = vec![
        ("Nodes".into(), r.nodes.to_string()),
        ("Membership epoch".into(), r.epoch.to_string()),
        ("Requests".into(), o.requests.to_string()),
        ("Workflow runs (cache misses)".into(), o.flights_run.to_string()),
        ("Cache hits".into(), o.cache_hits.to_string()),
        ("Single-flight shared".into(), o.shared.to_string()),
        ("Rejected (all sheds)".into(), o.rejected.to_string()),
        ("Quota sheds (tenant fair-share)".into(), r.quota_shed.to_string()),
        ("Rate-limited (front door)".into(), o.rate_limited.to_string()),
        ("Hit rate".into(), pct(o.hit_rate)),
        ("Warm-started runs".into(), o.warm_started.to_string()),
        ("Cross-node warm starts".into(), r.cross_node_warm.to_string()),
        ("Lint short-circuits".into(), o.lint_short_circuits.to_string()),
        ("p50/p95/p99 latency (min)".into(), {
            format!(
                "{} / {} / {}",
                f2(o.p50_latency_s / 60.0),
                f2(o.p95_latency_s / 60.0),
                f2(o.p99_latency_s / 60.0)
            )
        }),
        ("Mean queue wait (min)".into(), f2(o.mean_queue_wait_s / 60.0)),
        ("Fleet utilization (cluster)".into(), pct(o.utilization)),
        ("API spent ($)".into(), f2(o.api_usd_spent)),
        ("API saved vs cold ($)".into(), f2(o.api_usd_saved)),
        ("Simulated GPU-hours".into(), f2(o.gpu_hours)),
        ("Node-hours (alive-node time)".into(), f2(r.node_hours)),
    ];
    for n in &r.per_node {
        rows.push((
            format!("node {}{}", n.node, if n.alive { "" } else { " (failed)" }),
            format!(
                "{} reqs | hit {} | util {} | {} flights | {} shed | {} cached",
                n.requests,
                pct(n.hit_rate),
                pct(n.utilization),
                n.flights_run,
                n.rejected,
                n.cache_entries
            ),
        ));
    }
    for tn in &r.per_tenant {
        rows.push((
            format!("tenant {} (w={})", tn.tenant, tn.weight),
            format!(
                "{} reqs ({} served) | SLO {} | p50/p95/p99 {}/{}/{}m | \
                 {} shed ({} quota, {} rate) | peak depth {}",
                tn.requests,
                tn.served,
                pct(tn.slo_attainment),
                f2(tn.p50_latency_s / 60.0),
                f2(tn.p95_latency_s / 60.0),
                f2(tn.p99_latency_s / 60.0),
                tn.rejected,
                tn.quota_shed,
                tn.throttled,
                tn.peak_queue_depth
            ),
        ));
    }
    for rb in &r.rebalances {
        let (label, detail) = match rb.kind {
            crate::cluster::RebalanceKind::NodeFailure => (
                format!("rebalance: node {} failed @{}s", rb.node, rb.at_s),
                format!(
                    "{} entries lost | {} reqs rehashed | {} re-missed flights (${} re-spent)",
                    rb.cache_entries_lost,
                    rb.rehashed_requests,
                    rb.remissed_flights,
                    f2(rb.remiss_api_usd)
                ),
            ),
            crate::cluster::RebalanceKind::NodeJoin => (
                format!("rebalance: node {} joined @{}s", rb.node, rb.at_s),
                format!(
                    "{} entries refilled ({}s transfer) | {} reqs rehashed | \
                     {} re-missed flights (${} re-spent)",
                    rb.entries_moved,
                    f2(rb.transfer_s),
                    rb.rehashed_requests,
                    rb.remissed_flights,
                    f2(rb.remiss_api_usd)
                ),
            ),
            crate::cluster::RebalanceKind::SnapshotRestore => (
                format!("rebalance: snapshot restore (was {} nodes)", rb.node),
                format!(
                    "{} entries moved ({}s transfer) | {} unplaceable",
                    rb.entries_moved,
                    f2(rb.transfer_s),
                    rb.cache_entries_lost
                ),
            ),
        };
        rows.push((label, detail));
    }
    for (k, v) in rows {
        t.row(vec![k, v]);
    }
    t
}

/// Render + persist a cluster report.
pub fn cluster_report(ctx: &Ctx, r: &crate::cluster::ClusterReport) {
    ctx.save("cluster", &cluster_table(r));
}

/// One `(policy, scenario)` cell of the autoscaling cost/SLO frontier: the
/// policy's action counts plus the full cluster report its replay produced.
pub struct FrontierRow {
    /// Autoscaling policy name (`static`, `threshold`, `target-tracking`).
    pub policy: String,
    /// Scenario name (`steady`, `diurnal`, `flash-crowd`, …).
    pub scenario: String,
    /// Join events the policy scheduled.
    pub joins: usize,
    /// Fail events the policy scheduled.
    pub fails: usize,
    /// The replay's report under this policy/scenario combination.
    pub report: crate::cluster::ClusterReport,
}

/// The autoscaling frontier (the `autoscale` subcommand): one row per
/// `(policy, scenario)` replay, ranking policies within each scenario by
/// node-hours spent against what that spend bought — per-priority SLO
/// attainment, tail latency, shed counts, and the rebalance bill the
/// policy's own churn ran up. Column glossary: `Node-hrs` is alive-node
/// time integrated over the simulated span (the fleet-sizing cost axis);
/// `Shed` counts every rejected request; `SLO int/std/batch` are the
/// per-priority attainment fractions; `Rebal $` is API spend re-incurred
/// re-running work that policy-driven failures lost (or joins had in
/// transit); `Transfer (s)` is simulated seconds of cache-entry movement
/// the policy's joins paid for.
pub fn frontier_table(rows: &[FrontierRow]) -> Table {
    use crate::service::queue::Priority;
    let mut t = Table::new(
        "Autoscale frontier — node-hours vs SLO attainment",
        &[
            "Scenario", "Policy", "Node-hrs", "Joins", "Fails", "Shed", "p99 (min)",
            "SLO int", "SLO std", "SLO batch", "Rebal $", "Transfer (s)",
        ],
    );
    let slo_of = |row: &FrontierRow, p: Priority| {
        row.report
            .overall
            .per_priority
            .iter()
            .find(|c| c.priority == p)
            .map(|c| pct(c.slo_attainment))
            .unwrap_or_else(|| "-".to_string())
    };
    // Rank within each scenario by node-hours (the cost axis), cheapest
    // first; policy name breaks exact ties so the order is total.
    let mut order: Vec<&FrontierRow> = rows.iter().collect();
    order.sort_by(|a, b| {
        a.scenario
            .cmp(&b.scenario)
            .then(a.report.node_hours.total_cmp(&b.report.node_hours))
            .then(a.policy.cmp(&b.policy))
    });
    for row in order {
        let r = &row.report;
        let rebal_usd: f64 = r.rebalances.iter().map(|rb| rb.remiss_api_usd).sum();
        let transfer_s: f64 = r.rebalances.iter().map(|rb| rb.transfer_s).sum();
        t.row(vec![
            row.scenario.clone(),
            row.policy.clone(),
            f2(r.node_hours),
            row.joins.to_string(),
            row.fails.to_string(),
            r.overall.rejected.to_string(),
            f2(r.overall.p99_latency_s / 60.0),
            slo_of(row, Priority::Interactive),
            slo_of(row, Priority::Standard),
            slo_of(row, Priority::Batch),
            f2(rebal_usd),
            f2(transfer_s),
        ]);
    }
    t
}

/// Render + persist the autoscaling frontier.
pub fn frontier_report(ctx: &Ctx, rows: &[FrontierRow]) {
    ctx.save("frontier", &frontier_table(rows));
}

/// Render an optional ratio (`-` when the denominator never existed).
fn opt_f3(x: Option<f64>) -> String {
    x.map(f3).unwrap_or_else(|| "-".to_string())
}

/// The static-analyzer scorecard (the `lint --table` subcommand): one row
/// per rule with its confusion counts against the seeded corpus's ground
/// truth — injected `Bug`s for correctness rules, the catalog's own
/// applicability guards for perf smells. `Conf` is the rule's *documented*
/// confidence, a claimed lower bound on `Precision`; the precision test in
/// `analysis` holds every firing correctness rule to it, so rule quality is
/// a regression-tested number, not a vibe.
pub fn lint_table(scores: &[crate::analysis::RuleScore]) -> Table {
    let mut t = Table::new(
        "Lint rules — precision/recall over the seeded corpus",
        &[
            "Rule", "Class", "Conf", "Fired", "TP", "FP", "Missed", "Precision",
            "Recall", "F1",
        ],
    );
    for s in scores {
        t.row(vec![
            s.rule.name().to_string(),
            s.rule.severity().name().to_string(),
            f2(s.rule.confidence()),
            s.fired.to_string(),
            s.tp.to_string(),
            s.fp.to_string(),
            s.missed.to_string(),
            opt_f3(s.precision()),
            opt_f3(s.recall()),
            opt_f3(s.f1()),
        ]);
    }
    t
}

/// Render + persist the analyzer scorecard (written to `results/lint.csv`;
/// the committed `LINT_TABLE.csv` at the repo root is this file, and CI
/// asserts the regeneration is bit-identical).
pub fn lint_report(ctx: &Ctx, scores: &[crate::analysis::RuleScore]) {
    ctx.save("lint", &lint_table(scores));
}

/// Run every experiment (the `bench --exp all` path).
pub fn run_all(ctx: &Ctx, oracle: &dyn CorrectnessOracle, quick: bool) {
    table1(ctx, oracle, quick);
    table2(ctx, oracle, quick);
    table3(ctx, oracle);
    table4(ctx, oracle);
    table5(ctx, oracle);
    fig4(ctx, oracle, quick);
    fig5(ctx, oracle, quick);
    fig6(ctx, oracle);
    fig7(ctx, oracle);
    fig8(ctx, oracle);
    fig9(ctx, oracle);
    let iters = if quick { 40 } else { 100 };
    table6_7(ctx, iters);
    table8(ctx, iters);
}

/// Dispatch by experiment id.
pub fn run_experiment(ctx: &Ctx, exp: &str, oracle: &dyn CorrectnessOracle, quick: bool) {
    match exp {
        "table1" | "fig1" => table1(ctx, oracle, quick),
        "table2" => table2(ctx, oracle, quick),
        "table3" => table3(ctx, oracle),
        "table4" => table4(ctx, oracle),
        "table5" => table5(ctx, oracle),
        "fig4" => fig4(ctx, oracle, quick),
        "fig5" => fig5(ctx, oracle, quick),
        "fig6" => fig6(ctx, oracle),
        "fig7" => fig7(ctx, oracle),
        "fig8" => fig8(ctx, oracle),
        "fig9" => fig9(ctx, oracle),
        "table6" | "table7" => table6_7(ctx, if quick { 40 } else { 100 }),
        "table8" => table8(ctx, if quick { 40 } else { 100 }),
        "all" => run_all(ctx, oracle, quick),
        other => {
            eprintln!("unknown experiment '{other}'; see DESIGN.md §5");
            let _ = NoOracle; // keep the import referenced in all cfgs
        }
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..s.char_indices().take_while(|(i, _)| *i < n).last().map(|(i, c)| i + c.len_utf8()).unwrap_or(0)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truncate_is_utf8_safe() {
        assert_eq!(truncate("hello", 10), "hello");
        let t = truncate("héllo wörld extra", 7);
        assert!(t.ends_with('…'));
        let s = "日本語テキスト";
        let _ = truncate(s, 5); // must not panic on char boundaries
    }

    #[test]
    fn fig8_runs_on_anchor() {
        let ctx = Ctx { results_dir: "/tmp/cudaforge_test_results".into(), ..Ctx::default() };
        fig8(&ctx, &NoOracle);
        assert!(Path::new("/tmp/cudaforge_test_results/fig8.csv").exists());
    }

    fn cluster_report_with_rebalances() -> crate::cluster::ClusterReport {
        use crate::cluster::{ClusterReport, RebalanceKind, RebalanceReport};
        ClusterReport {
            overall: crate::service::ServiceReport::default(),
            nodes: 3,
            epoch: 3,
            per_node: Vec::new(),
            per_tenant: Vec::new(),
            cross_node_warm: 0,
            node_hours: 12.5,
            quota_shed: 0,
            rebalances: vec![
                RebalanceReport {
                    kind: RebalanceKind::NodeFailure,
                    node: 2,
                    at_s: 1800.0,
                    cache_entries_lost: 7,
                    entries_moved: 0,
                    transfer_s: 0.0,
                    rehashed_requests: 11,
                    remissed_flights: 4,
                    remiss_api_usd: 1.25,
                },
                RebalanceReport {
                    kind: RebalanceKind::NodeJoin,
                    node: 2,
                    at_s: 5400.0,
                    cache_entries_lost: 0,
                    entries_moved: 9,
                    transfer_s: 270.0,
                    rehashed_requests: 3,
                    remissed_flights: 1,
                    remiss_api_usd: 0.3,
                },
                RebalanceReport {
                    kind: RebalanceKind::SnapshotRestore,
                    node: 4,
                    at_s: 0.0,
                    cache_entries_lost: 2,
                    entries_moved: 15,
                    transfer_s: 450.0,
                    rehashed_requests: 0,
                    remissed_flights: 0,
                    remiss_api_usd: 0.0,
                },
            ],
        }
    }

    /// Compare a rendered table against its committed golden under
    /// `tests/golden/`. `UPDATE_GOLDEN=1 cargo test` blesses the current
    /// rendering instead of comparing, for intentional format changes.
    fn assert_golden(name: &str, rendered: &str) {
        let path = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
            .join(format!("{name}.txt"));
        let bless = std::env::var("UPDATE_GOLDEN")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if bless {
            std::fs::write(&path, rendered).expect("bless golden file");
            return;
        }
        let want = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!(
                "missing golden {} ({e}); run UPDATE_GOLDEN=1 cargo test to bless",
                path.display()
            )
        });
        assert_eq!(
            rendered, want,
            "{name} drifted from tests/golden/{name}.txt; \
             run UPDATE_GOLDEN=1 cargo test to bless an intentional change"
        );
    }

    /// A fully-populated service report with hand-picked figures whose
    /// decimal renderings are unambiguous (no rounding ties).
    fn service_report_fixture() -> crate::service::ServiceReport {
        use crate::service::queue::Priority;
        crate::service::ServiceReport {
            requests: 120,
            flights_run: 48,
            cache_hits: 52,
            shared: 9,
            evictions: 3,
            rejected: 11,
            warm_started: 16,
            warm_correct: 12,
            hit_rate: 0.525,
            p50_latency_s: 720.0,
            p95_latency_s: 2400.0,
            p99_latency_s: 5400.0,
            mean_latency_s: 1080.0,
            mean_queue_wait_s: 360.0,
            peak_queue_depth: 7,
            utilization: 0.675,
            per_priority: vec![crate::service::PriorityClassReport {
                priority: Priority::Interactive,
                requests: 40,
                rejected: 4,
                p50_latency_s: 600.0,
                p95_latency_s: 1800.0,
                p99_latency_s: 3600.0,
                slo_target_s: 1800.0,
                slo_attainment: 0.925,
            }],
            api_usd_spent: 19.25,
            api_usd_saved: 30.5,
            api_usd_cold: 49.75,
            mean_rounds_to_best_cold: 6.25,
            mean_rounds_to_best_warm: 3.5,
            gpu_hours: 12.5,
            requests_per_gpu_hour: 9.6,
            lint_short_circuits: 5,
            rate_limited: 2,
        }
    }

    #[test]
    fn service_table_matches_golden() {
        assert_golden("service_table", &service_table(&service_report_fixture()).render());
    }

    #[test]
    fn cluster_table_matches_golden() {
        let mut r = cluster_report_with_rebalances();
        r.per_node.push(crate::cluster::NodeReport {
            node: 0,
            alive: true,
            requests: 60,
            cache_hits: 20,
            shared: 5,
            flights_run: 25,
            rejected: 2,
            evictions: 1,
            hit_rate: 0.45,
            utilization: 0.8,
            peak_queue_depth: 4,
            cache_entries: 12,
        });
        r.per_tenant.push(crate::cluster::TenantReport {
            tenant: "acme".into(),
            weight: 2.0,
            requests: 30,
            served: 28,
            rejected: 2,
            quota_shed: 1,
            throttled: 1,
            peak_queue_depth: 3,
            p50_latency_s: 600.0,
            p95_latency_s: 1500.0,
            p99_latency_s: 3000.0,
            slo_attainment: 0.95,
        });
        assert_golden("cluster_table", &cluster_table(&r).render());
    }

    #[test]
    fn frontier_table_matches_golden() {
        let mut cheap = cluster_report_with_rebalances();
        cheap.node_hours = 8.0;
        let rows = vec![
            FrontierRow {
                policy: "static".into(),
                scenario: "diurnal".into(),
                joins: 0,
                fails: 0,
                report: cluster_report_with_rebalances(),
            },
            FrontierRow {
                policy: "threshold".into(),
                scenario: "diurnal".into(),
                joins: 2,
                fails: 1,
                report: cheap,
            },
        ];
        assert_golden("frontier_table", &frontier_table(&rows).render());
    }

    #[test]
    fn cluster_table_renders_every_rebalance_kind_with_its_figures() {
        let rendered = cluster_table(&cluster_report_with_rebalances()).render();
        // Failure row: kind + node + instant, and the loss/re-miss figures.
        assert!(rendered.contains("rebalance: node 2 failed @1800s"), "{rendered}");
        assert!(rendered.contains("7 entries lost"), "{rendered}");
        assert!(rendered.contains("11 reqs rehashed"), "{rendered}");
        assert!(rendered.contains("4 re-missed flights ($1.25 re-spent)"), "{rendered}");
        // Join row: kind + node + instant, entries moved, transfer spend.
        assert!(rendered.contains("rebalance: node 2 joined @5400s"), "{rendered}");
        assert!(rendered.contains("9 entries refilled (270.00s transfer)"), "{rendered}");
        // Restore row: prior node count, movement, unplaceable count.
        assert!(rendered.contains("rebalance: snapshot restore (was 4 nodes)"), "{rendered}");
        assert!(rendered.contains("15 entries moved (450.00s transfer)"), "{rendered}");
        assert!(rendered.contains("2 unplaceable"), "{rendered}");
        // The new cost axis renders alongside.
        assert!(rendered.contains("Node-hours (alive-node time)"), "{rendered}");
        assert!(rendered.contains("12.50"), "{rendered}");
    }

    #[test]
    fn lint_table_renders_confusion_counts_and_dashes_silent_rules() {
        use crate::analysis::{RuleId, RuleScore};
        let fired = RuleScore { rule: RuleId::SmemRace, fired: 10, tp: 9, fp: 1, missed: 3 };
        let silent = RuleScore { rule: RuleId::WastedPasses, ..RuleScore::default() };
        let rendered = lint_table(&[fired, silent]).render();
        assert!(rendered.contains("smem-race"), "{rendered}");
        assert!(rendered.contains("0.900"), "precision 9/10: {rendered}");
        assert!(rendered.contains("0.750"), "recall 9/12: {rendered}");
        assert!(rendered.contains("0.818"), "f1: {rendered}");
        assert!(rendered.contains("wasted-passes"), "{rendered}");
        let csv = lint_table(&[silent]).to_csv();
        assert!(csv.contains("wasted-passes,warning,0.60,0,0,0,0,-,-,-"), "{csv}");
    }

    #[test]
    fn frontier_table_ranks_policies_by_node_hours_within_scenario() {
        let mut cheap = cluster_report_with_rebalances();
        cheap.node_hours = 8.0;
        let expensive = cluster_report_with_rebalances();
        let rows = vec![
            FrontierRow {
                policy: "static".into(),
                scenario: "diurnal".into(),
                joins: 0,
                fails: 0,
                report: expensive,
            },
            FrontierRow {
                policy: "threshold".into(),
                scenario: "diurnal".into(),
                joins: 2,
                fails: 1,
                report: cheap,
            },
        ];
        let t = frontier_table(&rows);
        let rendered = t.render();
        assert!(rendered.contains("Autoscale frontier"), "{rendered}");
        let threshold_at = rendered.find("threshold").expect("threshold row renders");
        let static_at = rendered.find("static").expect("static row renders");
        assert!(
            threshold_at < static_at,
            "the cheaper policy (8.0 node-hrs) ranks above the 12.5 one:\n{rendered}"
        );
        // The rebalance bill columns aggregate across the report's entries.
        assert!(rendered.contains("1.55"), "rebal $ sums remiss spend: {rendered}");
        assert!(rendered.contains("720.00"), "transfer sums transfer_s: {rendered}");
    }
}
