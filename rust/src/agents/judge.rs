//! The Judge agent — the evaluation half of the two-agent workflow (§2.2–2.3).
//!
//! Correction mode reads the error log and names the single most critical
//! defect; optimization mode reads GPU specs + the NCU metric vector, keys on
//! 3–4 critical metrics, diagnoses the dominant bottleneck and returns
//! exactly one optimization (the Appendix-A JSON schema).
//!
//! Metric scope is the paper's central ablation: in `Subset` mode the Judge
//! sees the curated 24 metrics and reads them with expert rules; in `Full`
//! mode the extra ~40 redundant/collinear signals substantially raise the
//! probability of keying on a red herring (§3.6, Appendix B.1) and triple
//! the token bill.

use crate::agents::prompts;
use crate::agents::{estimate_tokens_len, CallStats, Feedback, ModelProfile};
use crate::gpu::GpuSpec;
use crate::kernel::transform::Bottleneck;
use crate::kernel::{Bug, KernelConfig, Opt};
use crate::sim::ncu::{self, id};
use crate::tasks::TaskSpec;
use crate::util::rng::Rng;

/// Which slice of NCU metrics the Judge is shown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricMode {
    /// The offline-selected 24-metric key subset (CudaForge default).
    Subset,
    /// The entire metric set (the "CudaForge (full metrics)" ablation).
    Full,
}

#[derive(Clone, Copy, Debug)]
pub struct Judge {
    pub profile: ModelProfile,
    pub mode: MetricMode,
    /// Multiplier on diagnosis skill; the self-refine baseline uses < 1 to
    /// model the un-split cognitive load (§3.6 "Comparison with o3-self-refine").
    pub skill_scale: f64,
}

impl Judge {
    pub fn new(profile: ModelProfile, mode: MetricMode) -> Judge {
        Judge { profile, mode, skill_scale: 1.0 }
    }

    /// Degraded judge used inside self-refine (same model plays both roles —
    /// the un-split "cognitive load" of §2.1; calibrated so the self-refine
    /// ablation lands near Table 1's 1.11x).
    pub fn self_refine(profile: ModelProfile) -> Judge {
        Judge { profile, mode: MetricMode::Subset, skill_scale: 0.30 }
    }

    fn diag(&self) -> f64 {
        (self.profile.diag_skill * self.skill_scale).clamp(0.0, 1.0)
    }

    /// Correction mode (kernel failed compile or mismatch).
    pub fn correction(
        &self,
        task: &TaskSpec,
        cfg: &KernelConfig,
        error_log: &str,
        rng: &mut Rng,
    ) -> (Feedback, CallStats) {
        let stats = CallStats {
            tokens_in: estimate_tokens_len(prompts::judge_correction_len(task, cfg, error_log)),
            tokens_out: self.profile.judge_out_tokens,
        };
        // The most observable defect is the one the log points at.
        let target = cfg
            .bugs
            .iter()
            .copied()
            .max_by(|a, b| a.observability().total_cmp(&b.observability()));
        let Some(bug) = target else {
            return (Feedback::NothingFound, stats);
        };
        let p_found = self.diag() * bug.observability();
        let fb = if rng.chance(p_found) {
            Feedback::Correction {
                critical_issue: format!("{} detected in kernel body", bug.name()),
                why_it_matters: format!(
                    "causes behavioral mismatch vs PyTorch reference: {}",
                    bug.error_log()
                ),
                minimal_fix_hint: fix_hint(bug).to_string(),
                bug: Some(bug),
            }
        } else if rng.chance(0.5) {
            // Confidently wrong: names a defect that is not there.
            let wrong = *rng.choice(&crate::kernel::ALL_BUGS);
            Feedback::Correction {
                critical_issue: format!("suspected {}", wrong.name()),
                why_it_matters: "may explain the observed mismatch".into(),
                minimal_fix_hint: fix_hint(wrong).to_string(),
                bug: if wrong == bug { Some(bug) } else { Some(wrong) },
            }
        } else {
            Feedback::Correction {
                critical_issue: "output mismatch of unclear origin".into(),
                why_it_matters: "kernel output deviates beyond 1e-4".into(),
                minimal_fix_hint: "re-derive the indexing and reduction logic".into(),
                bug: None,
            }
        };
        (fb, stats)
    }

    /// Optimization mode (kernel is correct; NCU metrics available).
    pub fn optimization(
        &self,
        task: &TaskSpec,
        gpu: &GpuSpec,
        cfg: &KernelConfig,
        metrics: &[f64],
        rng: &mut Rng,
    ) -> (Feedback, CallStats) {
        let indices: Vec<usize> = match self.mode {
            MetricMode::Subset => ncu::key_subset_indices(),
            MetricMode::Full => (0..ncu::N_METRICS).collect(),
        };
        // Stream the prompt (metric block included) through the counting
        // writer: the token bill is exact, and no prompt text materialises.
        let mut tokens_in = estimate_tokens_len(prompts::judge_optimization_len(
            task,
            gpu,
            cfg,
            ncu::MetricBlock { indices: &indices, values: metrics },
        ));
        if self.mode == MetricMode::Full {
            // The real full NCU dump is ~2000 metrics; our catalog carries the
            // informative core. Account the remaining bulk as tokens (sized so
            // ten full-metrics rounds land near the paper's ~$1/kernel).
            tokens_in += 35_000.0;
        }
        let stats = CallStats { tokens_in, tokens_out: self.profile.judge_out_tokens };

        // Distraction: with the full dump the Judge keys on redundant or
        // misleading signals far more often (Appendix B.1 case study).
        let mut p_distract = match self.mode {
            MetricMode::Subset => 0.06 + (1.0 - self.diag()) * 0.55,
            MetricMode::Full => 0.55 + (1.0 - self.diag()) * 0.40,
        };
        if self.skill_scale < 1.0 {
            // Self-refinement: the generator grading its own work anchors on
            // its generation rationale instead of the metrics (§3.6).
            p_distract += 0.30;
        }
        if rng.chance(p_distract) {
            return (self.distracted_feedback(task, cfg, metrics, rng), stats);
        }

        let (bneck, critical) = diagnose(task, cfg, metrics, self.diag(), rng);
        let Some(bneck) = bneck else {
            return (Feedback::NothingFound, stats);
        };
        let candidates: Vec<Opt> = Opt::for_bottleneck(bneck)
            .into_iter()
            .filter(|o| o.applicable(task, cfg))
            .collect();
        let Some(&opt) = candidates.first() else {
            // Diagnosis has no applicable move left; fall back to any move.
            return match crate::agents::coder::random_applicable(task, cfg, rng) {
                Some(o) => (self.opt_feedback(o, bneck, metrics, critical), stats),
                None => (Feedback::NothingFound, stats),
            };
        };
        // Mild exploration across equivalent moves.
        let opt = if candidates.len() > 1 && rng.chance(0.25) {
            candidates[rng.below(candidates.len())]
        } else {
            opt
        };
        (self.opt_feedback(opt, bneck, metrics, critical), stats)
    }

    fn opt_feedback(
        &self,
        opt: Opt,
        bneck: Bottleneck,
        metrics: &[f64],
        critical: Vec<usize>,
    ) -> Feedback {
        let lead = critical.first().copied().unwrap_or(id::DRAM_THROUGHPUT_PCT);
        Feedback::Optimization {
            bottleneck: format!(
                "{} ({} = {:.1})",
                bneck.name(),
                ncu::CATALOG[lead],
                metrics[lead]
            ),
            method: opt.suggestion().to_string(),
            plan: format!("apply {} and re-profile", opt.name()),
            opt: Some(opt),
            critical_metrics: critical
                .iter()
                .take(4)
                .map(|&i| ncu::CATALOG[i].to_string())
                .collect(),
        }
    }

    /// A distracted Judge keys on a random metric and proposes a move that
    /// does not address the real limiter (often monolithic rewrites — the
    /// Appendix-B.1 failure signature).
    fn distracted_feedback(
        &self,
        task: &TaskSpec,
        cfg: &KernelConfig,
        metrics: &[f64],
        rng: &mut Rng,
    ) -> Feedback {
        let i = rng.below(ncu::N_METRICS);
        // Half the time the distracted Judge gives vague monolithic-rewrite
        // advice with no actionable move at all (the misaligned CUTLASS-
        // epilogue suggestion of Appendix B.1); otherwise it names a move
        // unrelated to the real limiter.
        let opt = if rng.chance(0.5) {
            None
        } else {
            crate::agents::coder::random_applicable(task, cfg, rng)
        };
        Feedback::Optimization {
            bottleneck: format!(
                "{} = {:.1} looks anomalous; suspect it dominates cycles",
                ncu::CATALOG[i],
                metrics[i]
            ),
            method: opt
                .map(|o| o.suggestion().to_string())
                .unwrap_or_else(|| "restructure the kernel around a monolithic \
                     CUTLASS epilogue".to_string()),
            plan: "rewrite and re-profile".into(),
            opt,
            critical_metrics: vec![ncu::CATALOG[i].to_string()],
        }
    }
}

/// Expert metric reading: map the (noisy) NCU vector to a bottleneck.
/// This is the Judge's own inference from observables — intentionally a
/// different code path from the simulator's internal attribution, so the
/// Judge can be wrong the way a human can.
fn diagnose(
    task: &TaskSpec,
    cfg: &KernelConfig,
    m: &[f64],
    diag_skill: f64,
    rng: &mut Rng,
) -> (Option<Bottleneck>, Vec<usize>) {
    let occ = m[id::WARPS_ACTIVE_PCT];
    let dram = m[id::DRAM_THROUGHPUT_PCT];
    let barrier = m[id::STALL_BARRIER_PCT];
    let long_sb = m[id::STALL_LONG_SB_PCT];
    let short_sb = m[id::STALL_SHORT_SB_PCT];
    let l1 = m[id::L1_HIT_PCT];
    let fp32 = m[id::PIPE_FP32_PCT];
    let tensor = m[id::PIPE_TENSOR_PCT];
    let lim_regs = m[id::OCC_LIMIT_REGISTERS];
    let lim_smem = m[id::OCC_LIMIT_SHARED_MEM];
    let regs = m[id::REGISTERS_PER_THREAD];

    let mut scored: Vec<(Bottleneck, f64, Vec<usize>)> = Vec::with_capacity(8);
    if barrier > 10.0 {
        scored.push((
            Bottleneck::BarrierStall,
            barrier / 100.0 + 0.15,
            vec![id::STALL_BARRIER_PCT, id::WARPS_ACTIVE_PCT, id::CYCLES_ACTIVE],
        ));
    }
    if dram > 55.0 && l1 < 48.0 {
        scored.push((
            Bottleneck::Uncoalesced,
            dram / 110.0 + (48.0 - l1) / 100.0,
            vec![id::DRAM_THROUGHPUT_PCT, id::L1_HIT_PCT, id::DRAM_BYTES_PER_SEC],
        ));
    }
    if long_sb > 25.0 {
        scored.push((
            Bottleneck::MemLatency,
            long_sb / 100.0,
            vec![id::STALL_LONG_SB_PCT, id::WARPS_ACTIVE_PCT, id::DRAM_BYTES_READ],
        ));
    }
    if occ < 45.0 && lim_regs <= 3.0 && regs > 64.0 {
        scored.push((
            Bottleneck::OccupancyRegisters,
            (45.0 - occ) / 60.0 + 0.2,
            vec![id::OCC_LIMIT_REGISTERS, id::REGISTERS_PER_THREAD, id::WARPS_ACTIVE_PCT],
        ));
    }
    if occ < 45.0 && lim_smem <= 3.0 {
        scored.push((
            Bottleneck::OccupancySmem,
            (45.0 - occ) / 60.0 + 0.15,
            vec![id::OCC_LIMIT_SHARED_MEM, id::WARPS_ACTIVE_PCT],
        ));
    }
    if dram > 72.0 {
        scored.push((
            Bottleneck::MemBandwidth,
            dram / 120.0,
            vec![id::DRAM_THROUGHPUT_PCT, id::DRAM_BYTES_PER_SEC, id::GPU_DRAM_THROUGHPUT_PCT],
        ));
    }
    if short_sb > 5.0 {
        scored.push((
            Bottleneck::ShortScoreboard,
            short_sb / 60.0 + 0.05,
            vec![id::STALL_SHORT_SB_PCT, id::L1_THROUGHPUT_PCT],
        ));
    }
    if task.tc_eligible && tensor < 5.0 && fp32 > 35.0 && dram < 70.0 {
        scored.push((
            Bottleneck::ComputeBound,
            fp32 / 110.0 + 0.1,
            vec![id::PIPE_FP32_PCT, id::PIPE_TENSOR_PCT, id::DRAM_THROUGHPUT_PCT],
        ));
    }
    // Structural reads of the candidate code (not NCU): unfused stages and
    // algorithmic waste. These are "insight" diagnoses — harder, gated on
    // skill.
    if cfg.fused_stages < task.stages && rng.chance(0.35 + 0.45 * diag_skill) {
        let unfused = (task.stages - cfg.fused_stages) as f64 / task.stages as f64;
        scored.push((
            Bottleneck::LaunchOverhead,
            0.25 + 0.35 * unfused,
            vec![id::CYCLES_ACTIVE, id::DRAM_BYTES_WRITE],
        ));
    }
    if task.baseline_waste > 1.0 && !cfg.algo_optimal && rng.chance(0.22 * diag_skill) {
        scored.push((
            Bottleneck::AlgorithmicWaste,
            0.9,
            vec![id::INST_EXECUTED, id::DRAM_BYTES_READ],
        ));
    }

    scored.sort_by(|a, b| b.1.total_cmp(&a.1));
    match scored.into_iter().next() {
        Some((b, _, crit)) => (Some(b), crit),
        None => (None, vec![id::DRAM_THROUGHPUT_PCT]),
    }
}

fn fix_hint(bug: Bug) -> &'static str {
    match bug {
        Bug::CompileMissingHeader => "add the missing #include / intrinsic header",
        Bug::CompileSyntax => "fix the syntax error near the kernel body",
        Bug::CompileWrongApi => "match the extension signature to the call site",
        Bug::LaunchMisconfig => "recompute grid/block dims from the output shape",
        Bug::RaceCondition => "add __syncthreads() between smem write and read",
        Bug::OobIndex => "guard the tail tile with a bounds check",
        Bug::UninitValue => "broadcast the initialized value to all lanes",
        Bug::WrongConstant => "restore the reference constant (check literature value)",
        Bug::WrongAxis => "reduce over the feature axis, not the batch axis",
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::agents::profiles::O3;
    use crate::gpu::RTX6000_ADA;
    use crate::sim::{simulate, SimParams};
    use crate::tasks::by_id;

    fn profile_for(cfg: &KernelConfig, task: &TaskSpec, rng: &mut Rng) -> Vec<f64> {
        let out = simulate(&RTX6000_ADA, task, cfg, &SimParams::default(), 1.0);
        ncu::profile(&RTX6000_ADA, task, cfg, &out, rng)
    }

    #[test]
    fn correction_names_the_planted_bug_mostly() {
        let t = by_id("L1-95").unwrap();
        let judge = Judge::new(O3, MetricMode::Subset);
        let mut rng = Rng::new(11);
        let mut named = 0;
        for _ in 0..300 {
            let mut cfg = KernelConfig::naive();
            cfg.bugs.push(Bug::CompileSyntax);
            let (fb, _) = judge.correction(&t, &cfg, Bug::CompileSyntax.error_log(), &mut rng);
            if matches!(fb, Feedback::Correction { bug: Some(Bug::CompileSyntax), .. }) {
                named += 1;
            }
        }
        let rate = named as f64 / 300.0;
        assert!(rate > 0.70, "named rate {rate}"); // diag 0.84 * obs 0.98
    }

    #[test]
    fn barrier_heavy_kernel_gets_shuffle_suggestion() {
        // The Fig. 8 round-2 situation: 16 syncs per block.
        let t = by_id("L1-95").unwrap();
        let judge = Judge::new(O3, MetricMode::Subset);
        let mut cfg = KernelConfig::naive();
        cfg.coalesced = true;
        cfg.syncs_per_tile = 16;
        cfg.legalize(&RTX6000_ADA);
        let mut rng = Rng::new(5);
        let mut shuffle = 0;
        for _ in 0..100 {
            let m = profile_for(&cfg, &t, &mut rng);
            let (fb, _) = judge.optimization(&t, &RTX6000_ADA, &cfg, &m, &mut rng);
            if let Feedback::Optimization { opt: Some(o), critical_metrics, .. } = fb {
                if o == Opt::WarpShuffleReduction || o == Opt::ReduceSyncs {
                    shuffle += 1;
                    assert!(!critical_metrics.is_empty());
                }
            }
        }
        assert!(shuffle > 55, "barrier move suggested {shuffle}/100");
    }

    #[test]
    fn full_metrics_mode_distracts_more_and_costs_more() {
        let t = by_id("L2-51").unwrap();
        let mut cfg = KernelConfig::naive();
        cfg.coalesced = true;
        cfg.legalize(&RTX6000_ADA);
        let subset = Judge::new(O3, MetricMode::Subset);
        let full = Judge::new(O3, MetricMode::Full);
        let mut rng = Rng::new(9);
        let aligned = |j: &Judge, rng: &mut Rng| {
            let mut hits = 0;
            let mut cost_in = 0.0;
            for _ in 0..200 {
                let m = profile_for(&cfg, &t, rng);
                let (fb, st) = j.optimization(&t, &RTX6000_ADA, &cfg, &m, rng);
                cost_in += st.tokens_in;
                if let Feedback::Optimization { opt: Some(o), .. } = fb {
                    // "aligned" = the move addresses the sim's own attribution
                    let out = simulate(&RTX6000_ADA, &t, &cfg, &SimParams::default(), 1.0);
                    if o.target() == out.internals.bottleneck {
                        hits += 1;
                    }
                }
            }
            (hits, cost_in / 200.0)
        };
        let (hit_sub, tok_sub) = aligned(&subset, &mut rng);
        let (hit_full, tok_full) = aligned(&full, &mut rng);
        assert!(
            hit_sub > hit_full + 20,
            "subset {hit_sub} vs full {hit_full} aligned diagnoses"
        );
        assert!(tok_full > tok_sub * 2.0, "{tok_full} vs {tok_sub}");
    }

    #[test]
    fn self_refine_judge_is_weaker() {
        let j = Judge::self_refine(O3);
        assert!(j.diag() < Judge::new(O3, MetricMode::Subset).diag());
    }
}
