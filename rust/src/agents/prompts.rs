//! Prompt templates — the paper's Appendix A, verbatim structure.
//!
//! Rendering real prompt text serves two purposes: (i) the cost model counts
//! tokens off the actual strings (Table 3 / Fig 6), and (ii) the case-study
//! outputs (Fig 8) display the same artifacts a user of the original system
//! would see. The behavioural agents *parse nothing from these strings* —
//! they receive structured state — but every call renders and accounts them,
//! exactly like the original system pays for them.

use crate::gpu::GpuSpec;
use crate::kernel::KernelConfig;
use crate::tasks::TaskSpec;

/// The one-shot demonstration pair (KernelBench's few-shot example: a
/// PyTorch module and its custom-CUDA rewrite). Abbreviated but realistic
/// in size so token accounting stays honest.
const FEW_BASE: &str = "\
import torch\nimport torch.nn as nn\n\nclass Model(nn.Module):\n    \
def __init__(self):\n        super().__init__()\n\n    \
def forward(self, a, b):\n        return a + b\n";

const FEW_NEW: &str = "\
import torch\nimport torch.nn as nn\nfrom torch.utils.cpp_extension import \
load_inline\n\nsource = '''\n__global__ void add_kernel(const float* a, const \
float* b, float* out, int n) {\n  int i = blockIdx.x * blockDim.x + \
threadIdx.x;\n  if (i < n) out[i] = a[i] + b[i];\n}\ntorch::Tensor add_cuda(\
torch::Tensor a, torch::Tensor b) {\n  auto out = torch::empty_like(a);\n  \
int n = a.numel();\n  add_kernel<<<(n+255)/256, 256>>>(a.data_ptr<float>(), \
b.data_ptr<float>(), out.data_ptr<float>(), n);\n  return out;\n}\n'''\n\n\
cpp_src = 'torch::Tensor add_cuda(torch::Tensor a, torch::Tensor b);'\n\
add_mod = load_inline(name='add', cpp_sources=cpp_src, cuda_sources=source,\n\
                      functions=['add_cuda'])\n\nclass ModelNew(nn.Module):\n    \
def forward(self, a, b):\n        return add_mod.add_cuda(a, b)\n";

/// One-shot baseline prompt for the first generation (KernelBench's
/// one-shot prompt, per Appendix A.1).
pub fn coder_initial(task: &TaskSpec) -> String {
    format!(
        "You write custom CUDA kernels to replace the PyTorch operators in the \
         given architecture to get speedups. You have complete freedom to choose \
         the set of operators you want to replace. Consider operator fusion \
         opportunities (combining multiple operators into a single kernel, for \
         example, combining matmul+relu), or algorithmic changes (such as online \
         softmax). You are only limited by your imagination.\n\n\
         The example given architecture is:\n{FEW_BASE}\n\n\
         The example new architecture with custom CUDA kernels looks like \
         this:\n{FEW_NEW}\n\n\
         You are given the following architecture:\n{arch}\n\n\
         Optimize the architecture named Model with custom CUDA operators! Name \
         your optimized output architecture ModelNew. Output the new code in \
         code blocks. Please generate real code, NOT pseudocode. Make sure the \
         code compiles and is fully functional. Just output the new model code, \
         no other text, and NO testing code!",
        arch = arch_src(task),
    )
}

/// Warm-start adaptation prompt (service layer): port a cached best kernel
/// onto the current target GPU instead of generating from scratch. Much
/// shorter than the one-shot prompt — that gap is the service's per-request
/// token saving.
pub fn coder_adapt(task: &TaskSpec, gpu: &GpuSpec, cached: &KernelConfig) -> String {
    format!(
        "You previously optimized this operator and the best known kernel is \
         cached below. Port it to the target GPU: keep the algorithmic \
         structure, re-check launch limits (threads per block, shared memory \
         per block, registers) against the target's specification, and adjust \
         tile sizes only where the limits require it. Output the adapted \
         kernel only.\n\n\
         Target GPU:\n{spec}\n\n\
         The architecture:\n{arch}\n\n\
         Cached best kernel:\n{src}",
        spec = gpu.spec_sheet_cached(),
        arch = arch_src(task),
        src = cuda_src(cached),
    )
}

/// Judge prompt, correction mode (Appendix A.2, "CUDA Kernel Correction").
pub fn judge_correction(task: &TaskSpec, cfg: &KernelConfig, error_log: &str) -> String {
    format!(
        "You are a senior CUDA + PyTorch correctness auditor. Your job is to \
         read a PyTorch reference and a CUDA candidate and report exactly one \
         most critical correctness issue in the CUDA code that would cause a \
         behavioral mismatch vs. the PyTorch reference. Be terse and precise.\n\n\
         Rules:\n\
         - Return one and only one issue - the single highest-impact problem.\n\
         - Prefer semantic/correctness issues over micro-optimizations or style.\n\
         - If multiple issues exist, pick the one that most changes outputs or \
         gradients.\n\
         - If nothing clearly wrong is found, say it explicitly.\n\n\
         Output format (JSON):\n\
         {{\n \"critical_issue\": \"<max 20 words>\",\n \"why_it_matters\": \
         \"<max 35 words>\",\n \"minimal_fix_hint\": \"<max 20 words>\"\n}}\n\n\
         You are given:\n\nERROR_LOG:\n{error_log}\n\n\
         PyTorch reference (ground truth):\n{arch}\n\n\
         CUDA candidate (to audit):\n{cuda}\n\n\
         Follow the Rules and produce the JSON exactly in the specified format.",
        arch = arch_src(task),
        cuda = cuda_src(cfg),
    )
}

/// Judge prompt, optimization mode (Appendix A.2, "CUDA Kernel Optimization").
pub fn judge_optimization(
    task: &TaskSpec,
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    metric_block: &str,
) -> String {
    format!(
        "You are a senior CUDA performance engineer. Read the target GPU spec, \
         the PyTorch reference code, the current CUDA candidate, and the Nsight \
         Compute metrics. Then identify exactly one highest-impact speed \
         bottleneck by 3-4 most important metrics, propose exactly one \
         optimisation method and propose a modification plan. Be surgical and \
         metrics-driven.\n\n\
         Rules:\n\
         - Return one and only one optimisation method - the largest expected \
         speedup.\n\
         - Prefer changes that directly address measured bottlenecks (occupancy \
         limits, memory coalescing, smem bank conflicts, register pressure, \
         long/short scoreboard stalls, tensor-core underutilisation, etc.).\n\
         - Keep fields brief; avoid lists of alternatives, disclaimers, or \
         generic advice.\n\n\
         Output format (JSON):\n\
         {{\n \"bottleneck\": \"<max 30 words>\",\n \"optimisation method\": \
         \"<max 35 words>\",\n \"modification plan\": \"<max 35 words>\"\n}}\n\n\
         Target GPU\n{spec}\n\n\
         PyTorch Reference\n{arch}\n\n\
         CUDA Candidate\n{cuda}\n\n\
         Nsight Compute metrics (verbatim)\n{metrics}\n\n\
         Read everything and follow the Rules exactly. Return the JSON in the \
         specified format.",
        spec = gpu.spec_sheet_cached(),
        arch = arch_src(task),
        cuda = cuda_src(cfg),
        metrics = metric_block,
    )
}

/// Coder prompt, rounds 2..N, correction (Appendix A.3).
pub fn coder_correction(cfg: &KernelConfig, error_log: &str, problem_json: &str) -> String {
    format!(
        "You are a senior CUDA-extension developer. Your job is to FIX the \
         compilation or runtime errors in the Python script shown below.\n\n\
         OUTPUT RULES (STRICT)\n\
         1. Inside the block, follow exactly this order: imports, source \
         (triple-quoted CUDA string), cpp_src prototypes, one load_inline call \
         per kernel group, class ModelNew(nn.Module).\n\
         2. Do NOT include testing code, if __name__ == \"__main__\", or extra \
         prose.\n\n\
         ERROR LOG\n{error_log}\n\n\
         OLD CODE (read-only)\n{cuda}\n\n\
         Main Critical Problem\n{problem_json}\n\n\
         Output Section (to be generated):\n# <your corrected code>",
        cuda = cuda_src(cfg),
    )
}

/// Coder prompt, rounds 2..N, optimization (Appendix A.3).
pub fn coder_optimization(
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    suggestion_json: &str,
) -> String {
    format!(
        "Target GPU\n{spec}\n\n\
         You are a CUDA-kernel optimization specialist.\n\
         Analyze the provided architecture and strictly apply the following \
         STRATEGY to produce an improved CUDA kernel.\n\n{cuda}\n\n\
         Optimization instructions:\n{suggestion_json}\n\n\
         GOAL\n\
         - Improve latency and throughput on the target GPU.\n\
         - Maintain correctness within atol=1e-4 or rtol=1e-4.\n\
         - Preserve the public Python API (same inputs/outputs, shapes, \
         dtypes).\n\n\
         OUTPUT RULES (STRICT)\n\
         1. Imports, source, cpp_src, one load_inline call, class \
         ModelNew(nn.Module).\n\
         2. Do NOT include testing code or extra prose.\n\n\
         Output Section (to be generated):\n# <your corrected code>",
        spec = gpu.spec_sheet_cached(),
        cuda = cuda_src(cfg),
    )
}

/// Synthetic PyTorch "reference source" for a task — sized realistically so
/// token accounting is honest (task cards in KernelBench are 0.5-3 KB).
pub fn arch_src(task: &TaskSpec) -> String {
    let mut body = String::with_capacity(64 * task.stages.min(12) as usize);
    for s in 0..task.stages.min(12) {
        body.push_str(&format!(
            "        x = self.stage_{s}(x)  # {} op, stage {s}\n",
            task.op_class.name()
        ));
    }
    format!(
        "# KernelBench task {} ({}), level {}\n\
         # flops={:.3e} bytes={:.3e} stages={} tc_eligible={}\n\
         import torch\nimport torch.nn as nn\n\n\
         class Model(nn.Module):\n    def __init__(self):\n        \
         super().__init__()\n        # {} reference pipeline\n\n    \
         def forward(self, x):\n{body}        return x\n",
        task.id(),
        task.name,
        task.level,
        task.flops,
        task.ideal_bytes,
        task.stages,
        task.tc_eligible,
        task.name,
    )
}

/// Synthetic "CUDA candidate source" for a config — again sized realistically
/// (a candidate kernel is 2-6 KB); content mirrors the config so the Judge
/// prompt genuinely encodes the kernel state.
pub fn cuda_src(cfg: &KernelConfig) -> String {
    format!(
        "// candidate kernel (configuration fingerprint)\n\
         // {desc}\n\
         __global__ void kernel(const float* __restrict__ in, float* out) {{\n\
         {body}}}\n",
        desc = cfg.describe(),
        body = {
            let mut b = String::with_capacity(256 + 24 * cfg.syncs_per_tile as usize);
            b.push_str(&format!(
                "  // launch: {} threads/block, tile {}x{}x{}\n",
                cfg.block_threads, cfg.tile_m, cfg.tile_n, cfg.tile_k
            ));
            if cfg.use_smem {
                b.push_str("  __shared__ float a_tile[TM][TK]; __shared__ float b_tile[TK][TN];\n");
            }
            for _ in 0..cfg.syncs_per_tile.min(16) {
                b.push_str("  __syncthreads();\n");
            }
            if cfg.warp_shuffle {
                b.push_str("  v += __shfl_down_sync(0xffffffff, v, offset);\n");
            }
            if cfg.use_tensor_cores {
                b.push_str("  wmma::mma_sync(acc, a_frag, b_frag, acc);\n");
            }
            for p in 0..cfg.extra_global_passes {
                b.push_str(&format!("  // pass {} re-reads input from global\n", p + 2));
            }
            b
        }
    )
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;
    use crate::tasks::by_id;

    #[test]
    fn prompts_contain_the_paper_sections() {
        let t = by_id("L1-95").unwrap();
        let cfg = KernelConfig::naive();
        let p = coder_initial(&t);
        assert!(p.contains("online softmax"));
        assert!(p.contains("ModelNew"));
        let p = judge_correction(&t, &cfg, "Outputs are not close");
        assert!(p.contains("critical_issue"));
        assert!(p.contains("ERROR_LOG"));
        let p = judge_optimization(&t, &RTX6000_ADA, &cfg, "dram__bytes.sum: 1\n");
        assert!(p.contains("Nsight Compute metrics (verbatim)"));
        assert!(p.contains("Target GPU"));
        assert!(p.contains("RTX 6000"));
        let p = coder_optimization(&RTX6000_ADA, &cfg, "{\"bottleneck\":\"x\"}");
        assert!(p.contains("atol=1e-4"));
    }

    #[test]
    fn cuda_src_reflects_config() {
        let mut cfg = KernelConfig::naive();
        cfg.use_smem = true;
        cfg.warp_shuffle = true;
        cfg.syncs_per_tile = 3;
        let s = cuda_src(&cfg);
        assert!(s.contains("__shared__"));
        assert!(s.contains("__shfl_down_sync"));
        assert_eq!(s.matches("__syncthreads()").count(), 3);
    }

    #[test]
    fn prompt_sizes_realistic_for_token_accounting() {
        let t = by_id("L3-5").unwrap();
        let cfg = KernelConfig::naive();
        let p = judge_optimization(&t, &RTX6000_ADA, &cfg, &"m: 1.0\n".repeat(24));
        let tokens = crate::agents::estimate_tokens(&p);
        assert!(tokens > 500.0 && tokens < 5000.0, "{tokens}");
    }
}
