//! Prompt templates — the paper's Appendix A, verbatim structure.
//!
//! Rendering real prompt text serves two purposes: (i) the cost model counts
//! tokens off the actual strings (Table 3 / Fig 6), and (ii) the case-study
//! outputs (Fig 8) display the same artifacts a user of the original system
//! would see. The behavioural agents *parse nothing from these strings* —
//! they receive structured state — but every call renders and accounts them,
//! exactly like the original system pays for them.
//!
//! # The two render paths
//!
//! Every template is written once, as a `write_*` function streaming into any
//! [`std::fmt::Write`] sink. The `String`-returning functions (what the
//! case-study display uses) stream into a `String`; the `*_len` functions
//! (what the token accountants on the replay hot path use) stream into a
//! [`LenWriter`] that counts bytes and stores nothing. Both paths execute the
//! *same* formatting code, so the accounted length is the materialised
//! string's length by construction — never a drifting re-implementation —
//! while the hot path allocates no multi-kilobyte prompt per agent call.
// The prompts are literal text with embedded newlines; `write!` is the
// point (one template, two sinks), so the writeln!-style lint is noise here.
#![allow(clippy::write_with_newline)]

use std::fmt::{self, Write};

use crate::gpu::GpuSpec;
use crate::kernel::KernelConfig;
use crate::tasks::TaskSpec;

/// A `fmt::Write` sink that counts bytes and stores nothing. Streaming a
/// prompt template into it yields the exact rendered length (and therefore
/// the exact token estimate) without allocating the prompt text — the
/// replay hot path renders millions of prompts per trace for accounting
/// only.
#[derive(Default)]
pub struct LenWriter(pub usize);

impl fmt::Write for LenWriter {
    fn write_str(&mut self, s: &str) -> fmt::Result {
        self.0 += s.len();
        Ok(())
    }
}

/// Render `f` into a [`LenWriter`] and return the byte count.
fn count<F: FnOnce(&mut LenWriter) -> fmt::Result>(f: F) -> usize {
    let mut w = LenWriter::default();
    f(&mut w).expect("LenWriter never fails");
    w.0
}

/// Render `f` into a fresh `String`.
fn render<F: FnOnce(&mut String) -> fmt::Result>(f: F) -> String {
    let mut s = String::new();
    f(&mut s).expect("fmt::Write to String never fails");
    s
}

/// The one-shot demonstration pair (KernelBench's few-shot example: a
/// PyTorch module and its custom-CUDA rewrite). Abbreviated but realistic
/// in size so token accounting stays honest.
const FEW_BASE: &str = "\
import torch\nimport torch.nn as nn\n\nclass Model(nn.Module):\n    \
def __init__(self):\n        super().__init__()\n\n    \
def forward(self, a, b):\n        return a + b\n";

const FEW_NEW: &str = "\
import torch\nimport torch.nn as nn\nfrom torch.utils.cpp_extension import \
load_inline\n\nsource = '''\n__global__ void add_kernel(const float* a, const \
float* b, float* out, int n) {\n  int i = blockIdx.x * blockDim.x + \
threadIdx.x;\n  if (i < n) out[i] = a[i] + b[i];\n}\ntorch::Tensor add_cuda(\
torch::Tensor a, torch::Tensor b) {\n  auto out = torch::empty_like(a);\n  \
int n = a.numel();\n  add_kernel<<<(n+255)/256, 256>>>(a.data_ptr<float>(), \
b.data_ptr<float>(), out.data_ptr<float>(), n);\n  return out;\n}\n'''\n\n\
cpp_src = 'torch::Tensor add_cuda(torch::Tensor a, torch::Tensor b);'\n\
add_mod = load_inline(name='add', cpp_sources=cpp_src, cuda_sources=source,\n\
                      functions=['add_cuda'])\n\nclass ModelNew(nn.Module):\n    \
def forward(self, a, b):\n        return add_mod.add_cuda(a, b)\n";

/// Stream the one-shot baseline prompt (KernelBench's one-shot prompt, per
/// Appendix A.1) into `w`.
pub fn write_coder_initial<W: Write>(w: &mut W, task: &TaskSpec) -> fmt::Result {
    write!(
        w,
        "You write custom CUDA kernels to replace the PyTorch operators in the \
         given architecture to get speedups. You have complete freedom to choose \
         the set of operators you want to replace. Consider operator fusion \
         opportunities (combining multiple operators into a single kernel, for \
         example, combining matmul+relu), or algorithmic changes (such as online \
         softmax). You are only limited by your imagination.\n\n\
         The example given architecture is:\n{FEW_BASE}\n\n\
         The example new architecture with custom CUDA kernels looks like \
         this:\n{FEW_NEW}\n\n\
         You are given the following architecture:\n{arch}\n\n\
         Optimize the architecture named Model with custom CUDA operators! Name \
         your optimized output architecture ModelNew. Output the new code in \
         code blocks. Please generate real code, NOT pseudocode. Make sure the \
         code compiles and is fully functional. Just output the new model code, \
         no other text, and NO testing code!",
        arch = ArchSrc(task),
    )
}

/// One-shot baseline prompt for the first generation (KernelBench's
/// one-shot prompt, per Appendix A.1).
pub fn coder_initial(task: &TaskSpec) -> String {
    render(|w| write_coder_initial(w, task))
}

/// Rendered byte length of [`coder_initial`] without materialising it.
pub fn coder_initial_len(task: &TaskSpec) -> usize {
    count(|w| write_coder_initial(w, task))
}

/// Stream the warm-start adaptation prompt into `w`.
pub fn write_coder_adapt<W: Write>(
    w: &mut W,
    task: &TaskSpec,
    gpu: &GpuSpec,
    cached: &KernelConfig,
) -> fmt::Result {
    write!(
        w,
        "You previously optimized this operator and the best known kernel is \
         cached below. Port it to the target GPU: keep the algorithmic \
         structure, re-check launch limits (threads per block, shared memory \
         per block, registers) against the target's specification, and adjust \
         tile sizes only where the limits require it. Output the adapted \
         kernel only.\n\n\
         Target GPU:\n{spec}\n\n\
         The architecture:\n{arch}\n\n\
         Cached best kernel:\n{src}",
        spec = gpu.spec_sheet_cached(),
        arch = ArchSrc(task),
        src = CudaSrc(cached),
    )
}

/// Warm-start adaptation prompt (service layer): port a cached best kernel
/// onto the current target GPU instead of generating from scratch. Much
/// shorter than the one-shot prompt — that gap is the service's per-request
/// token saving.
pub fn coder_adapt(task: &TaskSpec, gpu: &GpuSpec, cached: &KernelConfig) -> String {
    render(|w| write_coder_adapt(w, task, gpu, cached))
}

/// Rendered byte length of [`coder_adapt`] without materialising it.
pub fn coder_adapt_len(task: &TaskSpec, gpu: &GpuSpec, cached: &KernelConfig) -> usize {
    count(|w| write_coder_adapt(w, task, gpu, cached))
}

/// Stream the correction-mode Judge prompt into `w`.
pub fn write_judge_correction<W: Write>(
    w: &mut W,
    task: &TaskSpec,
    cfg: &KernelConfig,
    error_log: &str,
) -> fmt::Result {
    write!(
        w,
        "You are a senior CUDA + PyTorch correctness auditor. Your job is to \
         read a PyTorch reference and a CUDA candidate and report exactly one \
         most critical correctness issue in the CUDA code that would cause a \
         behavioral mismatch vs. the PyTorch reference. Be terse and precise.\n\n\
         Rules:\n\
         - Return one and only one issue - the single highest-impact problem.\n\
         - Prefer semantic/correctness issues over micro-optimizations or style.\n\
         - If multiple issues exist, pick the one that most changes outputs or \
         gradients.\n\
         - If nothing clearly wrong is found, say it explicitly.\n\n\
         Output format (JSON):\n\
         {{\n \"critical_issue\": \"<max 20 words>\",\n \"why_it_matters\": \
         \"<max 35 words>\",\n \"minimal_fix_hint\": \"<max 20 words>\"\n}}\n\n\
         You are given:\n\nERROR_LOG:\n{error_log}\n\n\
         PyTorch reference (ground truth):\n{arch}\n\n\
         CUDA candidate (to audit):\n{cuda}\n\n\
         Follow the Rules and produce the JSON exactly in the specified format.",
        arch = ArchSrc(task),
        cuda = CudaSrc(cfg),
    )
}

/// Judge prompt, correction mode (Appendix A.2, "CUDA Kernel Correction").
pub fn judge_correction(task: &TaskSpec, cfg: &KernelConfig, error_log: &str) -> String {
    render(|w| write_judge_correction(w, task, cfg, error_log))
}

/// Rendered byte length of [`judge_correction`] without materialising it.
pub fn judge_correction_len(task: &TaskSpec, cfg: &KernelConfig, error_log: &str) -> usize {
    count(|w| write_judge_correction(w, task, cfg, error_log))
}

/// Stream the optimization-mode Judge prompt into `w`. `metrics` is any
/// displayable metric block — a rendered `&str`, or `ncu::MetricBlock` to
/// stream the block without materialising it either.
pub fn write_judge_optimization<W: Write, M: fmt::Display>(
    w: &mut W,
    task: &TaskSpec,
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    metrics: M,
) -> fmt::Result {
    write!(
        w,
        "You are a senior CUDA performance engineer. Read the target GPU spec, \
         the PyTorch reference code, the current CUDA candidate, and the Nsight \
         Compute metrics. Then identify exactly one highest-impact speed \
         bottleneck by 3-4 most important metrics, propose exactly one \
         optimisation method and propose a modification plan. Be surgical and \
         metrics-driven.\n\n\
         Rules:\n\
         - Return one and only one optimisation method - the largest expected \
         speedup.\n\
         - Prefer changes that directly address measured bottlenecks (occupancy \
         limits, memory coalescing, smem bank conflicts, register pressure, \
         long/short scoreboard stalls, tensor-core underutilisation, etc.).\n\
         - Keep fields brief; avoid lists of alternatives, disclaimers, or \
         generic advice.\n\n\
         Output format (JSON):\n\
         {{\n \"bottleneck\": \"<max 30 words>\",\n \"optimisation method\": \
         \"<max 35 words>\",\n \"modification plan\": \"<max 35 words>\"\n}}\n\n\
         Target GPU\n{spec}\n\n\
         PyTorch Reference\n{arch}\n\n\
         CUDA Candidate\n{cuda}\n\n\
         Nsight Compute metrics (verbatim)\n{metrics}\n\n\
         Read everything and follow the Rules exactly. Return the JSON in the \
         specified format.",
        spec = gpu.spec_sheet_cached(),
        arch = ArchSrc(task),
        cuda = CudaSrc(cfg),
        metrics = metrics,
    )
}

/// Judge prompt, optimization mode (Appendix A.2, "CUDA Kernel Optimization").
pub fn judge_optimization(
    task: &TaskSpec,
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    metric_block: &str,
) -> String {
    render(|w| write_judge_optimization(w, task, gpu, cfg, metric_block))
}

/// Rendered byte length of [`judge_optimization`] without materialising it.
pub fn judge_optimization_len<M: fmt::Display>(
    task: &TaskSpec,
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    metrics: M,
) -> usize {
    count(|w| write_judge_optimization(w, task, gpu, cfg, metrics))
}

/// Stream the rounds-2..N correction Coder prompt into `w`.
pub fn write_coder_correction<W: Write>(
    w: &mut W,
    cfg: &KernelConfig,
    error_log: &str,
    problem_json: &str,
) -> fmt::Result {
    write!(
        w,
        "You are a senior CUDA-extension developer. Your job is to FIX the \
         compilation or runtime errors in the Python script shown below.\n\n\
         OUTPUT RULES (STRICT)\n\
         1. Inside the block, follow exactly this order: imports, source \
         (triple-quoted CUDA string), cpp_src prototypes, one load_inline call \
         per kernel group, class ModelNew(nn.Module).\n\
         2. Do NOT include testing code, if __name__ == \"__main__\", or extra \
         prose.\n\n\
         ERROR LOG\n{error_log}\n\n\
         OLD CODE (read-only)\n{cuda}\n\n\
         Main Critical Problem\n{problem_json}\n\n\
         Output Section (to be generated):\n# <your corrected code>",
        cuda = CudaSrc(cfg),
    )
}

/// Coder prompt, rounds 2..N, correction (Appendix A.3).
pub fn coder_correction(cfg: &KernelConfig, error_log: &str, problem_json: &str) -> String {
    render(|w| write_coder_correction(w, cfg, error_log, problem_json))
}

/// Rendered byte length of [`coder_correction`] without materialising it.
pub fn coder_correction_len(cfg: &KernelConfig, error_log: &str, problem_json: &str) -> usize {
    count(|w| write_coder_correction(w, cfg, error_log, problem_json))
}

/// Stream the rounds-2..N optimization Coder prompt into `w`.
pub fn write_coder_optimization<W: Write>(
    w: &mut W,
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    suggestion_json: &str,
) -> fmt::Result {
    write!(
        w,
        "Target GPU\n{spec}\n\n\
         You are a CUDA-kernel optimization specialist.\n\
         Analyze the provided architecture and strictly apply the following \
         STRATEGY to produce an improved CUDA kernel.\n\n{cuda}\n\n\
         Optimization instructions:\n{suggestion_json}\n\n\
         GOAL\n\
         - Improve latency and throughput on the target GPU.\n\
         - Maintain correctness within atol=1e-4 or rtol=1e-4.\n\
         - Preserve the public Python API (same inputs/outputs, shapes, \
         dtypes).\n\n\
         OUTPUT RULES (STRICT)\n\
         1. Imports, source, cpp_src, one load_inline call, class \
         ModelNew(nn.Module).\n\
         2. Do NOT include testing code or extra prose.\n\n\
         Output Section (to be generated):\n# <your corrected code>",
        spec = gpu.spec_sheet_cached(),
        cuda = CudaSrc(cfg),
    )
}

/// Coder prompt, rounds 2..N, optimization (Appendix A.3).
pub fn coder_optimization(
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    suggestion_json: &str,
) -> String {
    render(|w| write_coder_optimization(w, gpu, cfg, suggestion_json))
}

/// Rendered byte length of [`coder_optimization`] without materialising it.
pub fn coder_optimization_len(
    gpu: &GpuSpec,
    cfg: &KernelConfig,
    suggestion_json: &str,
) -> usize {
    count(|w| write_coder_optimization(w, gpu, cfg, suggestion_json))
}

/// Display adapter streaming the synthetic PyTorch "reference source" for a
/// task — the same bytes [`arch_src`] returns, without the intermediate
/// `String` (task cards in KernelBench are 0.5-3 KB).
pub struct ArchSrc<'a>(pub &'a TaskSpec);

impl fmt::Display for ArchSrc<'_> {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        let task = self.0;
        write!(
            w,
            "# KernelBench task {} ({}), level {}\n\
             # flops={:.3e} bytes={:.3e} stages={} tc_eligible={}\n\
             import torch\nimport torch.nn as nn\n\n\
             class Model(nn.Module):\n    def __init__(self):\n        \
             super().__init__()\n        # {} reference pipeline\n\n    \
             def forward(self, x):\n",
            task.id(),
            task.name,
            task.level,
            task.flops,
            task.ideal_bytes,
            task.stages,
            task.tc_eligible,
            task.name,
        )?;
        for s in 0..task.stages.min(12) {
            write!(
                w,
                "        x = self.stage_{s}(x)  # {} op, stage {s}\n",
                task.op_class.name()
            )?;
        }
        w.write_str("        return x\n")
    }
}

/// Synthetic PyTorch "reference source" for a task — sized realistically so
/// token accounting is honest (task cards in KernelBench are 0.5-3 KB).
pub fn arch_src(task: &TaskSpec) -> String {
    ArchSrc(task).to_string()
}

/// Display adapter streaming the synthetic "CUDA candidate source" for a
/// config — the same bytes [`cuda_src`] returns, without the intermediate
/// `String`.
pub struct CudaSrc<'a>(pub &'a KernelConfig);

impl fmt::Display for CudaSrc<'_> {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        let cfg = self.0;
        write!(
            w,
            "// candidate kernel (configuration fingerprint)\n\
             // {desc}\n\
             __global__ void kernel(const float* __restrict__ in, float* out) {{\n",
            desc = cfg.describe(),
        )?;
        write!(
            w,
            "  // launch: {} threads/block, tile {}x{}x{}\n",
            cfg.block_threads, cfg.tile_m, cfg.tile_n, cfg.tile_k
        )?;
        if cfg.use_smem {
            w.write_str(
                "  __shared__ float a_tile[TM][TK]; __shared__ float b_tile[TK][TN];\n",
            )?;
        }
        for _ in 0..cfg.syncs_per_tile.min(16) {
            w.write_str("  __syncthreads();\n")?;
        }
        if cfg.warp_shuffle {
            w.write_str("  v += __shfl_down_sync(0xffffffff, v, offset);\n")?;
        }
        if cfg.use_tensor_cores {
            w.write_str("  wmma::mma_sync(acc, a_frag, b_frag, acc);\n")?;
        }
        for p in 0..cfg.extra_global_passes {
            write!(w, "  // pass {} re-reads input from global\n", p + 2)?;
        }
        w.write_str("}\n")
    }
}

/// Synthetic "CUDA candidate source" for a config — again sized realistically
/// (a candidate kernel is 2-6 KB); content mirrors the config so the Judge
/// prompt genuinely encodes the kernel state.
pub fn cuda_src(cfg: &KernelConfig) -> String {
    CudaSrc(cfg).to_string()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;
    use crate::tasks::by_id;

    #[test]
    fn prompts_contain_the_paper_sections() {
        let t = by_id("L1-95").unwrap();
        let cfg = KernelConfig::naive();
        let p = coder_initial(&t);
        assert!(p.contains("online softmax"));
        assert!(p.contains("ModelNew"));
        let p = judge_correction(&t, &cfg, "Outputs are not close");
        assert!(p.contains("critical_issue"));
        assert!(p.contains("ERROR_LOG"));
        let p = judge_optimization(&t, &RTX6000_ADA, &cfg, "dram__bytes.sum: 1\n");
        assert!(p.contains("Nsight Compute metrics (verbatim)"));
        assert!(p.contains("Target GPU"));
        assert!(p.contains("RTX 6000"));
        let p = coder_optimization(&RTX6000_ADA, &cfg, "{\"bottleneck\":\"x\"}");
        assert!(p.contains("atol=1e-4"));
    }

    #[test]
    fn cuda_src_reflects_config() {
        let mut cfg = KernelConfig::naive();
        cfg.use_smem = true;
        cfg.warp_shuffle = true;
        cfg.syncs_per_tile = 3;
        let s = cuda_src(&cfg);
        assert!(s.contains("__shared__"));
        assert!(s.contains("__shfl_down_sync"));
        assert_eq!(s.matches("__syncthreads()").count(), 3);
    }

    #[test]
    fn prompt_sizes_realistic_for_token_accounting() {
        let t = by_id("L3-5").unwrap();
        let cfg = KernelConfig::naive();
        let p = judge_optimization(&t, &RTX6000_ADA, &cfg, &"m: 1.0\n".repeat(24));
        let tokens = crate::agents::estimate_tokens(&p);
        assert!(tokens > 500.0 && tokens < 5000.0, "{tokens}");
    }

    /// The load-bearing contract of the two-path design: the counted length
    /// IS the materialised length, for every template. If a template and its
    /// `_len` twin ever diverge, token accounting (and therefore every
    /// reported API-cost number) drifts.
    #[test]
    fn counted_lengths_match_rendered_strings() {
        let t = by_id("L3-5").unwrap();
        let g = &RTX6000_ADA;
        let mut cfg = KernelConfig::naive();
        cfg.use_smem = true;
        cfg.syncs_per_tile = 5;
        cfg.extra_global_passes = 2;
        assert_eq!(coder_initial_len(&t), coder_initial(&t).len());
        assert_eq!(coder_adapt_len(&t, g, &cfg), coder_adapt(&t, g, &cfg).len());
        assert_eq!(
            judge_correction_len(&t, &cfg, "Outputs are not close"),
            judge_correction(&t, &cfg, "Outputs are not close").len()
        );
        let block = "m: 1.0\n".repeat(24);
        assert_eq!(
            judge_optimization_len(&t, g, &cfg, block.as_str()),
            judge_optimization(&t, g, &cfg, &block).len()
        );
        assert_eq!(
            coder_correction_len(&cfg, "log", "{}"),
            coder_correction(&cfg, "log", "{}").len()
        );
        assert_eq!(
            coder_optimization_len(g, &cfg, "{}"),
            coder_optimization(g, &cfg, "{}").len()
        );
    }
}
