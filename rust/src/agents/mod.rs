//! The two-agent layer: behavioural models of the Coder and the Judge.
//!
//! The paper's agents are frontier LLMs; here they are deterministic, seeded
//! behavioural models with the *same interface* — prompt text in, structured
//! JSON feedback out — whose free parameters (capability profiles) are
//! calibrated once against Table 1 and then frozen (DESIGN.md §6). The
//! workflow, prompts, feedback protocol, memory policy and cost accounting
//! are exactly the paper's; only the "reasoning engine" inside each agent is
//! substituted.

pub mod coder;
pub mod judge;
pub mod profiles;
pub mod prompts;

pub use coder::Coder;
pub use judge::{Judge, MetricMode};
pub use profiles::ModelProfile;

use crate::kernel::{Bug, Opt};
use crate::util::json::Json;

/// Structured Judge feedback — the Appendix-A JSON schemas.
#[derive(Clone, Debug, PartialEq)]
pub enum Feedback {
    /// Correction mode: "exactly one most critical correctness issue".
    Correction {
        critical_issue: String,
        why_it_matters: String,
        minimal_fix_hint: String,
        /// The bug the Judge believes it found (None = misdiagnosis /
        /// generic advice; the Coder then has nothing precise to act on).
        bug: Option<Bug>,
    },
    /// Optimization mode: "exactly one highest-impact bottleneck".
    Optimization {
        bottleneck: String,
        method: String,
        plan: String,
        /// The transformation the Judge is asking for (None = vague /
        /// distracted advice).
        opt: Option<Opt>,
        /// The 3-4 metric names the Judge keyed on this round.
        critical_metrics: Vec<String>,
    },
    /// "If nothing clearly wrong is found, say it explicitly."
    NothingFound,
}

impl Feedback {
    /// Serialize to the paper's JSON wire format (what the Judge "prints"
    /// and the Coder receives — the protocol surface).
    pub fn to_json(&self) -> Json {
        match self {
            Feedback::Correction { critical_issue, why_it_matters, minimal_fix_hint, bug } => {
                Json::obj(vec![
                    ("critical_issue", Json::str(critical_issue.clone())),
                    ("why_it_matters", Json::str(why_it_matters.clone())),
                    ("minimal_fix_hint", Json::str(minimal_fix_hint.clone())),
                    (
                        "bug_tag",
                        bug.map(|b| Json::str(b.name())).unwrap_or(Json::Null),
                    ),
                ])
            }
            Feedback::Optimization { bottleneck, method, plan, opt, critical_metrics } => {
                Json::obj(vec![
                    ("bottleneck", Json::str(bottleneck.clone())),
                    ("optimisation method", Json::str(method.clone())),
                    ("modification plan", Json::str(plan.clone())),
                    (
                        "opt_tag",
                        opt.map(|o| Json::str(o.name())).unwrap_or(Json::Null),
                    ),
                    (
                        "critical_metrics",
                        Json::Arr(
                            critical_metrics.iter().map(|m| Json::str(m.clone())).collect(),
                        ),
                    ),
                ])
            }
            Feedback::NothingFound => Json::obj(vec![
                ("critical_issue", Json::str("none found")),
                ("why_it_matters", Json::str("kernel appears correct and near roofline")),
                ("minimal_fix_hint", Json::str("no change recommended")),
            ]),
        }
    }

    /// Parse the wire format back (the Coder side of the protocol).
    pub fn from_json(v: &Json) -> Option<Feedback> {
        if let Some(b) = v.get("bottleneck") {
            let opt = v
                .get("opt_tag")
                .and_then(|t| t.as_str())
                .and_then(Opt::by_name);
            return Some(Feedback::Optimization {
                bottleneck: b.as_str()?.to_string(),
                method: v.get("optimisation method")?.as_str()?.to_string(),
                plan: v.get("modification plan")?.as_str()?.to_string(),
                opt,
                critical_metrics: v
                    .get("critical_metrics")
                    .and_then(|m| m.as_arr())
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str().map(|s| s.to_string()))
                            .collect()
                    })
                    .unwrap_or_default(),
            });
        }
        let issue = v.get("critical_issue")?.as_str()?.to_string();
        if issue == "none found" {
            return Some(Feedback::NothingFound);
        }
        let bug = v.get("bug_tag").and_then(|t| t.as_str()).and_then(|name| {
            crate::kernel::ALL_BUGS.iter().copied().find(|b| b.name() == name)
        });
        Some(Feedback::Correction {
            critical_issue: issue,
            why_it_matters: v
                .get("why_it_matters")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
            minimal_fix_hint: v
                .get("minimal_fix_hint")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
            bug,
        })
    }
}

/// Token accounting for one agent call (drives the cost model, Table 3).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CallStats {
    pub tokens_in: f64,
    pub tokens_out: f64,
}

impl CallStats {
    pub fn add(&mut self, other: CallStats) {
        self.tokens_in += other.tokens_in;
        self.tokens_out += other.tokens_out;
    }
}

/// Crude-but-stable token estimate (~4 chars/token, the industry heuristic).
pub fn estimate_tokens(text: &str) -> f64 {
    estimate_tokens_len(text.len())
}

/// The same estimate when only the rendered byte length is known — the hot
/// path streams prompts through a counting writer (`prompts::LenWriter`)
/// instead of materialising them, so the estimate costs no allocation while
/// staying bit-identical to `estimate_tokens` over the rendered string.
pub fn estimate_tokens_len(len: usize) -> f64 {
    len as f64 / 4.0
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::kernel::Bug;

    #[test]
    fn feedback_json_round_trip_correction() {
        let f = Feedback::Correction {
            critical_issue: "Thread-0 uses uninitialized target_logit".into(),
            why_it_matters: "row 0 of the loss is wrong".into(),
            minimal_fix_hint: "broadcast target_logit via __shfl_sync to thread 0".into(),
            bug: Some(Bug::UninitValue),
        };
        let wire = f.to_json().to_string();
        let back = Feedback::from_json(&crate::util::json::Json::parse(&wire).unwrap());
        assert_eq!(back, Some(f));
    }

    #[test]
    fn feedback_json_round_trip_optimization() {
        let f = Feedback::Optimization {
            bottleneck: "23.7% of active warps stalled on barriers".into(),
            method: Opt::WarpShuffleReduction.suggestion().into(),
            plan: "use warp-level shuffles in the max and sum phases".into(),
            opt: Some(Opt::WarpShuffleReduction),
            critical_metrics: vec![
                "smsp__warp_issue_stalled_barrier_per_warp_active.pct".into(),
            ],
        };
        let wire = f.to_json().to_string();
        let back = Feedback::from_json(&crate::util::json::Json::parse(&wire).unwrap());
        assert_eq!(back, Some(f));
    }

    #[test]
    fn nothing_found_round_trips() {
        let wire = Feedback::NothingFound.to_json().to_string();
        let back = Feedback::from_json(&crate::util::json::Json::parse(&wire).unwrap());
        assert_eq!(back, Some(Feedback::NothingFound));
    }

    #[test]
    fn token_estimate_scales() {
        assert!(estimate_tokens("abcd") == 1.0);
        assert!(estimate_tokens(&"x".repeat(4000)) == 1000.0);
    }
}
