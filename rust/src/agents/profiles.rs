//! Base-model capability profiles.
//!
//! The paper instantiates CudaForge with o3, GPT-5, Claude-Sonnet-4,
//! GPT-OSS-120B and QwQ-32B (Table 5). Here each base model is a calibrated
//! capability vector; the *framework* (roles, feedback, memory policy) is
//! identical across profiles — which is exactly the paper's model-agnosticism
//! claim. Calibration touches only the o3 row (against Table 1's o3 one-shot
//! and CudaForge rows); the other profiles are set relative to o3 from public
//! coding-benchmark deltas and the qualitative Table 5 ordering, then frozen.

/// Capability + price profile of one base model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Quality of first-shot kernel generation in [0,1]: how many good
    /// structural choices (coalescing, staging, fusion, algorithmic insight)
    /// the initial candidate already makes.
    pub gen_skill: f64,
    /// Probability of correctly fixing a *named* bug.
    pub fix_skill: f64,
    /// Judge-side diagnosis quality (error-log reading, metric reading).
    pub diag_skill: f64,
    /// Probability of faithfully applying a *named* optimization.
    pub follow: f64,
    /// Base probability of introducing a defect per generation.
    pub bug_rate: f64,
    /// API price, USD per 1M input tokens.
    pub usd_per_mtok_in: f64,
    /// API price, USD per 1M output tokens.
    pub usd_per_mtok_out: f64,
    /// Wall-clock seconds per call (reasoning models think slowly).
    pub seconds_per_call: f64,
    /// Typical completion size for a kernel generation (tokens).
    pub gen_out_tokens: f64,
    /// Typical completion size for a Judge verdict (tokens).
    pub judge_out_tokens: f64,
}

/// OpenAI-o3 — the paper's default Coder and Judge.
pub const O3: ModelProfile = ModelProfile {
    name: "OpenAI-o3",
    gen_skill: 0.74,
    fix_skill: 0.86,
    diag_skill: 0.84,
    follow: 0.86,
    bug_rate: 0.24,
    usd_per_mtok_in: 2.0,
    usd_per_mtok_out: 8.0,
    seconds_per_call: 55.0,
    gen_out_tokens: 2600.0,
    judge_out_tokens: 700.0,
};

pub const GPT5: ModelProfile = ModelProfile {
    name: "GPT-5",
    gen_skill: 0.78,
    fix_skill: 0.88,
    diag_skill: 0.91,
    follow: 0.90,
    bug_rate: 0.22,
    usd_per_mtok_in: 1.25,
    usd_per_mtok_out: 10.0,
    seconds_per_call: 60.0,
    gen_out_tokens: 2800.0,
    judge_out_tokens: 800.0,
};

pub const CLAUDE_SONNET_4: ModelProfile = ModelProfile {
    name: "Claude-Sonnet-4",
    gen_skill: 0.62,
    fix_skill: 0.78,
    diag_skill: 0.86,
    follow: 0.84,
    bug_rate: 0.33,
    usd_per_mtok_in: 3.0,
    usd_per_mtok_out: 15.0,
    seconds_per_call: 35.0,
    gen_out_tokens: 2400.0,
    judge_out_tokens: 650.0,
};

pub const GPT_OSS_120B: ModelProfile = ModelProfile {
    name: "GPT-OSS-120B",
    gen_skill: 0.66,
    fix_skill: 0.80,
    diag_skill: 0.72,
    follow: 0.78,
    bug_rate: 0.30,
    usd_per_mtok_in: 0.15,
    usd_per_mtok_out: 0.6,
    seconds_per_call: 25.0,
    gen_out_tokens: 2200.0,
    judge_out_tokens: 600.0,
};

pub const QWQ_32B: ModelProfile = ModelProfile {
    name: "QwQ-32B",
    gen_skill: 0.42,
    fix_skill: 0.62,
    diag_skill: 0.60,
    follow: 0.62,
    bug_rate: 0.46,
    usd_per_mtok_in: 0.12,
    usd_per_mtok_out: 0.4,
    seconds_per_call: 40.0,
    gen_out_tokens: 3200.0, // long chain-of-thought
    judge_out_tokens: 900.0,
};

pub const ALL: [&ModelProfile; 5] = [&O3, &GPT5, &CLAUDE_SONNET_4, &GPT_OSS_120B, &QWQ_32B];

pub fn by_name(name: &str) -> Option<&'static ModelProfile> {
    ALL.iter().copied().find(|p| p.name.eq_ignore_ascii_case(name))
        .or_else(|| match name.to_ascii_lowercase().as_str() {
            "o3" => Some(&O3),
            "gpt5" | "gpt-5" => Some(&GPT5),
            "claude" | "sonnet4" | "claude-sonnet-4" => Some(&CLAUDE_SONNET_4),
            "oss" | "gpt-oss" | "oss120b" => Some(&GPT_OSS_120B),
            "qwq" | "qwq-32b" => Some(&QWQ_32B),
            _ => None,
        })
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn profile_fields_in_range() {
        for p in ALL {
            for v in [p.gen_skill, p.fix_skill, p.diag_skill, p.follow, p.bug_rate] {
                assert!((0.0..=1.0).contains(&v), "{}", p.name);
            }
            assert!(p.usd_per_mtok_out > 0.0 && p.seconds_per_call > 0.0);
        }
    }

    #[test]
    fn table5_qualitative_ordering() {
        // GPT-5 >= o3 as a judge; QwQ is the weakest coder; o3 is a strong
        // all-rounder — the preconditions for Table 5's ordering to emerge.
        assert!(GPT5.diag_skill > O3.diag_skill);
        assert!(QWQ_32B.gen_skill < CLAUDE_SONNET_4.gen_skill);
        assert!(CLAUDE_SONNET_4.gen_skill < GPT_OSS_120B.gen_skill + 0.05);
        assert!(O3.gen_skill > GPT_OSS_120B.gen_skill);
    }

    #[test]
    fn lookup_aliases() {
        assert_eq!(by_name("o3").unwrap().name, "OpenAI-o3");
        assert_eq!(by_name("GPT-5").unwrap().name, "GPT-5");
        assert_eq!(by_name("qwq").unwrap().name, "QwQ-32B");
        assert!(by_name("gemini").is_none());
    }
}
