//! The Coder agent — the generative half of the two-agent workflow (§2.2).
//!
//! Behavioural model: the Coder owns an explicit kernel configuration and
//! rewrites it under feedback. Its capability profile controls (i) how many
//! good structural choices the *initial* kernel already makes, (ii) how
//! reliably it fixes a *named* bug, (iii) how faithfully it applies a *named*
//! optimization, and (iv) how often a rewrite introduces a fresh defect —
//! the four failure axes the paper's ablations isolate (§3.6).
//!
//! Lightweight memory (§2.2 "memory scope"): `revise_*` receives only the
//! previous candidate and the latest Judge feedback — never the dialogue
//! history — mirroring the paper's round-by-round prompting.

use crate::agents::prompts;
use crate::agents::{estimate_tokens_len, CallStats, Feedback, ModelProfile};
use crate::gpu::GpuSpec;
use crate::kernel::{Bug, KernelConfig, Opt, OPT_CATALOG};
use crate::tasks::TaskSpec;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct Coder {
    pub profile: ModelProfile,
}

/// Bug classes weighted by how often fresh generations exhibit them
/// (compile errors dominate first attempts; KernelBench §5.1 of [13]).
const BUG_WEIGHTS: [(Bug, f64); 9] = [
    (Bug::CompileMissingHeader, 0.14),
    (Bug::CompileSyntax, 0.12),
    (Bug::CompileWrongApi, 0.12),
    (Bug::LaunchMisconfig, 0.07),
    (Bug::RaceCondition, 0.10),
    (Bug::OobIndex, 0.18),
    (Bug::UninitValue, 0.12),
    (Bug::WrongConstant, 0.08),
    (Bug::WrongAxis, 0.07),
];

fn random_bug(rng: &mut Rng) -> Bug {
    let weights: Vec<f64> = BUG_WEIGHTS.iter().map(|(_, w)| *w).collect();
    BUG_WEIGHTS[rng.weighted_choice(&weights)].0
}

/// Rewrite-risk bug (runtime-leaning — rewrites rarely fail to compile).
fn rewrite_bug(rng: &mut Rng) -> Bug {
    let tail = &BUG_WEIGHTS[3..];
    let weights: Vec<f64> = tail.iter().map(|(_, w)| *w).collect();
    tail[rng.weighted_choice(&weights)].0
}

impl Coder {
    pub fn new(profile: ModelProfile) -> Coder {
        Coder { profile }
    }

    /// Call stats for a prompt whose rendered byte length is `prompt_len` —
    /// the hot path streams prompts through `prompts::LenWriter` instead of
    /// materialising them, so only the length reaches the accountant.
    fn stats_for_len(&self, prompt_len: usize) -> CallStats {
        CallStats {
            tokens_in: estimate_tokens_len(prompt_len),
            tokens_out: self.profile.gen_out_tokens,
        }
    }

    /// Probability the current generation introduces a defect.
    fn p_bug(&self, task: &TaskSpec) -> f64 {
        (self.profile.bug_rate + 0.26 * task.difficulty).clamp(0.03, 0.92)
    }

    /// Round 1: one-shot generation from the task card (Appendix A.1 prompt).
    pub fn initial(
        &self,
        task: &TaskSpec,
        gpu: &GpuSpec,
        rng: &mut Rng,
    ) -> (KernelConfig, CallStats) {
        let s = self.profile.gen_skill;
        let mut cfg = KernelConfig::naive();

        // Structural quality of the first shot scales with generation skill.
        if rng.chance(0.45 + 0.50 * s) {
            cfg.coalesced = true;
        }
        if task.op_class.has_data_reuse() && rng.chance(0.35 + 0.55 * s) {
            cfg.use_smem = true;
            cfg.tile_m = *rng.choice(&[32, 32, 64]);
            cfg.tile_n = cfg.tile_m;
            cfg.tile_k = *rng.choice(&[8, 16, 32]);
            // Weak coders over-synchronize (the Fig. 8 starting point: 16
            // __syncthreads per block).
            cfg.syncs_per_tile = if rng.chance(s) { 2 } else { *rng.choice(&[8, 16]) };
        }
        if rng.chance(0.35 * s) {
            cfg.vector_width = 4;
        }
        if task.op_class.online_eligible() {
            // Naive reduction kernels make extra passes over the input.
            cfg.extra_global_passes = if rng.chance(0.35 + 0.45 * s) { 1 } else { 2 };
            if rng.chance(0.30 * s) {
                cfg.online_algorithm = true;
                cfg.extra_global_passes = 0;
            }
            cfg.syncs_per_tile = cfg.syncs_per_tile.max(if rng.chance(s) { 2 } else { 12 });
        }
        if task.baseline_waste > 1.0 && rng.chance(0.30 * s) {
            // The "algorithmic changes" insight from the one-shot prompt.
            cfg.algo_optimal = true;
        }
        if task.tc_eligible && rng.chance(0.30 * s) {
            Opt::UseTensorCores.apply(&mut cfg, task, gpu);
        }
        // Partial epilogue fusion in the first shot.
        let mut extra_fuse = 0;
        for _ in 0..(task.stages.saturating_sub(1)).min(3) {
            if rng.chance(0.15 + 0.35 * s) {
                extra_fuse += 1;
            }
        }
        cfg.fused_stages = 1 + extra_fuse;
        cfg.unroll = *rng.choice(&[1, 1, 2, 4]);
        cfg.block_threads = *rng.choice(&[128, 256, 256, 512]);
        cfg.regs_per_thread = rng.range_usize(40, 128) as u32;

        // Defect injection.
        let p = self.p_bug(task);
        if rng.chance(p) {
            cfg.bugs.push(random_bug(rng));
        }
        if rng.chance(p * 0.30) {
            cfg.bugs.push(random_bug(rng));
        }
        cfg.legalize(gpu);
        let stats = self.stats_for_len(prompts::coder_initial_len(task));
        (cfg, stats)
    }

    /// Round 1 of a warm-started run (service layer): adapt a cached best
    /// kernel instead of generating cold. Cheaper than `initial` (short
    /// prompt, short completion) and far less defect-prone — porting a known-
    /// good kernel is a light edit, not a rewrite. Cross-GPU transfers
    /// re-legalize the launch geometry against the target part and carry a
    /// small re-tuning risk.
    pub fn adapt(
        &self,
        task: &TaskSpec,
        gpu: &GpuSpec,
        warm: &crate::workflow::WarmStart,
        rng: &mut Rng,
    ) -> (KernelConfig, CallStats) {
        let mut cfg = warm.config.clone();
        cfg.bugs.clear(); // cached entries hold correct kernels only
        let cross_gpu = gpu.key != warm.source_gpu;
        if cross_gpu {
            // Transfer heuristic: the launch envelope moves with the part
            // (smem per block, register file, warp count) — legalize clamps
            // the cached geometry into the new envelope. Occasionally the
            // port fumbles a tuning knob and has to re-discover it.
            if rng.chance(0.15 * (1.0 - self.profile.follow)) {
                perturb(&mut cfg, rng);
            }
        }
        // Light-edit defect risk, well below a cold generation's p_bug.
        let p = (0.25 * self.p_bug(task) * if cross_gpu { 1.5 } else { 1.0 }).min(0.3);
        if rng.chance(p) {
            cfg.bugs.push(rewrite_bug(rng));
        }
        cfg.legalize(gpu);
        let stats = CallStats {
            tokens_in: estimate_tokens_len(prompts::coder_adapt_len(task, gpu, &warm.config)),
            // Porting emits the kernel once, without the exploratory chatter
            // of a cold generation.
            tokens_out: self.profile.gen_out_tokens * 0.45,
        };
        (cfg, stats)
    }

    /// Rounds 2..N, correction mode: fix the named problem.
    pub fn revise_correction(
        &self,
        task: &TaskSpec,
        gpu: &GpuSpec,
        prev: &KernelConfig,
        feedback: &Feedback,
        error_log: &str,
        rng: &mut Rng,
    ) -> (KernelConfig, CallStats) {
        let mut cfg = prev.clone();
        // Hard tasks are harder to debug even with the defect named.
        let fix = self.profile.fix_skill * (1.0 - 0.35 * task.difficulty);
        match feedback {
            Feedback::Correction { bug: Some(b), .. } => {
                if cfg.bugs.contains(b) {
                    if rng.chance(fix) {
                        cfg.remove_bug(*b);
                    }
                } else if !cfg.bugs.is_empty() && rng.chance(0.30 * fix) {
                    // Judge misnamed the defect; while rewriting, the Coder
                    // sometimes stumbles onto the real one anyway.
                    let b0 = cfg.bugs[0];
                    cfg.remove_bug(b0);
                }
            }
            _ => {
                // Vague feedback: unguided debugging, much less reliable.
                if !cfg.bugs.is_empty() && rng.chance(0.30 * fix) {
                    let b0 = cfg.bugs[0];
                    cfg.remove_bug(b0);
                }
            }
        }
        // Any rewrite can regress.
        if rng.chance(0.05 + 0.12 * (1.0 - fix) + 0.05 * task.difficulty) {
            cfg.bugs.push(rewrite_bug(rng));
        }
        cfg.legalize(gpu);
        let fb_json = feedback.to_json().to_string();
        let stats = self.stats_for_len(prompts::coder_correction_len(prev, error_log, &fb_json));
        let _ = task;
        (cfg, stats)
    }

    /// Rounds 2..N, optimization mode: apply the suggested strategy.
    pub fn revise_optimization(
        &self,
        task: &TaskSpec,
        gpu: &GpuSpec,
        prev: &KernelConfig,
        feedback: &Feedback,
        rng: &mut Rng,
    ) -> (KernelConfig, CallStats) {
        let mut cfg = prev.clone();
        let s = self.profile.gen_skill;
        let mut applied: Option<Opt> = None;
        match feedback {
            Feedback::Optimization { opt: Some(o), .. } if o.applicable(task, &cfg) => {
                if rng.chance(self.profile.follow) {
                    o.apply(&mut cfg, task, gpu);
                    applied = Some(*o);
                } else {
                    // Unfaithful application: the Coder does *something*, just
                    // not what was asked (a hallucinated variant).
                    if let Some(alt) = random_applicable(task, &cfg, rng) {
                        alt.apply(&mut cfg, task, gpu);
                        applied = Some(alt);
                    }
                }
            }
            _ => {
                // Vague / absent guidance: unguided exploration. This is the
                // blind-search regime the paper contrasts with hardware-
                // guided iteration (§1 C3): it sometimes lands a useful move,
                // often thrashes the kernel sideways or backwards ("higher
                // hallucination", §2.2).
                if !cfg.coalesced && rng.chance(0.30 * s) {
                    // Coalescing is the first thing any unguided pass checks.
                    Opt::CoalesceAccesses.apply(&mut cfg, task, gpu);
                    applied = Some(Opt::CoalesceAccesses);
                } else if rng.chance(0.55 * s) {
                    if let Some(alt) = random_applicable(task, &cfg, rng) {
                        alt.apply(&mut cfg, task, gpu);
                        applied = Some(alt);
                    }
                } else if rng.chance(0.35) {
                    perturb(&mut cfg, rng);
                    cfg.legalize(gpu);
                    applied = None;
                }
            }
        }
        // Rewrite risk scales with how invasive the change is.
        let complexity = match applied {
            Some(
                Opt::UseTensorCores
                | Opt::UseSharedMemoryTiling
                | Opt::OnlineAlgorithm
                | Opt::AlgorithmicRewrite
                | Opt::WarpShuffleReduction,
            ) => 1.7,
            Some(_) => 1.0,
            None => 0.4,
        };
        let p = (0.04 + 0.16 * (1.0 - s)) * complexity * (0.5 + task.difficulty);
        if rng.chance(p) {
            cfg.bugs.push(rewrite_bug(rng));
        }
        cfg.legalize(gpu);
        let fb_json = feedback.to_json().to_string();
        let stats = self.stats_for_len(prompts::coder_optimization_len(gpu, prev, &fb_json));
        (cfg, stats)
    }
}

/// An unguided sideways rewrite: randomize one configuration axis. Unlike a
/// catalog transform this has no reason to help — it models speculative
/// rewrites that churn the kernel without addressing the real limiter.
pub fn perturb(cfg: &mut KernelConfig, rng: &mut Rng) {
    match rng.below(7) {
        0 => cfg.block_threads = *rng.choice(&[128, 256, 512, 1024]),
        1 => {
            cfg.tile_m = *rng.choice(&[16, 32, 64, 128]);
            cfg.tile_n = cfg.tile_m;
        }
        2 => cfg.vector_width = *rng.choice(&[1, 2, 4]),
        3 => cfg.unroll = *rng.choice(&[1, 2, 4, 8]),
        4 => cfg.regs_per_thread = rng.range_usize(32, 160) as u32,
        5 => cfg.syncs_per_tile = rng.range_usize(0, 8) as u32,
        _ => cfg.extra_global_passes = rng.range_usize(0, 2) as u32,
    }
}

/// A uniformly random still-applicable transform (the unguided move).
pub fn random_applicable(
    task: &TaskSpec,
    cfg: &KernelConfig,
    rng: &mut Rng,
) -> Option<Opt> {
    let options: Vec<Opt> = OPT_CATALOG
        .iter()
        .copied()
        .filter(|o| o.applicable(task, cfg))
        .collect();
    if options.is_empty() {
        None
    } else {
        Some(*rng.choice(&options))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::agents::profiles::{O3, QWQ_32B};
    use crate::gpu::RTX6000_ADA;
    use crate::tasks::{by_id, kernelbench};

    #[test]
    fn initial_correctness_rate_tracks_skill() {
        // o3 one-shot correctness should land near Table 1's 57.6%; QwQ far
        // below it.
        let tasks = kernelbench();
        let mut rng = Rng::new(7);
        let count_ok = |p, rng: &mut Rng| {
            let coder = Coder::new(p);
            tasks
                .iter()
                .filter(|t| {
                    let (cfg, _) = coder.initial(t, &RTX6000_ADA, rng);
                    !cfg.is_buggy()
                })
                .count() as f64
                / tasks.len() as f64
        };
        let o3 = count_ok(O3, &mut rng);
        let qwq = count_ok(QWQ_32B, &mut rng);
        assert!((0.40..=0.70).contains(&o3), "o3 one-shot correct {o3}");
        assert!(qwq < o3 - 0.15, "qwq {qwq} vs o3 {o3}");
    }

    #[test]
    fn correction_with_named_bug_usually_fixes() {
        let t = by_id("L1-95").unwrap();
        let coder = Coder::new(O3);
        let mut fixed = 0;
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let mut cfg = KernelConfig::naive();
            cfg.bugs.push(Bug::UninitValue);
            let fb = Feedback::Correction {
                critical_issue: "uninitialized value".into(),
                why_it_matters: "".into(),
                minimal_fix_hint: "".into(),
                bug: Some(Bug::UninitValue),
            };
            let (new, _) =
                coder.revise_correction(&t, &RTX6000_ADA, &cfg, &fb, "log", &mut rng);
            if !new.bugs.contains(&Bug::UninitValue) {
                fixed += 1;
            }
        }
        let rate = fixed as f64 / 200.0;
        assert!(rate > 0.7, "named-bug fix rate {rate}");
    }

    #[test]
    fn optimization_applies_named_opt_mostly_faithfully() {
        let t = by_id("L1-24").unwrap();
        let coder = Coder::new(O3);
        let mut faithful = 0;
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let cfg = KernelConfig::naive();
            let fb = Feedback::Optimization {
                bottleneck: "uncoalesced".into(),
                method: Opt::CoalesceAccesses.suggestion().into(),
                plan: "".into(),
                opt: Some(Opt::CoalesceAccesses),
                critical_metrics: vec![],
            };
            let (new, _) = coder.revise_optimization(&t, &RTX6000_ADA, &cfg, &fb, &mut rng);
            if new.coalesced {
                faithful += 1;
            }
        }
        assert!(faithful > 140, "faithful {faithful}/200");
    }

    #[test]
    fn deterministic_given_seed() {
        let t = by_id("L2-51").unwrap();
        let coder = Coder::new(O3);
        let (a, _) = coder.initial(&t, &RTX6000_ADA, &mut Rng::new(99));
        let (b, _) = coder.initial(&t, &RTX6000_ADA, &mut Rng::new(99));
        assert_eq!(a, b);
    }

    #[test]
    fn stats_count_prompt_tokens() {
        let t = by_id("L1-1").unwrap();
        let coder = Coder::new(O3);
        let (_, st) = coder.initial(&t, &RTX6000_ADA, &mut Rng::new(1));
        assert!(st.tokens_in > 100.0);
        assert_eq!(st.tokens_out, O3.gen_out_tokens);
    }
}
