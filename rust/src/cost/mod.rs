//! API-cost and wall-clock model (Table 3, Figure 6).
//!
//! Prices each agent call off the *rendered prompt tokens* and the profile's
//! completion size, and charges wall-clock for model latency, nvcc
//! compilation, test execution and NCU profiling. Full-set NCU profiling is
//! substantially slower than the curated subset (§3.6: ~40 min + ~$1 vs
//! 26.5 min + $0.30 per kernel).

use crate::agents::{CallStats, ModelProfile};

/// Environment timing constants (seconds). Defaults reproduce the paper's
/// per-kernel wall-clock on an RTX 6000 with o3 agents.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub compile_s: f64,
    pub exec_test_s: f64,
    pub ncu_subset_s: f64,
    pub ncu_full_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            compile_s: 25.0,
            exec_test_s: 8.0,
            ncu_subset_s: 30.0,
            ncu_full_s: 110.0,
        }
    }
}

impl CostModel {
    /// USD for one agent call.
    pub fn api_usd(&self, profile: &ModelProfile, stats: CallStats) -> f64 {
        stats.tokens_in / 1e6 * profile.usd_per_mtok_in
            + stats.tokens_out / 1e6 * profile.usd_per_mtok_out
    }
}

/// Running totals for one task's workflow.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostLedger {
    pub api_usd: f64,
    pub wall_s: f64,
    pub tokens_in: f64,
    pub tokens_out: f64,
    pub agent_calls: u32,
    pub profiles: u32,
    pub compiles: u32,
}

impl CostLedger {
    pub fn charge_call(&mut self, model: &CostModel, profile: &ModelProfile, st: CallStats) {
        self.api_usd += model.api_usd(profile, st);
        self.wall_s += profile.seconds_per_call;
        self.tokens_in += st.tokens_in;
        self.tokens_out += st.tokens_out;
        self.agent_calls += 1;
    }

    pub fn charge_compile(&mut self, model: &CostModel, compiled_ok: bool) {
        self.wall_s += model.compile_s;
        if compiled_ok {
            self.wall_s += model.exec_test_s;
        }
        self.compiles += 1;
    }

    pub fn charge_profile(&mut self, model: &CostModel, full: bool) {
        self.wall_s += if full { model.ncu_full_s } else { model.ncu_subset_s };
        self.profiles += 1;
    }

    pub fn merge(&mut self, other: &CostLedger) {
        self.api_usd += other.api_usd;
        self.wall_s += other.wall_s;
        self.tokens_in += other.tokens_in;
        self.tokens_out += other.tokens_out;
        self.agent_calls += other.agent_calls;
        self.profiles += other.profiles;
        self.compiles += other.compiles;
    }

    pub fn wall_min(&self) -> f64 {
        self.wall_s / 60.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::profiles::O3;

    #[test]
    fn o3_round_cost_matches_paper_scale() {
        // One CudaForge round: coder call + judge call + compile + exec + NCU.
        let m = CostModel::default();
        let mut ledger = CostLedger::default();
        ledger.charge_call(&m, &O3, CallStats { tokens_in: 2500.0, tokens_out: 2600.0 });
        ledger.charge_call(&m, &O3, CallStats { tokens_in: 2200.0, tokens_out: 700.0 });
        ledger.charge_compile(&m, true);
        ledger.charge_profile(&m, false);
        // 10 rounds should land near $0.30 and ~26.5 min (Table 3).
        let usd10 = ledger.api_usd * 10.0;
        let min10 = ledger.wall_min() * 10.0;
        assert!((0.2..=0.45).contains(&usd10), "usd {usd10}");
        assert!((20.0..=32.0).contains(&min10), "min {min10}");
    }

    #[test]
    fn full_profile_costs_more_time() {
        let m = CostModel::default();
        let mut a = CostLedger::default();
        let mut b = CostLedger::default();
        a.charge_profile(&m, false);
        b.charge_profile(&m, true);
        assert!(b.wall_s > a.wall_s * 3.0);
    }

    #[test]
    fn merge_accumulates() {
        let m = CostModel::default();
        let mut a = CostLedger::default();
        a.charge_compile(&m, true);
        let mut b = CostLedger::default();
        b.charge_compile(&m, false);
        b.merge(&a);
        assert_eq!(b.compiles, 2);
        assert!(b.wall_s > m.compile_s * 2.0);
    }
}
