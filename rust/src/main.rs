//! `cudaforge` — leader CLI for the CudaForge reproduction.
//!
//! Subcommands:
//!   run        optimize one task (e.g. `run --task L1-95 --gpu rtx6000`)
//!   suite      run a strategy over KernelBench or D*
//!   serve      replay Zipf traffic through the kernel-optimization service
//!   cluster    replay Zipf traffic over a sharded multi-tenant cluster
//!   autoscale  compare autoscaling policies across traffic scenarios
//!   lint       static-analyze a kernel candidate (rule scorecard via --table)
//!   bench      regenerate a paper table/figure (`--exp table1|...|all`)
//!   select     run the offline metric-selection pipeline (Algorithms 1-2)
//!   verify     execute every AOT artifact on PJRT vs its reference (pjrt)
//!   specs      print the GPU spec database
//!   trace      explain one fingerprint's causal story from a recorded trace
//!   version    print the build stamp (crate version + enabled features)
//!
//! Global flags: --seed N --threads N --rounds N --gpu KEY --quick
//!               --strategy NAME --coder MODEL --judge MODEL
//!               --artifacts DIR (enables the real-numerics oracle)
//! Serve flags:  --requests N --zipf S --capacity N
//!               --window N (host-side OS-thread batch size; never changes
//!               reported numbers — replay is event-driven)
//!               --interarrival SECS (mean Poisson arrival gap)
//!               --sim-workers N (simulated GPU fleet size)
//!               --queue-depth N (shed batch work past this backlog)
//!               --slo I,S,B (per-priority latency targets, seconds)
//!               --snapshot PATH (restore before / save after the replay)
//! Cluster flags: serve flags (capacity/sim-workers/queue-depth are *per
//!               node*) plus --nodes N --tenants NAME:W,NAME:W --no-quotas
//!               --transfer-latency SECS --warm-locality-margin M
//!               --fail-node N --fail-at SECS (node N drops at SECS)
//!               --join-node N --join-at SECS (node N enters, empty, at
//!               SECS; with no prior --fail-node N it starts outside the
//!               cluster)
//!               --snapshot DIR (shard-aware snapshot directory: restore
//!               before the replay if its manifest exists, save after)
//! Autoscale flags: cluster flags (minus --fail/--join scheduling and
//!               --snapshot) plus --policy static|threshold|target-tracking
//!               (comma list or `all`) --scenario steady|diurnal|
//!               flash-crowd|mass-interruption|straggler (comma list or
//!               `all`) --tick SECS (decision-tick period)
//!               --provision-delay SECS (join lead time) --min-nodes N
//!               --max-nodes N (fleet size bounds; slots above --nodes
//!               start outside the cluster)
//! Lint flags:   --task ID --gpu KEY --seed N (lint the round-1 candidate)
//!               --bug NAME (inject a named defect first) --json
//!               --table --corpus N (score every rule on a seeded corpus,
//!               writing results/lint.csv)
//!               run/serve/cluster/autoscale accept --lint (pre-compile
//!               analyzer gate) with --lint-confidence T --lint-repairs N
//! Observability: serve/cluster/autoscale accept --trace DIR (record the
//!               deterministic flight-recorder stream and write
//!               events.jsonl + chrome_trace.json + metrics.csv into DIR)
//!               and --profile (host wall-clock stage breakdown printed
//!               after the replay)
//! Trace flags:  --explain FINGERPRINT (reconstruct that request's causal
//!               story) --dir DIR (trace directory, default `trace`)
//!
//! Every subcommand rejects flags it does not understand (exit 2 + usage)
//! instead of silently falling back to defaults.

use cudaforge::agents::profiles;
use cudaforge::cluster::{
    snapshot as cluster_snapshot, ClusterConfig, ClusterService, MembershipEvent,
    RebalanceKind, TenantSpec,
};
use cudaforge::coordinator::{default_threads, run_suite};
use cudaforge::gpu;
use cudaforge::report::{self, Ctx};
use cudaforge::runtime;
use cudaforge::service::cache::ResultCache;
use cudaforge::service::traffic::{try_generate, TrafficConfig};
use cudaforge::service::{KernelService, ServiceConfig, SloTargets};
use cudaforge::tasks;
use cudaforge::trace::{profile::Profiler, NullSink, Observer, Recorder, TraceMeta};
use cudaforge::util::cli::Args;
use cudaforge::workflow::{
    run_task, CorrectnessOracle, NoOracle, Strategy, WorkflowConfig, ALL_STRATEGIES,
};

fn strategy_or_exit(name: &str) -> Strategy {
    Strategy::by_name(name).unwrap_or_else(|| {
        eprintln!("error: unknown strategy '{name}'");
        eprintln!("valid strategies:");
        for s in ALL_STRATEGIES {
            eprintln!("  {:<14} {}", s.cli_key(), s.name());
        }
        std::process::exit(2);
    })
}

/// Build the real-numerics oracle if artifacts exist (or were requested).
fn build_oracle(args: &Args) -> Box<dyn CorrectnessOracle> {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let explicit = args.get("artifacts").is_some();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        if explicit {
            eprintln!("error: no manifest in {dir}; run `make artifacts`");
            std::process::exit(2);
        }
        eprintln!("[no artifacts found — correctness uses the modelled check; run `make artifacts` for real numerics]");
        return Box::new(NoOracle);
    }
    match runtime::try_real_oracle(&dir, 42) {
        Some(oracle) => {
            let n = oracle.matrix().verdicts.len();
            assert!(oracle.matrix().is_consistent(), "artifact verdicts inconsistent");
            eprintln!("[real-numerics oracle: {n} artifacts verified on PJRT]");
            Box::new(oracle)
        }
        None => {
            if explicit && !cfg!(feature = "pjrt") {
                eprintln!(
                    "error: --artifacts given but this binary was built without the \
                     `pjrt` feature (cargo build --features pjrt)"
                );
                std::process::exit(2);
            }
            eprintln!("warning: oracle unavailable; falling back to modelled check");
            Box::new(NoOracle)
        }
    }
}

fn gpu_or_exit(args: &Args) -> &'static gpu::GpuSpec {
    gpu::by_key(args.get_or("gpu", "rtx6000")).unwrap_or_else(|| {
        eprintln!("error: unknown gpu; options: rtx6000 rtx4090 rtx3090 a100 h100 h200");
        std::process::exit(2);
    })
}

/// The `--lint` gate shared by run/serve/cluster/autoscale: repair
/// threshold and per-round repair budget for the pre-compile analyzer.
fn lint_gate_from(args: &Args) -> cudaforge::workflow::LintGate {
    let confidence = args.get_f64("lint-confidence", 0.9);
    if !(0.0..=1.0).contains(&confidence) {
        eprintln!("error: --lint-confidence must be in [0, 1], got {confidence}");
        std::process::exit(2);
    }
    cudaforge::workflow::LintGate {
        repair_confidence: confidence,
        max_repairs_per_round: args.get_usize("lint-repairs", 2) as u32,
    }
}

/// The `--trace DIR` / `--profile` pair shared by the replay subcommands
/// (`serve`, `cluster`, `autoscale`).
struct TraceOpts {
    dir: Option<String>,
    profile: bool,
}

impl TraceOpts {
    fn from(args: &Args) -> TraceOpts {
        TraceOpts {
            dir: args.get("trace").map(|s| s.to_string()),
            profile: args.flag("profile"),
        }
    }

    /// Write the recorded stream's three artifacts (under `DIR/sub` when
    /// several replays share one `--trace` invocation) and say what
    /// landed. A write failure is a warning, not an exit: the replay's
    /// report already printed and is the primary deliverable.
    fn write(&self, sub: Option<&str>, meta: &TraceMeta, events: &[cudaforge::trace::TraceEvent]) {
        let Some(dir) = &self.dir else { return };
        let path = match sub {
            Some(s) => std::path::Path::new(dir).join(s),
            None => std::path::PathBuf::from(dir),
        };
        match cudaforge::trace::write_dir(&path, meta, events) {
            Ok(()) => eprintln!(
                "[trace: {} events -> {}/{{events.jsonl,chrome_trace.json,metrics.csv}}]",
                events.len(),
                path.display()
            ),
            Err(e) => eprintln!("warning: trace not written: {e:#}"),
        }
    }

    /// Print the profiler's stage table (no-op when `--profile` was off).
    fn report(&self, profiler: Option<Profiler>) {
        if let Some(p) = profiler {
            println!("{}", p.finish().table().render());
        }
    }
}

fn workflow_from(args: &Args) -> WorkflowConfig {
    let gpu = gpu_or_exit(args);
    let strategy = strategy_or_exit(args.get_or("strategy", "cudaforge"));
    let mut wf = WorkflowConfig::cudaforge(gpu, args.get_u64("seed", 2024))
        .with_strategy(strategy)
        .with_rounds(args.get_usize("rounds", 10));
    if let Some(m) = args.get("coder") {
        wf.coder = *profiles::by_name(m).expect("unknown coder model");
    }
    if let Some(m) = args.get("judge") {
        wf.judge = *profiles::by_name(m).expect("unknown judge model");
    }
    if args.flag("lint") {
        wf = wf.with_lint(lint_gate_from(args));
    }
    wf
}

/// Parse `--slo I,S,B` (interactive/standard/batch latency targets, secs).
fn slo_from(arg: &str) -> SloTargets {
    let parts: Vec<f64> = arg
        .split(',')
        .map(|p| {
            p.trim().parse().unwrap_or_else(|_| {
                eprintln!("error: --slo wants three numbers, got '{p}' in '{arg}'");
                std::process::exit(2);
            })
        })
        .collect();
    if parts.len() != 3 {
        eprintln!(
            "error: --slo wants interactive,standard,batch seconds (e.g. 120,7200,86400)"
        );
        std::process::exit(2);
    }
    if parts.iter().any(|s| !s.is_finite() || *s <= 0.0) {
        eprintln!("error: --slo targets must be finite and > 0 seconds, got '{arg}'");
        std::process::exit(2);
    }
    SloTargets { interactive_s: parts[0], standard_s: parts[1], batch_s: parts[2] }
}

/// Parse `--tenants NAME:WEIGHT,NAME:WEIGHT` (weight defaults to 1).
fn tenants_from(arg: &str) -> Vec<TenantSpec> {
    let mut out = Vec::new();
    for part in arg.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => {
                let w: f64 = w.trim().parse().unwrap_or_else(|_| {
                    eprintln!("error: --tenants wants NAME:WEIGHT, got '{part}'");
                    std::process::exit(2);
                });
                (n.trim(), w)
            }
            None => (part, 1.0),
        };
        if name.is_empty() || !(weight.is_finite() && weight > 0.0) {
            eprintln!("error: --tenants entry '{part}' needs a name and a positive weight");
            std::process::exit(2);
        }
        out.push(TenantSpec::new(name, weight));
    }
    if out.is_empty() {
        eprintln!("error: --tenants names no tenants (e.g. alpha:3,beta:1)");
        std::process::exit(2);
    }
    out
}

/// Parse the front-door rate-limiter pair shared by `serve` and the
/// cluster-style subcommands. Validated here (exit 2) so the limiter's own
/// asserts can never fire from the CLI path: the rate must be finite and
/// > 0, the burst finite and >= 1, and a burst without a rate is a mistake
/// (no rate means no limiter, silently ignoring the burst).
fn rate_limit_args(args: &Args) -> (Option<f64>, Option<f64>) {
    let parse = |flag: &str| -> Option<f64> {
        args.get(flag).map(|v| {
            v.parse::<f64>().unwrap_or_else(|_| {
                eprintln!("error: --{flag} wants a number, got '{v}'");
                std::process::exit(2);
            })
        })
    };
    let rate = parse("tenant-rate");
    if let Some(r) = rate {
        if !r.is_finite() || r <= 0.0 {
            eprintln!("error: --tenant-rate must be finite and > 0, got {r}");
            std::process::exit(2);
        }
    }
    let burst = parse("tenant-burst");
    if let Some(b) = burst {
        if !b.is_finite() || b < 1.0 {
            eprintln!("error: --tenant-burst must be finite and >= 1, got {b}");
            std::process::exit(2);
        }
        if rate.is_none() {
            eprintln!("error: --tenant-burst needs --tenant-rate (no rate, no limiter)");
            std::process::exit(2);
        }
    }
    (rate, burst)
}

/// Everything the cluster-style subcommands share: the traffic model and
/// the deployment config, built from the same flags and defaults — which is
/// what makes `autoscale` under a do-nothing policy reproduce `cluster`
/// bit for bit.
struct ClusterSetup {
    seed: u64,
    traffic: TrafficConfig,
    config: ClusterConfig,
}

fn cluster_setup(args: &Args) -> ClusterSetup {
    let seed = args.get_u64("seed", 7);
    let tenants = tenants_from(args.get_or("tenants", "alpha:3,beta:1"));
    let traffic = TrafficConfig {
        requests: args.get_usize("requests", 2000),
        zipf_s: args.get_f64("zipf", 1.1),
        mean_interarrival_s: args.get_f64("interarrival", 90.0),
        seed,
        tenant_mix: tenants.iter().map(|t| (t.name.clone(), t.weight)).collect(),
        ..TrafficConfig::default()
    };
    let mut service = ServiceConfig {
        capacity: args.get_usize("capacity", 512),
        window: args.get_usize("window", 32),
        threads: args.get_usize("threads", default_threads()),
        sim_workers: args.get_usize("sim-workers", 2),
        queue_depth: args.get_usize("queue-depth", 16),
        strategy: strategy_or_exit(args.get_or("strategy", "cudaforge")),
        rounds: args.get_usize("rounds", 10),
        seed,
        ..ServiceConfig::default()
    };
    if let Some(slo) = args.get("slo") {
        service.slo = slo_from(slo);
    }
    if let Some(m) = args.get("coder") {
        service.coder = *profiles::by_name(m).unwrap_or_else(|| {
            eprintln!("error: unknown coder model '{m}'");
            std::process::exit(2);
        });
    }
    if let Some(m) = args.get("judge") {
        service.judge = *profiles::by_name(m).unwrap_or_else(|| {
            eprintln!("error: unknown judge model '{m}'");
            std::process::exit(2);
        });
    }
    if args.flag("lint") {
        service.lint = Some(lint_gate_from(args));
    }
    service.fair_dispatch = !args.flag("no-fair-dispatch");
    let (rate, burst) = rate_limit_args(args);
    service.tenant_rate = rate;
    service.tenant_burst = burst;
    let nodes = args.get_usize("nodes", 4).max(1);
    let node_arg = |flag: &str| -> Option<usize> {
        args.get(flag).map(|v| {
            let node: usize = v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{flag} wants a node index, got '{v}'");
                std::process::exit(2);
            });
            if node >= nodes {
                eprintln!(
                    "error: --{flag} {node} is out of range for --nodes {nodes} \
                     (valid indices: 0..{})",
                    nodes - 1
                );
                std::process::exit(2);
            }
            node
        })
    };
    // Simulated times and margins must be finite and non-negative: a NaN
    // instant would never fire as an event, silently dropping the scenario.
    let nonneg_arg = |flag: &str, default: f64| -> f64 {
        let v = args.get_f64(flag, default);
        if !v.is_finite() || v < 0.0 {
            eprintln!("error: --{flag} must be a finite value >= 0, got {v}");
            std::process::exit(2);
        }
        v
    };
    let mut events = Vec::new();
    if let Some(node) = node_arg("fail-node") {
        events.push(MembershipEvent::fail(node, nonneg_arg("fail-at", 0.0)));
    }
    if let Some(node) = node_arg("join-node") {
        events.push(MembershipEvent::join(node, nonneg_arg("join-at", 0.0)));
    }
    let config = ClusterConfig {
        service,
        nodes,
        tenants,
        tenant_quotas: !args.flag("no-quotas"),
        transfer_latency_s: nonneg_arg("transfer-latency", 30.0),
        warm_locality_margin: nonneg_arg("warm-locality-margin", 0.0),
        events,
        ..ClusterConfig::default()
    };
    ClusterSetup { seed, traffic, config }
}

fn cluster(args: &Args) {
    let oracle = build_oracle(args);
    let suite = tasks::kernelbench();
    let ClusterSetup { seed, traffic, config } = cluster_setup(args);
    println!(
        "cluster: {} nodes x {} sim GPUs | {} tenants (quotas {}) | cache {}/shard | \
         queue depth {} | {} requests (zipf s={}, seed {})",
        config.nodes,
        config.service.sim_workers,
        config.tenants.len(),
        if config.tenant_quotas { "on" } else { "off" },
        config.service.capacity,
        config.service.queue_depth,
        traffic.requests,
        traffic.zipf_s,
        seed,
    );
    for ev in &config.events {
        match ev.change {
            cudaforge::cluster::MembershipChange::Fail => {
                println!("  [failure scheduled: node {} drops at t={}s]", ev.node, ev.at_s)
            }
            cudaforge::cluster::MembershipChange::Join => println!(
                "  [join scheduled: node {} enters (empty) at t={}s]",
                ev.node, ev.at_s
            ),
        }
    }
    let trace = try_generate(suite.len(), &traffic).unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    });
    let snapshot_dir = args.get("snapshot").map(|s| s.to_string());
    let mut svc = match &snapshot_dir {
        Some(dir) if cluster_snapshot::exists(dir) => {
            match ClusterService::restore(config, dir) {
                Ok((svc, restore_rb)) => {
                    let entries: usize =
                        (0..svc.config.nodes).map(|i| svc.cache(i).len()).sum();
                    eprintln!(
                        "[restored {entries} cached results across {} shards from \
                         {dir} (epoch {})]",
                        svc.config.nodes,
                        svc.epoch()
                    );
                    if let Some(rb) = restore_rb {
                        println!(
                            "restore rebalance: snapshot was laid out for {} nodes; \
                             {} entries moved to their new owners ({:.0}s transfer \
                             spend), {} unplaceable",
                            rb.node, rb.entries_moved, rb.transfer_s, rb.cache_entries_lost,
                        );
                    }
                    svc
                }
                Err(e) => {
                    // Print the whole anyhow chain: the io error behind an
                    // unreadable file, or the manifest cross-check naming
                    // the offending path. Match the restore error's own
                    // remediation phrase to decide whether the version hint
                    // applies.
                    let chain = format!("{e:#}");
                    eprintln!("error: cannot restore cluster snapshot: {chain}");
                    if chain.contains("delete the snapshot and re-warm") {
                        eprintln!(
                            "hint: {dir} was written under an incompatible snapshot \
                             format; delete the directory (the cluster re-warms from \
                             traffic) or rerun with a matching build"
                        );
                    }
                    std::process::exit(2);
                }
            }
        }
        _ => ClusterService::new(config),
    };
    let topts = TraceOpts::from(args);
    let mut recorder = Recorder::default();
    let mut null = NullSink;
    let mut obs = if topts.dir.is_some() {
        Observer::new(&mut recorder)
    } else {
        Observer::new(&mut null)
    };
    let t0 = std::time::Instant::now();
    if topts.profile {
        obs.profiler = Some(Profiler::new());
    }
    let report = svc.replay_observed(&trace, &suite, oracle.as_ref(), &mut obs);
    let profiler = obs.profiler.take();
    let mut meta =
        TraceMeta::new("cluster", svc.config.nodes, svc.config.service.sim_workers);
    meta.tenants = svc.config.tenants.iter().map(|t| t.name.clone()).collect();
    topts.write(None, &meta, &recorder.events);
    let ctx = Ctx {
        seed,
        results_dir: args.get_or("out", "results").to_string(),
        ..Ctx::default()
    };
    report::cluster_report(&ctx, &report);
    println!(
        "replay wall {:.2}s | {} runs executed across {} nodes, {:.1}% served from \
         cache/in-flight, {} shed ({} by tenant quota), {} cross-node warm starts",
        t0.elapsed().as_secs_f64(),
        report.overall.flights_run,
        report.nodes,
        report.overall.hit_rate * 100.0,
        report.overall.rejected,
        report.quota_shed,
        report.cross_node_warm,
    );
    for rb in &report.rebalances {
        match rb.kind {
            RebalanceKind::NodeFailure => println!(
                "node {} failed at {}s: lost {} cached entries; {} requests rehashed \
                 to survivors; {} lost keys re-ran cold (${:.2} re-spent)",
                rb.node,
                rb.at_s,
                rb.cache_entries_lost,
                rb.rehashed_requests,
                rb.remissed_flights,
                rb.remiss_api_usd,
            ),
            RebalanceKind::NodeJoin => println!(
                "node {} joined at {}s: {} entries warm-refilled from surviving \
                 shards ({:.0}s transfer spend); {} requests rehashed to it; {} keys \
                 re-ran inside the transfer gap (${:.2} re-spent)",
                rb.node,
                rb.at_s,
                rb.entries_moved,
                rb.transfer_s,
                rb.rehashed_requests,
                rb.remissed_flights,
                rb.remiss_api_usd,
            ),
            // Restore-time movement was printed when the snapshot loaded.
            RebalanceKind::SnapshotRestore => {}
        }
    }
    topts.report(profiler);
    if let Some(dir) = &snapshot_dir {
        match svc.snapshot(dir) {
            Ok(m) => eprintln!(
                "[snapshot: {} entries across {} shards -> {dir} (epoch {})]",
                m.shards.iter().map(|s| s.entries).sum::<usize>(),
                m.nodes,
                m.epoch,
            ),
            Err(e) => eprintln!("warning: cluster snapshot not saved: {e:#}"),
        }
    }
}

/// Parse a comma-separated `--policy` / `--scenario` list, or `all`.
fn names_from<'a>(arg: &'a str, flag: &str, all: &[&'a str], valid: &[&str]) -> Vec<&'a str> {
    if arg == "all" {
        return all.to_vec();
    }
    arg.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| {
            if !valid.contains(&p) {
                eprintln!("error: --{flag} '{p}' unknown; options: {} or all", valid.join(" "));
                std::process::exit(2);
            }
            p
        })
        .collect()
}

fn autoscale(args: &Args) {
    use cudaforge::cluster::autoscale::{policy_by_name, AutoscaleConfig, POLICY_NAMES};
    use cudaforge::cluster::{AutoscaleRun, Scenario};

    let oracle = build_oracle(args);
    let suite = tasks::kernelbench();
    let ClusterSetup { seed, traffic, config: base } = cluster_setup(args);

    let scenario_names: Vec<&'static str> =
        Scenario::all().iter().map(|s| s.name()).collect();
    let policies = names_from(
        args.get_or("policy", "all"),
        "policy",
        &POLICY_NAMES,
        &POLICY_NAMES,
    );
    let scenarios: Vec<Scenario> =
        names_from(args.get_or("scenario", "all"), "scenario", &scenario_names, &scenario_names)
            .into_iter()
            .map(|n| Scenario::by_name(n).expect("validated above"))
            .collect();

    let start_alive = base.nodes;
    let min_nodes = args.get_usize("min-nodes", 1).max(1);
    let max_nodes = args.get_usize("max-nodes", start_alive).max(min_nodes);
    // Slots = the largest fleet any policy may reach; slots past the
    // starting size begin outside the cluster, waiting for a join.
    let slots = start_alive.max(max_nodes);
    let tick_s = args.get_f64("tick", 3600.0);
    let provision_delay_s = args.get_f64("provision-delay", 600.0);
    if !(tick_s.is_finite() && tick_s > 0.0) {
        eprintln!("error: --tick must be a finite value > 0 seconds, got {tick_s}");
        std::process::exit(2);
    }
    if !(provision_delay_s.is_finite() && provision_delay_s >= 0.0) {
        eprintln!(
            "error: --provision-delay must be a finite value >= 0 seconds, \
             got {provision_delay_s}"
        );
        std::process::exit(2);
    }

    let base_trace = try_generate(suite.len(), &traffic).unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    });
    println!(
        "autoscale: {} policies x {} scenarios | fleet {}..{} nodes (start {}) | \
         tick {}s, provisioning delay {}s | {} requests (seed {})",
        policies.len(),
        scenarios.len(),
        min_nodes,
        slots,
        start_alive,
        tick_s,
        provision_delay_s,
        traffic.requests,
        seed,
    );

    let ctx = Ctx {
        seed,
        results_dir: args.get_or("out", "results").to_string(),
        ..Ctx::default()
    };
    let topts = TraceOpts::from(args);
    // Several (policy, scenario) replays can share one `--trace` run: each
    // combination records into its own `DIR/<policy>-<scenario>/` subtree
    // (a single combination writes straight into DIR).
    let multi_combo = policies.len() * scenarios.len() > 1;
    let tenant_names: Vec<String> = base.tenants.iter().map(|t| t.name.clone()).collect();
    let mut rows: Vec<report::FrontierRow> = Vec::new();
    for scenario in &scenarios {
        let mut trace = base_trace.clone();
        scenario.shape_arrivals(&mut trace);
        let span_s = trace.last().map(|r| r.arrival_s).unwrap_or(0.0);
        for pname in &policies {
            let policy = policy_by_name(pname).expect("validated above");
            let mut config = base.clone();
            config.nodes = slots;
            config.initial_dead = (start_alive..slots).collect();
            config.node_service_multipliers = scenario.service_multipliers(slots);
            config.events.extend(scenario.membership_events(start_alive, span_s));
            let mut run = AutoscaleRun::new(
                policy,
                AutoscaleConfig { tick_s, provision_delay_s, min_nodes, max_nodes },
            );
            let mut recorder = Recorder::default();
            let mut null = NullSink;
            let mut obs = if topts.dir.is_some() {
                Observer::new(&mut recorder)
            } else {
                Observer::new(&mut null)
            };
            let t0 = std::time::Instant::now();
            if topts.profile {
                obs.profiler = Some(Profiler::new());
            }
            // Scenario-scripted events merge with any --fail-node/--join-node
            // flags; an inconsistent combination is a user error, not a bug.
            let mut svc = ClusterService::try_new(config).unwrap_or_else(|e| {
                eprintln!("error: {e:#}");
                std::process::exit(2);
            });
            let report = svc.replay_autoscaled_observed(
                &trace,
                &suite,
                oracle.as_ref(),
                &mut run,
                &mut obs,
            );
            let profiler = obs.profiler.take();
            let mut meta = TraceMeta::new("cluster", slots, base.service.sim_workers);
            meta.tenants = tenant_names.clone();
            let sub = format!("{pname}-{}", scenario.name());
            topts.write(multi_combo.then_some(sub.as_str()), &meta, &recorder.events);
            topts.report(profiler);
            println!(
                "  {pname} on {}: {} ticks, {} joins / {} fails | {:.2} node-hrs | \
                 {} shed | wall {:.2}s",
                scenario.name(),
                run.ticks,
                run.joins(),
                run.fails(),
                report.node_hours,
                report.overall.rejected,
                t0.elapsed().as_secs_f64(),
            );
            // A single (policy, scenario) combination is a plain cluster
            // replay with the policy in the loop: persist the full cluster
            // report too, so `autoscale --policy static --scenario steady`
            // writes a cluster.csv bit-identical to `cluster`'s (CI checks
            // exactly that).
            if policies.len() == 1 && scenarios.len() == 1 {
                report::cluster_report(&ctx, &report);
            }
            rows.push(report::FrontierRow {
                policy: pname.to_string(),
                scenario: scenario.name().to_string(),
                joins: run.joins(),
                fails: run.fails(),
                report,
            });
        }
    }
    println!("{}", report::frontier_table(&rows).render());
    report::frontier_report(&ctx, &rows);
}

fn serve(args: &Args) {
    let oracle = build_oracle(args);
    let suite = tasks::kernelbench();
    let seed = args.get_u64("seed", 7);
    let traffic = TrafficConfig {
        requests: args.get_usize("requests", 2000),
        zipf_s: args.get_f64("zipf", 1.1),
        mean_interarrival_s: args.get_f64("interarrival", 90.0),
        seed,
        ..TrafficConfig::default()
    };
    let mut config = ServiceConfig {
        capacity: args.get_usize("capacity", 1024),
        window: args.get_usize("window", 32),
        threads: args.get_usize("threads", default_threads()),
        sim_workers: args.get_usize("sim-workers", 8),
        queue_depth: args.get_usize("queue-depth", usize::MAX),
        strategy: strategy_or_exit(args.get_or("strategy", "cudaforge")),
        rounds: args.get_usize("rounds", 10),
        seed,
        ..ServiceConfig::default()
    };
    if let Some(slo) = args.get("slo") {
        config.slo = slo_from(slo);
    }
    if let Some(m) = args.get("coder") {
        config.coder = *profiles::by_name(m).unwrap_or_else(|| {
            eprintln!("error: unknown coder model '{m}'");
            std::process::exit(2);
        });
    }
    if let Some(m) = args.get("judge") {
        config.judge = *profiles::by_name(m).unwrap_or_else(|| {
            eprintln!("error: unknown judge model '{m}'");
            std::process::exit(2);
        });
    }
    if args.flag("lint") {
        config.lint = Some(lint_gate_from(args));
    }
    config.fair_dispatch = !args.flag("no-fair-dispatch");
    let (rate, burst) = rate_limit_args(args);
    config.tenant_rate = rate;
    config.tenant_burst = burst;
    let snapshot = args.get("snapshot").map(|s| s.to_string());

    let mut svc = match &snapshot {
        Some(path) if std::path::Path::new(path).exists() => {
            match ResultCache::restore(path, config.capacity) {
                Ok(cache) => {
                    eprintln!("[restored {} cached results from {path}]", cache.len());
                    KernelService::with_cache(config, cache)
                }
                Err(e) => {
                    // The alternate format prints the whole anyhow chain —
                    // the io error behind an unreadable file, or the
                    // version-header diagnosis behind an incompatible
                    // snapshot. Match the restore error's own remediation
                    // phrase (not a bare substring a *path* could contain)
                    // to decide whether the version hint applies.
                    let chain = format!("{e:#}");
                    eprintln!("error: cannot restore cache snapshot: {chain}");
                    if chain.contains("delete the snapshot and re-warm") {
                        eprintln!(
                            "hint: {path} was written under a different fingerprint \
                             scheme; delete it (the cache re-warms from traffic) or \
                             rerun with a matching build"
                        );
                    }
                    std::process::exit(2);
                }
            }
        }
        _ => KernelService::new(config),
    };

    println!(
        "serving {} requests (zipf s={}, seed {}, mean gap {}s) over {} tasks | \
         cache {} | {} sim GPU workers | host batch window {}",
        traffic.requests,
        traffic.zipf_s,
        seed,
        traffic.mean_interarrival_s,
        suite.len(),
        svc.config.capacity,
        svc.config.sim_workers,
        svc.config.window,
    );
    let trace = try_generate(suite.len(), &traffic).unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    });
    let topts = TraceOpts::from(args);
    let mut recorder = Recorder::default();
    let mut null = NullSink;
    let mut obs = if topts.dir.is_some() {
        Observer::new(&mut recorder)
    } else {
        Observer::new(&mut null)
    };
    let t0 = std::time::Instant::now();
    if topts.profile {
        obs.profiler = Some(Profiler::new());
    }
    let report = svc.replay_observed(&trace, &suite, oracle.as_ref(), &mut obs);
    let profiler = obs.profiler.take();
    let meta = TraceMeta::new("service", 1, svc.config.sim_workers);
    topts.write(None, &meta, &recorder.events);
    let ctx = Ctx {
        seed,
        results_dir: args.get_or("out", "results").to_string(),
        ..Ctx::default()
    };
    report::service_report(&ctx, &report);
    let rounds = report::mean_rounds;
    println!(
        "replay wall {:.2}s | {} runs executed, {:.1}% served from cache/in-flight, \
         {} shed | warm runs reached best in {} mean rounds vs {} cold",
        t0.elapsed().as_secs_f64(),
        report.flights_run,
        report.hit_rate * 100.0,
        report.rejected,
        rounds(report.mean_rounds_to_best_warm),
        rounds(report.mean_rounds_to_best_cold),
    );
    for c in &report.per_priority {
        println!(
            "  {:<11} p50 {:.1}m p95 {:.1}m p99 {:.1}m | SLO <= {}s attained {:.1}% | \
             {} requests, {} rejected",
            c.priority.name(),
            c.p50_latency_s / 60.0,
            c.p95_latency_s / 60.0,
            c.p99_latency_s / 60.0,
            c.slo_target_s,
            c.slo_attainment * 100.0,
            c.requests,
            c.rejected,
        );
    }
    topts.report(profiler);
    if let Some(path) = &snapshot {
        match svc.cache().snapshot(path) {
            Ok(()) => eprintln!("[snapshot: {} entries -> {path}]", svc.cache().len()),
            Err(e) => eprintln!("warning: cache snapshot not saved: {e:#}"),
        }
    }
}

/// `cudaforge lint` — run the static analyzer standalone. Two modes:
/// lint one Coder candidate (optionally with an injected defect), or score
/// every rule over the seeded corpus with `--table`. Always exits 0: the
/// diagnostics are the output, not a verdict.
fn lint_cmd(args: &Args) {
    use cudaforge::analysis;
    use cudaforge::kernel::{Bug, ALL_BUGS};
    use cudaforge::util::json::Json;

    let gpu = gpu_or_exit(args);
    let seed = args.get_u64("seed", 2024);

    if args.flag("table") {
        let n = args.get_usize("corpus", 250);
        let corpus = analysis::corpus(gpu, seed, n);
        let scores = analysis::evaluate(gpu, &corpus);
        println!(
            "lint: scoring {} rules over a {}-config corpus (gpu {}, seed {seed})",
            analysis::ALL_RULES.len(),
            corpus.len(),
            gpu.key,
        );
        let ctx = Ctx {
            seed,
            results_dir: args.get_or("out", "results").to_string(),
            ..Ctx::default()
        };
        report::lint_report(&ctx, &scores);
        return;
    }

    let id = args.get_or("task", "L1-95");
    let task = tasks::by_id(id).unwrap_or_else(|| {
        eprintln!("error: unknown task {id}");
        std::process::exit(2);
    });
    let coder = *profiles::by_name(args.get_or("coder", "o3")).unwrap_or_else(|| {
        eprintln!("error: unknown coder model");
        std::process::exit(2);
    });
    let mut cfg = analysis::round_one_candidate(coder, &task, gpu, seed);
    if let Some(name) = args.get("bug") {
        let bug = Bug::by_name(name).unwrap_or_else(|| {
            eprintln!("error: unknown bug '{name}'; options:");
            for b in ALL_BUGS {
                eprintln!("  {}", b.name());
            }
            std::process::exit(2);
        });
        if !cfg.bugs.contains(&bug) {
            cfg.bugs.push(bug);
        }
    }
    let diags = analysis::lint(&task, gpu, &cfg);
    if args.flag("json") {
        println!("{}", Json::Arr(diags.iter().map(|d| d.to_json()).collect()));
        return;
    }
    println!(
        "lint: {} ({}) on {} | seed {seed} | {} diagnostic(s)",
        task.id(),
        task.name,
        gpu.key,
        diags.len(),
    );
    for d in &diags {
        println!("  {}", d.render());
    }
    if diags.is_empty() {
        println!("  clean: no rule fired on this candidate");
    }
}

/// `cudaforge trace` — explain-mode over a recorded flight-recorder
/// directory: reconstruct one fingerprint's causal story from
/// `DIR/events.jsonl`.
fn trace_cmd(args: &Args) {
    let dir = args.get_or("dir", "trace");
    let Some(fp) = args.get("explain") else {
        eprintln!(
            "error: trace wants --explain FINGERPRINT (16 hex digits, as printed \
             in reports and trace events) and optionally --dir DIR"
        );
        std::process::exit(2);
    };
    match cudaforge::trace::explain::explain_dir(std::path::Path::new(dir), fp) {
        Ok(story) => println!("{story}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            eprintln!("hint: record a trace first, e.g. `cudaforge cluster --trace {dir}`");
            std::process::exit(2);
        }
    }
}

/// Exit 2 with usage when the invocation carries flags this subcommand
/// does not understand — a typo'd flag must fail loudly, not silently
/// fall back to its default.
fn reject_unknown(args: &Args, known: &[&str]) {
    let unknown = args.unknown(known);
    if unknown.is_empty() {
        return;
    }
    let list: Vec<String> = unknown.iter().map(|f| format!("--{f}")).collect();
    eprintln!("error: unknown flag(s) for this subcommand: {}\n", list.join(" "));
    usage();
    std::process::exit(2);
}

/// Flags understood by `serve` (the single-node replay).
const SERVE_FLAGS: &[&str] = &[
    "artifacts", "capacity", "coder", "interarrival", "judge", "lint",
    "lint-confidence", "lint-repairs", "no-fair-dispatch", "out", "profile",
    "queue-depth", "requests", "rounds", "seed", "sim-workers", "slo",
    "snapshot", "strategy", "tenant-burst", "tenant-rate", "threads",
    "trace", "window", "zipf",
];

/// Flags `cluster_setup` (shared by `cluster` and `autoscale`) parses,
/// plus the oracle/report/trace wiring both subcommands share.
const CLUSTER_SETUP_FLAGS: &[&str] = &[
    "artifacts", "capacity", "coder", "fail-at", "fail-node", "interarrival",
    "join-at", "join-node", "judge", "lint", "lint-confidence",
    "lint-repairs", "no-fair-dispatch", "no-quotas", "nodes", "out",
    "profile", "queue-depth", "requests", "rounds", "seed", "sim-workers",
    "slo", "strategy", "tenant-burst", "tenant-rate", "tenants", "threads",
    "trace", "transfer-latency", "warm-locality-margin", "window", "zipf",
];

/// `autoscale`'s additions on top of [`CLUSTER_SETUP_FLAGS`].
const AUTOSCALE_EXTRA_FLAGS: &[&str] =
    &["max-nodes", "min-nodes", "policy", "provision-delay", "scenario", "tick"];

fn usage() {
    println!("cudaforge {} — CudaForge reproduction CLI", cudaforge::version());
    println!("usage: cudaforge <run|suite|serve|cluster|autoscale|lint|bench|select|verify|specs|trace|version> [flags]");
    println!("  run    --task L1-95 [--gpu rtx6000 --strategy cudaforge --rounds 10]");
    println!("         [--lint (pre-compile analyzer gate) --lint-confidence 0.9 --lint-repairs 2]");
    println!("         (serve/cluster/autoscale accept the same three lint flags)");
    println!("  suite  [--dstar] [--strategy NAME --coder o3 --judge gpt5]");
    println!("  serve  [--requests 2000 --zipf 1.1 --seed 7 --capacity 1024]");
    println!("         [--window 32 (host batch size; reported numbers are window-free)]");
    println!("         [--interarrival 90 --sim-workers 8 --queue-depth N --slo 120,7200,86400]");
    println!("         [--snapshot cache.jsonl]");
    println!("         [--tenant-rate R --tenant-burst B (front-door token bucket, per tenant)]");
    println!("         [--no-fair-dispatch (strict arrival order within a priority class)]");
    println!("         [--trace DIR (record the flight-recorder artifacts into DIR)]");
    println!("         [--profile (host wall-clock stage breakdown after the replay)]");
    println!("         (cluster/autoscale accept --trace, --profile, and the tenant-rate/");
    println!("          fair-dispatch flags too)");
    println!("  cluster [serve flags, per node] [--nodes 4 --tenants alpha:3,beta:1]");
    println!("         [--no-quotas --transfer-latency 30 --warm-locality-margin 0.25]");
    println!("         [--fail-node N --fail-at SECS (node N drops at SECS)]");
    println!("         [--join-node N --join-at SECS (node N enters, empty, at SECS)]");
    println!("         [--snapshot DIR (shard-aware: restore before / save after)]");
    println!("  autoscale [cluster flags] [--policy static|threshold|target-tracking|all]");
    println!("         [--scenario steady|diurnal|flash-crowd|mass-interruption|straggler|all]");
    println!("         [--tick 3600 (decision period, secs) --provision-delay 600]");
    println!("         [--min-nodes 1 --max-nodes N (fleet bounds; defaults to --nodes)]");
    println!("  lint   [--task L1-95 --gpu rtx6000 --seed 2024] [--bug NAME --json]");
    println!("         [--table --corpus 250 --out results (rule precision/recall scorecard)]");
    println!("  bench  --exp <table1|table2|table3|table4|table5|fig4..fig9|table6|table8|all> [--quick]");
    println!("  select [--iterations 100]");
    println!("  verify [--artifacts artifacts]   (needs --features pjrt)");
    println!("  specs");
    println!("  trace  --explain FINGERPRINT [--dir trace (a --trace output directory)]");
    println!("  version   (build stamp: crate version + enabled features)");
    let keys: Vec<&str> = ALL_STRATEGIES.iter().map(|s| s.cli_key()).collect();
    println!("strategies: {}", keys.join(" "));
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => {
            reject_unknown(
                &args,
                &[
                    "artifacts", "coder", "gpu", "judge", "lint", "lint-confidence",
                    "lint-repairs", "rounds", "seed", "strategy", "task",
                ],
            );
            let id = args.get_or("task", "L1-95");
            let task = tasks::by_id(id).unwrap_or_else(|| {
                eprintln!("error: unknown task {id}");
                std::process::exit(2);
            });
            let oracle = build_oracle(&args);
            let wf = workflow_from(&args);
            println!(
                "optimizing {} ({}) on {} with {} (N={})",
                task.id(), task.name, wf.gpu.name, wf.strategy.name(), wf.max_rounds
            );
            let r = run_task(&wf, &task, oracle.as_ref());
            for round in &r.rounds {
                println!(
                    "  round {:>2} [{}] correct={} speedup={}",
                    round.round,
                    round.mode,
                    round.correct,
                    round.speedup.map(|s| format!("{s:.3}x")).unwrap_or_else(|| "-".into())
                );
                if !round.feedback_json.is_empty() {
                    println!("        judge: {}", round.feedback_json);
                }
            }
            println!(
                "best {:.3}x | ${:.2} API | {:.1} min | {} real-numerics checks",
                r.best_speedup, r.ledger.api_usd, r.ledger.wall_min(), r.oracle_checks
            );
            if wf.lint.is_some() {
                println!(
                    "lint: {} diagnostic(s), {} repair(s) ({} real bug(s)), \
                     {} correctness round(s) saved (${:.2} API, {:.0} s wall)",
                    r.lint.diagnostics,
                    r.lint.repairs,
                    r.lint.bugs_repaired,
                    r.lint.checks_saved,
                    r.lint.api_usd_saved,
                    r.lint.wall_s_saved,
                );
            }
        }
        "suite" => {
            reject_unknown(
                &args,
                &[
                    "artifacts", "coder", "dstar", "gpu", "judge", "lint",
                    "lint-confidence", "lint-repairs", "rounds", "seed", "strategy",
                    "threads",
                ],
            );
            let oracle = build_oracle(&args);
            let wf = workflow_from(&args);
            let set = if args.flag("dstar") { tasks::dstar() } else { tasks::kernelbench() };
            let threads = args.get_usize("threads", default_threads());
            let out = run_suite(&wf, &set, oracle.as_ref(), threads);
            let s = &out.overall;
            println!(
                "{}: correct={:.1}% median={:.3} p75={:.3} perf={:.3} fast1={:.1}% \
                 ${:.2} {:.1}min",
                s.method, s.correct * 100.0, s.median, s.p75, s.perf,
                s.fast1 * 100.0, s.avg_cost_usd, s.avg_time_min
            );
            for (lvl, ls) in &out.per_level {
                println!(
                    "  L{lvl}: correct={:.1}% median={:.3} perf={:.3} fast1={:.1}%",
                    ls.correct * 100.0, ls.median, ls.perf, ls.fast1 * 100.0
                );
            }
        }
        "serve" => {
            reject_unknown(&args, SERVE_FLAGS);
            serve(&args)
        }
        "cluster" => {
            let known: Vec<&str> =
                CLUSTER_SETUP_FLAGS.iter().chain(&["snapshot"]).copied().collect();
            reject_unknown(&args, &known);
            cluster(&args)
        }
        "autoscale" => {
            let known: Vec<&str> = CLUSTER_SETUP_FLAGS
                .iter()
                .chain(AUTOSCALE_EXTRA_FLAGS)
                .copied()
                .collect();
            reject_unknown(&args, &known);
            autoscale(&args)
        }
        "lint" => {
            reject_unknown(
                &args,
                &["bug", "coder", "corpus", "gpu", "json", "out", "seed", "table", "task"],
            );
            lint_cmd(&args)
        }
        "bench" => {
            reject_unknown(
                &args,
                &["artifacts", "exp", "out", "quick", "rounds", "seed", "threads"],
            );
            let oracle = build_oracle(&args);
            let ctx = Ctx {
                seed: args.get_u64("seed", 2024),
                threads: args.get_usize("threads", default_threads()),
                results_dir: args.get_or("out", "results").to_string(),
                rounds: args.get_usize("rounds", 10),
            };
            let exp = args.get_or("exp", "all");
            report::run_experiment(&ctx, exp, oracle.as_ref(), args.flag("quick"));
        }
        "select" => {
            reject_unknown(&args, &["iterations", "out", "seed"]);
            let ctx = Ctx {
                seed: args.get_u64("seed", 2024),
                results_dir: args.get_or("out", "results").to_string(),
                ..Ctx::default()
            };
            report::table8(&ctx, args.get_usize("iterations", 100));
        }
        "verify" => {
            reject_unknown(&args, &["artifacts", "seed"]);
            #[cfg(feature = "pjrt")]
            {
                use cudaforge::runtime::oracle::VerificationMatrix;
                use cudaforge::runtime::Engine;
                let dir = args.get_or("artifacts", "artifacts");
                let mut engine = Engine::new(dir).expect("engine (run `make artifacts`)");
                let matrix = VerificationMatrix::build(&mut engine, args.get_u64("seed", 42))
                    .expect("verification");
                let mut names: Vec<_> = matrix.verdicts.iter().collect();
                names.sort_by(|a, b| a.0.cmp(b.0));
                for (name, v) in names {
                    println!(
                        "  {:36} {} max|diff|={:.3e} ({} elems)",
                        name,
                        if v.passes { "PASS" } else { "MISMATCH" },
                        v.max_abs_diff,
                        v.elements
                    );
                }
                println!(
                    "{} artifacts; consistent with labels: {}",
                    matrix.verdicts.len(),
                    matrix.is_consistent()
                );
            }
            #[cfg(not(feature = "pjrt"))]
            {
                eprintln!(
                    "error: `verify` needs the PJRT engine — rebuild with \
                     `cargo build --features pjrt` (requires the vendored `xla` crate)"
                );
                std::process::exit(2);
            }
        }
        "specs" => {
            reject_unknown(&args, &[]);
            for g in gpu::ALL {
                println!("{}\n", g.spec_sheet());
            }
        }
        "trace" => {
            reject_unknown(&args, &["dir", "explain"]);
            trace_cmd(&args)
        }
        "version" => {
            reject_unknown(&args, &[]);
            println!("cudaforge {}", cudaforge::version());
            let feats = cudaforge::features();
            if feats.is_empty() {
                println!("features: (none)");
            } else {
                println!("features: {}", feats.join(", "));
            }
            println!("build stamp: {}", cudaforge::trace::build_stamp());
        }
        "help" => usage(),
        other => {
            eprintln!("error: unknown subcommand '{other}'\n");
            usage();
            std::process::exit(2);
        }
    }
}
