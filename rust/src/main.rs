//! `cudaforge` — leader CLI for the CudaForge reproduction.
//!
//! Subcommands:
//!   run        optimize one task (e.g. `run --task L1-95 --gpu rtx6000`)
//!   suite      run a strategy over KernelBench or D*
//!   bench      regenerate a paper table/figure (`--exp table1|...|all`)
//!   select     run the offline metric-selection pipeline (Algorithms 1-2)
//!   verify     execute every AOT artifact on PJRT vs its reference
//!   specs      print the GPU spec database
//!
//! Global flags: --seed N --threads N --rounds N --gpu KEY --quick
//!               --strategy NAME --coder MODEL --judge MODEL
//!               --artifacts DIR (enables the real-numerics oracle)

use cudaforge::agents::profiles;
use cudaforge::coordinator::{default_threads, run_suite};
use cudaforge::gpu;
use cudaforge::report::{self, Ctx};
use cudaforge::runtime::oracle::{RealOracle, VerificationMatrix};
use cudaforge::runtime::Engine;
use cudaforge::tasks;
use cudaforge::util::cli::Args;
use cudaforge::workflow::{run_task, CorrectnessOracle, NoOracle, Strategy, WorkflowConfig};

fn strategy_by_name(name: &str) -> Option<Strategy> {
    Some(match name.to_ascii_lowercase().as_str() {
        "cudaforge" => Strategy::CudaForge,
        "one-shot" | "oneshot" => Strategy::OneShot,
        "self-refine" => Strategy::SelfRefine,
        "correction" | "correction-only" => Strategy::CorrectionOnly,
        "optimization" | "optimization-only" => Strategy::OptimizationOnly,
        "full-metrics" => Strategy::CudaForgeFullMetrics,
        "kevin" => Strategy::Kevin,
        "agentic" => Strategy::AgenticBaseline,
        _ => return None,
    })
}

/// Build the real-numerics oracle if artifacts exist (or were requested).
fn build_oracle(args: &Args) -> Box<dyn CorrectnessOracle> {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        if args.get("artifacts").is_some() {
            eprintln!("error: no manifest in {dir}; run `make artifacts`");
            std::process::exit(2);
        }
        eprintln!("[no artifacts found — correctness uses the modelled check; run `make artifacts` for real numerics]");
        return Box::new(NoOracle);
    }
    match Engine::new(&dir).and_then(|mut e| VerificationMatrix::build(&mut e, 42)) {
        Ok(matrix) => {
            let n = matrix.verdicts.len();
            assert!(matrix.is_consistent(), "artifact verdicts inconsistent");
            eprintln!("[real-numerics oracle: {n} artifacts verified on PJRT]");
            Box::new(RealOracle::new(matrix))
        }
        Err(e) => {
            eprintln!("warning: oracle unavailable ({e}); falling back to modelled check");
            Box::new(NoOracle)
        }
    }
}

fn workflow_from(args: &Args) -> WorkflowConfig {
    let gpu = gpu::by_key(args.get_or("gpu", "rtx6000")).unwrap_or_else(|| {
        eprintln!("unknown gpu; options: rtx6000 rtx4090 rtx3090 a100 h100 h200");
        std::process::exit(2);
    });
    let strategy = strategy_by_name(args.get_or("strategy", "cudaforge")).unwrap_or_else(|| {
        eprintln!("unknown strategy");
        std::process::exit(2);
    });
    let mut wf = WorkflowConfig::cudaforge(gpu, args.get_u64("seed", 2024))
        .with_strategy(strategy)
        .with_rounds(args.get_usize("rounds", 10));
    if let Some(m) = args.get("coder") {
        wf.coder = *profiles::by_name(m).expect("unknown coder model");
    }
    if let Some(m) = args.get("judge") {
        wf.judge = *profiles::by_name(m).expect("unknown judge model");
    }
    wf
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => {
            let id = args.get_or("task", "L1-95");
            let task = tasks::by_id(id).unwrap_or_else(|| {
                eprintln!("unknown task {id}");
                std::process::exit(2);
            });
            let oracle = build_oracle(&args);
            let wf = workflow_from(&args);
            println!(
                "optimizing {} ({}) on {} with {} (N={})",
                task.id(), task.name, wf.gpu.name, wf.strategy.name(), wf.max_rounds
            );
            let r = run_task(&wf, &task, oracle.as_ref());
            for round in &r.rounds {
                println!(
                    "  round {:>2} [{}] correct={} speedup={}",
                    round.round,
                    round.mode,
                    round.correct,
                    round.speedup.map(|s| format!("{s:.3}x")).unwrap_or_else(|| "-".into())
                );
                if !round.feedback_json.is_empty() {
                    println!("        judge: {}", round.feedback_json);
                }
            }
            println!(
                "best {:.3}x | ${:.2} API | {:.1} min | {} real-numerics checks",
                r.best_speedup, r.ledger.api_usd, r.ledger.wall_min(), r.oracle_checks
            );
        }
        "suite" => {
            let oracle = build_oracle(&args);
            let wf = workflow_from(&args);
            let set = if args.flag("dstar") { tasks::dstar() } else { tasks::kernelbench() };
            let threads = args.get_usize("threads", default_threads());
            let out = run_suite(&wf, &set, oracle.as_ref(), threads);
            let s = &out.overall;
            println!(
                "{}: correct={:.1}% median={:.3} p75={:.3} perf={:.3} fast1={:.1}% \
                 ${:.2} {:.1}min",
                s.method, s.correct * 100.0, s.median, s.p75, s.perf,
                s.fast1 * 100.0, s.avg_cost_usd, s.avg_time_min
            );
            for (lvl, ls) in &out.per_level {
                println!(
                    "  L{lvl}: correct={:.1}% median={:.3} perf={:.3} fast1={:.1}%",
                    ls.correct * 100.0, ls.median, ls.perf, ls.fast1 * 100.0
                );
            }
        }
        "bench" => {
            let oracle = build_oracle(&args);
            let ctx = Ctx {
                seed: args.get_u64("seed", 2024),
                threads: args.get_usize("threads", default_threads()),
                results_dir: args.get_or("out", "results").to_string(),
                rounds: args.get_usize("rounds", 10),
            };
            let exp = args.get_or("exp", "all");
            report::run_experiment(&ctx, exp, oracle.as_ref(), args.flag("quick"));
        }
        "select" => {
            let ctx = Ctx {
                seed: args.get_u64("seed", 2024),
                results_dir: args.get_or("out", "results").to_string(),
                ..Ctx::default()
            };
            report::table8(&ctx, args.get_usize("iterations", 100));
        }
        "verify" => {
            let dir = args.get_or("artifacts", "artifacts");
            let mut engine = Engine::new(dir).expect("engine (run `make artifacts`)");
            let matrix = VerificationMatrix::build(&mut engine, args.get_u64("seed", 42))
                .expect("verification");
            let mut names: Vec<_> = matrix.verdicts.iter().collect();
            names.sort_by(|a, b| a.0.cmp(b.0));
            for (name, v) in names {
                println!(
                    "  {:36} {} max|diff|={:.3e} ({} elems)",
                    name,
                    if v.passes { "PASS" } else { "MISMATCH" },
                    v.max_abs_diff,
                    v.elements
                );
            }
            println!(
                "{} artifacts; consistent with labels: {}",
                matrix.verdicts.len(),
                matrix.is_consistent()
            );
        }
        "specs" => {
            for g in gpu::ALL {
                println!("{}\n", g.spec_sheet());
            }
        }
        _ => {
            println!("cudaforge {} — CudaForge reproduction CLI", cudaforge::version());
            println!("usage: cudaforge <run|suite|bench|select|verify|specs> [flags]");
            println!("  run    --task L1-95 [--gpu rtx6000 --strategy cudaforge --rounds 10]");
            println!("  suite  [--dstar] [--strategy NAME --coder o3 --judge gpt5]");
            println!("  bench  --exp <table1|table2|table3|table4|table5|fig4..fig9|table6|table8|all> [--quick]");
            println!("  select [--iterations 100]");
            println!("  verify [--artifacts artifacts]");
        }
    }
}
