//! The CudaForge workflow engine (§2.1, Fig. 2) and its ablation/baseline
//! strategies (§3.2).
//!
//! One `run_task` call executes up to N rounds of the paper's loop for a
//! single KernelBench task: generate → compile/execute correctness test →
//! (on failure) Judge correction → (on success) NCU profile + Judge
//! optimization → Coder revision. The best correct kernel across rounds is
//! the task's solution (§2.1 "after which we select the most efficient
//! correct kernel").
//!
//! Real numerics: when a task is bound to a Pallas artifact family and a
//! `CorrectnessOracle` is supplied, the compile/execute stage runs genuine
//! PJRT executions of the matching kernel variant against its reference
//! oracle (see `runtime::oracle`).

pub mod baselines;

use crate::agents::{Coder, Feedback, Judge, MetricMode, ModelProfile};
use crate::cost::{CostLedger, CostModel};
use crate::gpu::GpuSpec;
use crate::kernel::KernelConfig;
use crate::sim::{baseline_time, ncu, simulate, SimParams};
use crate::tasks::TaskSpec;
use crate::util::rng::Rng;

/// Which workflow variant to run (Table 1's method rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Base model, single generation, no iteration.
    OneShot,
    /// Ten rounds of self-refinement: the same model corrects and optimizes
    /// its own kernels given hardware feedback (no independent Judge).
    SelfRefine,
    /// Judge provides only correctness feedback (o3-correction).
    CorrectionOnly,
    /// Judge provides only optimization feedback (o3-optimization).
    OptimizationOnly,
    /// The full system: correction + optimization, 24-metric subset.
    CudaForge,
    /// Ablation: Judge sees the entire NCU metric set.
    CudaForgeFullMetrics,
    /// Kevin-32B-like multi-trajectory RL-style refiner (16 x 8, score-only
    /// optimization feedback) — Fig. 5's comparison.
    Kevin,
    /// The ensemble sampling + verification-filtering agentic baseline [2].
    AgenticBaseline,
}

/// Every strategy, in Table 1 row order — the single source of truth the CLI
/// and the sweep experiments enumerate.
pub const ALL_STRATEGIES: [Strategy; 8] = [
    Strategy::OneShot,
    Strategy::SelfRefine,
    Strategy::CorrectionOnly,
    Strategy::OptimizationOnly,
    Strategy::CudaForge,
    Strategy::CudaForgeFullMetrics,
    Strategy::Kevin,
    Strategy::AgenticBaseline,
];

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::OneShot => "one-shot",
            Strategy::SelfRefine => "self-refine",
            Strategy::CorrectionOnly => "correction-only",
            Strategy::OptimizationOnly => "optimization-only",
            Strategy::CudaForge => "CudaForge",
            Strategy::CudaForgeFullMetrics => "CudaForge(full metrics)",
            Strategy::Kevin => "Kevin-like",
            Strategy::AgenticBaseline => "Agentic Baseline",
        }
    }

    /// Canonical `--strategy` key for this variant.
    pub fn cli_key(self) -> &'static str {
        match self {
            Strategy::OneShot => "one-shot",
            Strategy::SelfRefine => "self-refine",
            Strategy::CorrectionOnly => "correction",
            Strategy::OptimizationOnly => "optimization",
            Strategy::CudaForge => "cudaforge",
            Strategy::CudaForgeFullMetrics => "full-metrics",
            Strategy::Kevin => "kevin",
            Strategy::AgenticBaseline => "agentic",
        }
    }

    /// Parse a CLI strategy name (canonical keys plus common aliases).
    pub fn by_name(name: &str) -> Option<Strategy> {
        Some(match name.to_ascii_lowercase().as_str() {
            "cudaforge" => Strategy::CudaForge,
            "one-shot" | "oneshot" => Strategy::OneShot,
            "self-refine" => Strategy::SelfRefine,
            "correction" | "correction-only" => Strategy::CorrectionOnly,
            "optimization" | "optimization-only" => Strategy::OptimizationOnly,
            "full-metrics" => Strategy::CudaForgeFullMetrics,
            "kevin" => Strategy::Kevin,
            "agentic" => Strategy::AgenticBaseline,
            _ => return None,
        })
    }
}

/// A cached kernel used to seed a run instead of a cold first generation
/// (the service layer's warm-start path). When `source_gpu` differs from the
/// run's target GPU this is the cross-GPU transfer case: the Coder adapts a
/// kernel tuned for one part onto another.
// `PartialEq` so the service layer's run memo can recognize that two flights
// would execute the identical workflow (fingerprints cover everything else).
#[derive(Clone, Debug, PartialEq)]
pub struct WarmStart {
    /// Best known correct config for this task (possibly from another GPU).
    pub config: KernelConfig,
    /// GPU key the config was tuned on.
    pub source_gpu: &'static str,
    /// Speedup the source run measured on its own GPU.
    pub source_speedup: f64,
}

/// Early-exit policy: stop iterating once `patience` consecutive rounds fail
/// to improve the best speedup by more than `min_delta`. Off by default —
/// the paper always runs the full N rounds; the service layer turns it on
/// for warm-started runs, where the first candidate is already near-best.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EarlyStop {
    pub patience: usize,
    pub min_delta: f64,
}

impl Default for EarlyStop {
    fn default() -> Self {
        EarlyStop { patience: 2, min_delta: 0.05 }
    }
}

/// The pre-compile static-analysis gate (`analysis::lint`). Off by default:
/// a lint-off run draws no extra rng and charges nothing, so it stays
/// bit-identical to builds without the analyzer. When on, Error-severity
/// diagnostics at or above `repair_confidence` buy a Coder repair *before*
/// the compile+test stage spends its budget on a condemned candidate, and
/// residual diagnostics are appended to the error log the Judge reads.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LintGate {
    /// Minimum diagnostic confidence that triggers a pre-compile repair.
    pub repair_confidence: f64,
    /// Repair attempts per round (each is one priced Coder call).
    pub max_repairs_per_round: u32,
}

impl Default for LintGate {
    fn default() -> Self {
        LintGate { repair_confidence: 0.9, max_repairs_per_round: 2 }
    }
}

/// Per-run accounting of what the lint gate did, and the modelled spend it
/// avoided versus the same run with lint off. The "saved" figures are the
/// counterfactual cost of the correctness-test stage + Judge correction the
/// doomed candidate would have consumed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LintStats {
    /// Diagnostics emitted across all lint passes (repaired candidates are
    /// re-linted).
    pub diagnostics: u32,
    /// Lint-triggered pre-compile Coder repairs (each priced in the ledger).
    pub repairs: u32,
    /// Repairs that actually removed the suspected bug.
    pub bugs_repaired: u32,
    /// Correctness-test rounds not spent on a condemned candidate.
    pub checks_saved: u32,
    /// Modelled wall-clock avoided (skipped compile, plus the exec test for
    /// runtime defects).
    pub wall_s_saved: f64,
    /// Modelled Judge-correction API spend avoided.
    pub api_usd_saved: f64,
}

/// Workflow configuration for one run.
#[derive(Clone)]
pub struct WorkflowConfig {
    pub strategy: Strategy,
    pub max_rounds: usize,
    pub coder: ModelProfile,
    pub judge: ModelProfile,
    pub gpu: &'static GpuSpec,
    pub sim: SimParams,
    pub cost: CostModel,
    pub seed: u64,
    /// Seed the run from a cached kernel instead of a cold generation.
    pub warm_start: Option<WarmStart>,
    /// Stop early once the speedup plateaus (service warm runs).
    pub early_stop: Option<EarlyStop>,
    /// Pre-compile static-analysis gate (None = lint off, the default).
    pub lint: Option<LintGate>,
}

impl WorkflowConfig {
    pub fn cudaforge(gpu: &'static GpuSpec, seed: u64) -> WorkflowConfig {
        WorkflowConfig {
            strategy: Strategy::CudaForge,
            max_rounds: 10,
            coder: crate::agents::profiles::O3,
            judge: crate::agents::profiles::O3,
            gpu,
            sim: SimParams::default(),
            cost: CostModel::default(),
            seed,
            warm_start: None,
            early_stop: None,
            lint: None,
        }
    }

    pub fn with_strategy(mut self, s: Strategy) -> WorkflowConfig {
        self.strategy = s;
        self
    }

    pub fn with_rounds(mut self, n: usize) -> WorkflowConfig {
        self.max_rounds = n;
        self
    }

    pub fn with_warm_start(mut self, w: WarmStart) -> WorkflowConfig {
        self.warm_start = Some(w);
        self
    }

    pub fn with_early_stop(mut self, es: EarlyStop) -> WorkflowConfig {
        self.early_stop = Some(es);
        self
    }

    pub fn with_lint(mut self, gate: LintGate) -> WorkflowConfig {
        self.lint = Some(gate);
        self
    }
}

/// Outcome of the compile + execute correctness stage (§2.2).
#[derive(Clone, Debug, PartialEq)]
pub enum CheckOutcome {
    CompileError(String),
    Mismatch(String),
    Pass,
}

/// Hook for real-numerics correctness on artifact-bound tasks. Returning
/// `None` defers to the modelled check (bug presence).
pub trait CorrectnessOracle: Sync {
    fn check(&self, task: &TaskSpec, cfg: &KernelConfig) -> Option<CheckOutcome>;
}

/// The no-op oracle: everything modelled.
pub struct NoOracle;

impl CorrectnessOracle for NoOracle {
    fn check(&self, _: &TaskSpec, _: &KernelConfig) -> Option<CheckOutcome> {
        None
    }
}

/// What happened in one round (drives Figs. 7–9 and the case study).
#[derive(Clone, Debug, PartialEq)]
pub struct RoundLog {
    pub round: usize,
    /// "correction" | "optimization" | "initial"
    pub mode: &'static str,
    pub correct: bool,
    pub compiled: bool,
    /// Measured speedup vs the PyTorch baseline (correct rounds only).
    pub speedup: Option<f64>,
    /// Judge feedback JSON produced *after* this round's test (empty on the
    /// final round).
    pub feedback_json: String,
    pub config: KernelConfig,
}

/// Result of optimizing one task.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskResult {
    pub task_id: String,
    pub level: u8,
    /// Any round produced a correct kernel.
    pub correct: bool,
    /// Best speedup among correct rounds (0.0 if never correct — the
    /// KernelBench fast_p convention).
    pub best_speedup: f64,
    pub best_config: Option<KernelConfig>,
    pub rounds: Vec<RoundLog>,
    pub ledger: CostLedger,
    /// Real-numerics executions performed through the oracle.
    pub oracle_checks: u32,
    /// Static-analysis gate accounting (all zero when lint is off).
    pub lint: LintStats,
}

impl TaskResult {
    /// 1-based round at which the best speedup was first measured (`None`
    /// when no round produced a correct kernel). The service layer compares
    /// this between warm-started and cold runs.
    pub fn rounds_to_best(&self) -> Option<usize> {
        self.rounds
            .iter()
            .find(|r| r.speedup == Some(self.best_speedup))
            .map(|r| r.round)
    }
}

/// FNV-1a 64 — the crate's stable string hash (per-task seed derivation and
/// the analyzer's deterministic legibility gates).
pub(crate) fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Modelled correctness stage (used when no oracle claims the task).
pub fn modelled_check(cfg: &KernelConfig) -> CheckOutcome {
    if let Some(b) = cfg.bugs.iter().find(|b| b.is_compile_error()) {
        return CheckOutcome::CompileError(b.error_log().to_string());
    }
    match cfg
        .bugs
        .iter()
        .copied()
        .max_by(|a, b| a.observability().total_cmp(&b.observability()))
    {
        Some(b) => CheckOutcome::Mismatch(b.error_log().to_string()),
        None => CheckOutcome::Pass,
    }
}

/// Run one task through the configured workflow.
pub fn run_task(
    wf: &WorkflowConfig,
    task: &TaskSpec,
    oracle: &dyn CorrectnessOracle,
) -> TaskResult {
    match wf.strategy {
        Strategy::Kevin => baselines::run_kevin(wf, task, oracle),
        Strategy::AgenticBaseline => baselines::run_agentic(wf, task, oracle),
        _ => run_iterative(wf, task, oracle),
    }
}

/// The shared iterative loop used by CudaForge and its ablations.
pub(crate) fn run_iterative(
    wf: &WorkflowConfig,
    task: &TaskSpec,
    oracle: &dyn CorrectnessOracle,
) -> TaskResult {
    let mut rng = Rng::new(wf.seed ^ fnv(&task.id()));
    let coder = Coder::new(wf.coder);
    let judge = match wf.strategy {
        Strategy::SelfRefine => Judge::self_refine(wf.coder),
        Strategy::CudaForgeFullMetrics => Judge::new(wf.judge, MetricMode::Full),
        _ => Judge::new(wf.judge, MetricMode::Subset),
    };
    let full_profile = wf.strategy == Strategy::CudaForgeFullMetrics;
    let base_us = baseline_time(wf.gpu, task, &wf.sim);

    let mut ledger = CostLedger::default();
    let mut rounds: Vec<RoundLog> = Vec::with_capacity(wf.max_rounds);
    let mut oracle_checks = 0u32;
    let mut lint_stats = LintStats::default();
    let mut best: Option<(f64, KernelConfig)> = None;

    // Round state carried across iterations (lightweight memory: only the
    // latest candidate + latest feedback survive, per §2.2).
    let mut cfg: KernelConfig;
    let mut pending: Option<(Feedback, String, bool)> = None; // (fb, error_log, was_failure)

    let max_rounds = if wf.strategy == Strategy::OneShot { 1 } else { wf.max_rounds };

    {
        let (c, st) = match &wf.warm_start {
            Some(w) => coder.adapt(task, wf.gpu, w, &mut rng),
            None => coder.initial(task, wf.gpu, &mut rng),
        };
        ledger.charge_call(&wf.cost, &wf.coder, st);
        cfg = c;
    }

    let mut stagnant_rounds = 0usize;
    for round in 1..=max_rounds {
        let mut mode = "initial";
        if round > 1 {
            let (fb, log, was_failure) = pending.take().expect("feedback pending");
            let (mut c, st) = if was_failure {
                mode = "correction";
                coder.revise_correction(task, wf.gpu, &cfg, &fb, &log, &mut rng)
            } else {
                mode = "optimization";
                coder.revise_optimization(task, wf.gpu, &cfg, &fb, &mut rng)
            };
            // Self-refinement carries the model's own rationale as context;
            // its speculative rewrites hallucinate more (§2.2), which is why
            // the paper's self-refine loses correctness vs correction-only.
            if wf.strategy == Strategy::SelfRefine
                && mode == "optimization"
                && rng.chance(0.12)
            {
                c.bugs.push(crate::kernel::Bug::OobIndex);
            }
            ledger.charge_call(&wf.cost, &wf.coder, st);
            cfg = c;
        }

        // ---- static-analysis gate (lint-on only) --------------------------
        // Pure pre-compile pass: a high-confidence correctness diagnostic
        // buys a Coder repair instead of spending the compile+test stage on
        // a candidate the analyzer already condemned. When `wf.lint` is None
        // this arm draws no rng and charges nothing, so lint-off replays are
        // bit-identical to builds without the analyzer.
        if let Some(gate) = wf.lint {
            let mut repairs_left = gate.max_repairs_per_round;
            loop {
                let diags = crate::analysis::lint(task, wf.gpu, &cfg);
                lint_stats.diagnostics += diags.len() as u32;
                let Some(d) =
                    diags.into_iter().find(|d| d.triggers_repair(gate.repair_confidence))
                else {
                    break;
                };
                if repairs_left == 0 {
                    break;
                }
                repairs_left -= 1;
                let bug = d.suspect.expect("repair trigger implies a suspect");
                // Price the Judge correction this candidate would have
                // bought after failing the check (counterfactual only —
                // nothing is charged to the ledger for it).
                let judge_stats = crate::agents::CallStats {
                    tokens_in: crate::agents::estimate_tokens_len(
                        crate::agents::prompts::judge_correction_len(task, &cfg, &d.message),
                    ),
                    tokens_out: wf.judge.judge_out_tokens,
                };
                let had = cfg.bugs.contains(&bug);
                let fb = Feedback::Correction {
                    critical_issue: format!("{} flagged pre-compile", bug.name()),
                    why_it_matters: d.message.clone(),
                    minimal_fix_hint: format!(
                        "resolve the {} before submitting the kernel",
                        bug.name()
                    ),
                    bug: Some(bug),
                };
                let (c, st) =
                    coder.revise_correction(task, wf.gpu, &cfg, &fb, &d.message, &mut rng);
                ledger.charge_call(&wf.cost, &wf.coder, st);
                cfg = c;
                lint_stats.repairs += 1;
                if had && !cfg.bugs.contains(&bug) {
                    // The repair landed: this round's check is no longer
                    // doomed to fail on `bug`. The lint-off run would have
                    // spent the compile attempt (+ exec test for runtime
                    // defects) plus the Judge correction on it.
                    lint_stats.bugs_repaired += 1;
                    lint_stats.checks_saved += 1;
                    lint_stats.wall_s_saved += wf.cost.compile_s
                        + if bug.is_compile_error() { 0.0 } else { wf.cost.exec_test_s };
                    lint_stats.api_usd_saved += wf.cost.api_usd(&wf.judge, judge_stats);
                }
            }
        }

        // ---- compile + execute correctness stage --------------------------
        let outcome = match oracle.check(task, &cfg) {
            Some(o) => {
                oracle_checks += 1;
                o
            }
            None => modelled_check(&cfg),
        };
        let compiled = !matches!(outcome, CheckOutcome::CompileError(_));
        ledger.charge_compile(&wf.cost, compiled);

        // One pricing per round: the same SimOutput backs both the latency
        // measurement and the NCU profile (EXPERIMENTS.md §Perf, change 1).
        let best_before = best.as_ref().map(|(b, _)| *b).unwrap_or(0.0);
        let mut sim_out = None;
        let (correct, speedup) = match &outcome {
            CheckOutcome::Pass => {
                // Measured end-to-end latency (KernelBench timing harness),
                // with run-to-run noise.
                let out = simulate(wf.gpu, task, &cfg, &wf.sim, 1.0);
                let measured = out.runtime_us * rng.lognormal_noise(0.01);
                sim_out = Some(out);
                let s = base_us / measured;
                if best.as_ref().map(|(b, _)| s > *b).unwrap_or(true) {
                    best = Some((s, cfg.clone()));
                }
                (true, Some(s))
            }
            _ => (false, None),
        };

        // ---- early-exit bookkeeping ---------------------------------------
        // A plateau check before spending the Judge call: once `patience`
        // consecutive rounds fail to beat the running best by `min_delta`,
        // the run stops and no further feedback is purchased.
        let mut stop_now = false;
        if let Some(es) = wf.early_stop {
            let improved =
                speedup.map(|s| s > best_before + es.min_delta).unwrap_or(false);
            if improved {
                stagnant_rounds = 0;
            } else {
                stagnant_rounds += 1;
            }
            stop_now = stagnant_rounds >= es.patience;
        }

        // ---- feedback for the next round ----------------------------------
        let mut feedback_json = String::new();
        if round < max_rounds && !stop_now {
            let mut error_log = match &outcome {
                CheckOutcome::CompileError(l) | CheckOutcome::Mismatch(l) => l.clone(),
                CheckOutcome::Pass => String::new(),
            };
            // Lint-on: residual diagnostics ride along with the error log,
            // so the Judge (and next round's Coder) read them too. They are
            // honest prompt bytes — token accounting prices them.
            if wf.lint.is_some() && !correct {
                for d in crate::analysis::lint(task, wf.gpu, &cfg) {
                    error_log.push('\n');
                    error_log.push_str(&d.render());
                }
            }
            let (fb, was_failure) = if !correct {
                let (fb, st) = match wf.strategy {
                    // o3-optimization: no correction feedback — the Coder only
                    // sees the raw error log.
                    Strategy::OptimizationOnly => (Feedback::NothingFound, none_stats()),
                    _ => {
                        let (fb, st) = judge.correction(task, &cfg, &error_log, &mut rng);
                        (fb, st)
                    }
                };
                if st_nonzero(st) {
                    ledger.charge_call(&wf.cost, &wf.judge, st);
                }
                (fb, true)
            } else {
                let (fb, st) = match wf.strategy {
                    // o3-correction: no optimization feedback — the Coder
                    // improvises unguided.
                    Strategy::CorrectionOnly => (Feedback::NothingFound, none_stats()),
                    _ => {
                        let out = sim_out.take().expect("priced on pass");
                        let metrics =
                            ncu::profile(wf.gpu, task, &cfg, &out, &mut rng);
                        ledger.charge_profile(&wf.cost, full_profile);
                        judge.optimization(task, wf.gpu, &cfg, &metrics, &mut rng)
                    }
                };
                if st_nonzero(st) {
                    ledger.charge_call(&wf.cost, &wf.judge, st);
                }
                (fb, false)
            };
            // The JSON wire round-trip is part of the protocol (§2.2 "Judge
            // generates structured feedback in JSON format, which is then
            // extracted and passed to the Coder").
            feedback_json = fb.to_json().to_string();
            let parsed = Feedback::from_json(
                &crate::util::json::Json::parse(&feedback_json).expect("valid JSON"),
            )
            .expect("parseable feedback");
            pending = Some((parsed, error_log, was_failure));
        }

        rounds.push(RoundLog {
            round,
            mode,
            correct,
            compiled,
            speedup,
            feedback_json,
            config: cfg.clone(),
        });
        if stop_now {
            break;
        }
    }

    let (best_speedup, best_config) = match best {
        Some((s, c)) => (s, Some(c)),
        None => (0.0, None),
    };
    TaskResult {
        task_id: task.id(),
        level: task.level,
        correct: best_config.is_some(),
        best_speedup,
        best_config,
        rounds,
        ledger,
        oracle_checks,
        lint: lint_stats,
    }
}

fn none_stats() -> crate::agents::CallStats {
    crate::agents::CallStats::default()
}

fn st_nonzero(st: crate::agents::CallStats) -> bool {
    st.tokens_in > 0.0 || st.tokens_out > 0.0
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;
    use crate::tasks::by_id;

    fn wf(strategy: Strategy, seed: u64) -> WorkflowConfig {
        WorkflowConfig::cudaforge(&RTX6000_ADA, seed).with_strategy(strategy)
    }

    #[test]
    fn cudaforge_runs_n_rounds_and_tracks_best() {
        let task = by_id("L1-95").unwrap();
        let r = run_task(&wf(Strategy::CudaForge, 42), &task, &NoOracle);
        assert_eq!(r.rounds.len(), 10);
        assert_eq!(r.rounds[0].mode, "initial");
        if r.correct {
            assert!(r.best_speedup > 0.0);
            // best is the max over correct rounds
            let max_round = r
                .rounds
                .iter()
                .filter_map(|x| x.speedup)
                .fold(0.0f64, f64::max);
            assert!((r.best_speedup - max_round).abs() < 1e-9);
        }
        assert!(r.ledger.api_usd > 0.0);
        assert!(r.ledger.wall_s > 0.0);
    }

    #[test]
    fn one_shot_is_single_round() {
        let task = by_id("L1-1").unwrap();
        let r = run_task(&wf(Strategy::OneShot, 1), &task, &NoOracle);
        assert_eq!(r.rounds.len(), 1);
        assert!(r.rounds[0].feedback_json.is_empty());
    }

    #[test]
    fn deterministic_per_seed() {
        let task = by_id("L2-51").unwrap();
        let a = run_task(&wf(Strategy::CudaForge, 7), &task, &NoOracle);
        let b = run_task(&wf(Strategy::CudaForge, 7), &task, &NoOracle);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.correct, y.correct);
            assert_eq!(x.feedback_json, y.feedback_json);
        }
        let c = run_task(&wf(Strategy::CudaForge, 8), &task, &NoOracle);
        // different seed should (almost surely) differ somewhere
        let same = a
            .rounds
            .iter()
            .zip(&c.rounds)
            .all(|(x, y)| x.feedback_json == y.feedback_json);
        assert!(!same || a.best_speedup != c.best_speedup);
    }

    #[test]
    fn correction_only_never_profiles() {
        let task = by_id("L1-95").unwrap();
        let r = run_task(&wf(Strategy::CorrectionOnly, 5), &task, &NoOracle);
        assert_eq!(r.ledger.profiles, 0);
    }

    #[test]
    fn full_metrics_costs_more() {
        let task = by_id("L2-51").unwrap();
        let a = run_task(&wf(Strategy::CudaForge, 3), &task, &NoOracle);
        let b = run_task(&wf(Strategy::CudaForgeFullMetrics, 3), &task, &NoOracle);
        if a.ledger.profiles > 0 && b.ledger.profiles > 0 {
            let per_a = a.ledger.wall_s / a.ledger.profiles as f64;
            let per_b = b.ledger.wall_s / b.ledger.profiles as f64;
            assert!(per_b > per_a);
        }
    }

    #[test]
    fn rounds_to_best_points_at_max_round() {
        let task = by_id("L1-95").unwrap();
        let r = run_task(&wf(Strategy::CudaForge, 42), &task, &NoOracle);
        match r.rounds_to_best() {
            Some(n) => {
                assert!(r.correct);
                assert_eq!(r.rounds[n - 1].speedup, Some(r.best_speedup));
            }
            None => assert!(!r.correct),
        }
    }

    #[test]
    fn warm_start_converges_in_fewer_rounds_on_average() {
        // The service-layer acceptance property, at unit scale: seed a run
        // with a previous run's best kernel + early stopping, and the mean
        // rounds-to-best over several seeds drops below the cold mean.
        let task = by_id("L1-24").unwrap();
        let mut cold_rounds = 0.0;
        let mut warm_rounds = 0.0;
        let mut warm_len = 0.0;
        let mut n = 0.0;
        for seed in 0..12u64 {
            let cold = run_task(&wf(Strategy::CudaForge, seed), &task, &NoOracle);
            let Some(best_cfg) = cold.best_config.clone() else { continue };
            let warm_wf = wf(Strategy::CudaForge, seed)
                .with_warm_start(WarmStart {
                    config: best_cfg,
                    source_gpu: "a100",
                    source_speedup: cold.best_speedup,
                })
                .with_early_stop(EarlyStop::default());
            let warm = run_task(&warm_wf, &task, &NoOracle);
            let (Some(c), Some(w)) = (cold.rounds_to_best(), warm.rounds_to_best()) else {
                continue;
            };
            cold_rounds += c as f64;
            warm_rounds += w as f64;
            warm_len += warm.rounds.len() as f64;
            n += 1.0;
        }
        assert!(n >= 6.0, "expected most seeds to produce correct runs, got {n}");
        assert!(
            warm_rounds / n < cold_rounds / n,
            "warm mean {} !< cold mean {}",
            warm_rounds / n,
            cold_rounds / n
        );
        assert!(warm_len / n < 10.0, "early stop should shorten warm runs");
    }

    #[test]
    fn early_stop_off_by_default_runs_full_n() {
        let task = by_id("L2-51").unwrap();
        let r = run_task(&wf(Strategy::CudaForge, 123), &task, &NoOracle);
        assert_eq!(r.rounds.len(), 10);
    }

    #[test]
    fn strategy_names_round_trip_through_cli_keys() {
        for s in ALL_STRATEGIES {
            assert_eq!(Strategy::by_name(s.cli_key()), Some(s), "{}", s.name());
        }
        assert!(Strategy::by_name("nope").is_none());
    }

    #[test]
    fn modelled_check_classifies() {
        let mut cfg = KernelConfig::naive();
        assert_eq!(modelled_check(&cfg), CheckOutcome::Pass);
        cfg.bugs.push(crate::kernel::Bug::OobIndex);
        assert!(matches!(modelled_check(&cfg), CheckOutcome::Mismatch(_)));
        cfg.bugs.push(crate::kernel::Bug::CompileSyntax);
        assert!(matches!(modelled_check(&cfg), CheckOutcome::CompileError(_)));
    }
}
