//! External-system baselines: a Kevin-32B-like RL refiner (Fig. 5) and the
//! ensemble agentic baseline of [2] (Table 1 / Fig. 4 / Table 3).
//!
//! Both are modelled at the fidelity the comparison needs (DESIGN.md §5
//! "expected shapes"): Kevin does 16 parallel trajectories x 8 refinement
//! turns with *score-only* feedback (no hardware metrics -> blind
//! exploration, §1 C3); the agentic baseline samples candidate ensembles and
//! keeps verified winners (no NCU feedback either), at ~$5 and ~60 min per
//! kernel (Table 3).

use crate::agents::profiles::O3;
use crate::agents::{Coder, Feedback, Judge, ModelProfile};
use crate::cost::CostLedger;
use crate::kernel::{Bug, KernelConfig};
use crate::sim::{baseline_time, simulate};
use crate::tasks::TaskSpec;
use crate::util::rng::Rng;
use crate::workflow::{
    modelled_check, CheckOutcome, CorrectnessOracle, RoundLog, TaskResult, WorkflowConfig,
};

/// Kevin-32B stand-in: a fine-tuned 32B model — much weaker generation than
/// o3, decent error fixing (it was RL-trained on exactly that), zero API cost
/// (self-hosted).
pub const KEVIN_32B: ModelProfile = ModelProfile {
    name: "Kevin-32B",
    gen_skill: 0.45,
    fix_skill: 0.70,
    diag_skill: 0.52,
    follow: 0.60,
    bug_rate: 0.40,
    usd_per_mtok_in: 0.0,
    usd_per_mtok_out: 0.0,
    seconds_per_call: 20.0,
    gen_out_tokens: 3000.0,
    judge_out_tokens: 0.0,
};

const KEVIN_TRAJECTORIES: usize = 16;
const KEVIN_TURNS: usize = 8;

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Kevin: 16 trajectories x 8 turns, refinement driven only by the error log
/// and the speedup score — no NCU, no GPU specs, no independent Judge.
pub fn run_kevin(
    wf: &WorkflowConfig,
    task: &TaskSpec,
    oracle: &dyn CorrectnessOracle,
) -> TaskResult {
    let mut rng = Rng::new(wf.seed ^ fnv(&task.id()) ^ 0x4B45);
    let coder = Coder::new(KEVIN_32B);
    // Kevin reads its own error logs (that is what the RL reward taught it).
    let self_judge = Judge::self_refine(KEVIN_32B);
    let base_us = baseline_time(wf.gpu, task, &wf.sim);

    // Systematic blind spot: samples from one fine-tuned model share failure
    // modes, so for a fraction of (hard) tasks *every* trajectory carries an
    // unfixable defect. This is what keeps any-of-16 from saturating
    // correctness, matching Kevin's reported 82% on L1-2-difficulty tasks.
    let hard_case = rng.chance(0.05 + 0.28 * task.difficulty);

    let mut ledger = CostLedger::default();
    let mut rounds = Vec::new();
    let mut best: Option<(f64, KernelConfig)> = None;
    let mut oracle_checks = 0;

    for traj in 0..KEVIN_TRAJECTORIES {
        let mut trng = rng.fork(traj as u64);
        let (mut cfg, st) = coder.initial(task, wf.gpu, &mut trng);
        ledger.charge_call(&wf.cost, &KEVIN_32B, st);
        if hard_case {
            cfg.bugs.push(Bug::RaceCondition); // the shared blind spot
        }
        let mut pending: Option<(Feedback, String, bool)> = None;
        for turn in 1..=KEVIN_TURNS {
            if let Some((fb, log, was_failure)) = pending.take() {
                let (c, st) = if was_failure {
                    coder.revise_correction(task, wf.gpu, &cfg, &fb, &log, &mut trng)
                } else {
                    // Score-only feedback: no named move — blind exploration.
                    coder.revise_optimization(
                        task,
                        wf.gpu,
                        &cfg,
                        &Feedback::NothingFound,
                        &mut trng,
                    )
                };
                ledger.charge_call(&wf.cost, &KEVIN_32B, st);
                cfg = c;
                if hard_case {
                    // The blind spot re-manifests in every rewrite.
                    if !cfg.bugs.contains(&Bug::RaceCondition) {
                        cfg.bugs.push(Bug::RaceCondition);
                    }
                }
            }
            let outcome = match oracle.check(task, &cfg) {
                Some(o) => {
                    oracle_checks += 1;
                    o
                }
                None => modelled_check(&cfg),
            };
            let compiled = !matches!(outcome, CheckOutcome::CompileError(_));
            ledger.charge_compile(&wf.cost, compiled);
            let (correct, speedup) = match &outcome {
                CheckOutcome::Pass => {
                    let out = simulate(wf.gpu, task, &cfg, &wf.sim, 1.0);
                    let s = base_us / (out.runtime_us * trng.lognormal_noise(0.01));
                    if best.as_ref().map(|(b, _)| s > *b).unwrap_or(true) {
                        best = Some((s, cfg.clone()));
                    }
                    (true, Some(s))
                }
                _ => (false, None),
            };
            let error_log = match &outcome {
                CheckOutcome::CompileError(l) | CheckOutcome::Mismatch(l) => l.clone(),
                CheckOutcome::Pass => String::new(),
            };
            if turn < KEVIN_TURNS {
                let fb = if !correct {
                    let (fb, _) = self_judge.correction(task, &cfg, &error_log, &mut trng);
                    fb
                } else {
                    Feedback::NothingFound
                };
                pending = Some((fb, error_log, !correct));
            }
            if traj == 0 {
                rounds.push(RoundLog {
                    round: turn,
                    mode: if turn == 1 { "initial" } else if correct { "optimization" } else { "correction" },
                    correct,
                    compiled,
                    speedup,
                    feedback_json: String::new(),
                    config: cfg.clone(),
                });
            }
        }
    }

    let (best_speedup, best_config) = match best {
        Some((s, c)) => (s, Some(c)),
        None => (0.0, None),
    };
    TaskResult {
        task_id: task.id(),
        level: task.level,
        correct: best_config.is_some(),
        best_speedup,
        best_config,
        rounds,
        ledger,
        oracle_checks,
        lint: crate::workflow::LintStats::default(),
    }
}

const AGENTIC_ROUNDS: usize = 12;
const AGENTIC_SAMPLES: usize = 3;
/// Per-candidate benchmarking overhead of the baseline's exhaustive
/// verification harness (seconds).
const AGENTIC_VERIFY_S: f64 = 85.0;

/// The agentic baseline [2]: every round samples an ensemble of candidates
/// (reasoning + conventional LLMs), verification-filters them, and keeps the
/// best verified kernel. No hardware feedback; heavy API + wall-clock cost
/// (the full conversation history rides along in every call).
pub fn run_agentic(
    wf: &WorkflowConfig,
    task: &TaskSpec,
    oracle: &dyn CorrectnessOracle,
) -> TaskResult {
    let mut rng = Rng::new(wf.seed ^ fnv(&task.id()) ^ 0xA6E7);
    let coder = Coder::new(O3);
    let judge = Judge::new(O3, crate::agents::MetricMode::Subset);
    let base_us = baseline_time(wf.gpu, task, &wf.sim);

    let mut ledger = CostLedger::default();
    let mut rounds = Vec::new();
    let mut oracle_checks = 0;
    let mut best: Option<(f64, KernelConfig)> = None;
    let mut current: Option<KernelConfig> = None;
    let mut last_fb: Option<(Feedback, String, bool)> = None;

    for round in 1..=AGENTIC_ROUNDS {
        // Sample an ensemble of candidates.
        let mut round_best: Option<(f64, bool, KernelConfig, CheckOutcome)> = None;
        for sample in 0..AGENTIC_SAMPLES {
            let mut srng = rng.fork((round * 100 + sample) as u64);
            // Optimization progress comes from *fresh translation sampling*
            // (best-of-N draws, verification-filtered); refinement chains are
            // only used to repair a failing candidate. This is what keeps the
            // baseline below hardware-guided iteration (§1 C3).
            let (cfg, mut st) = match (&current, &last_fb) {
                (Some(prev), Some((fb, log, true))) => {
                    coder.revise_correction(task, wf.gpu, prev, fb, log, &mut srng)
                }
                _ => coder.initial(task, wf.gpu, &mut srng),
            };
            // The pipeline forwards the full dialogue history every call.
            st.tokens_in += 20_000.0;
            ledger.charge_call(&wf.cost, &O3, st);
            ledger.wall_s += AGENTIC_VERIFY_S;
            let outcome = match oracle.check(task, &cfg) {
                Some(o) => {
                    oracle_checks += 1;
                    o
                }
                None => modelled_check(&cfg),
            };
            let compiled = !matches!(outcome, CheckOutcome::CompileError(_));
            ledger.charge_compile(&wf.cost, compiled);
            let score = match &outcome {
                CheckOutcome::Pass => {
                    let out = simulate(wf.gpu, task, &cfg, &wf.sim, 1.0);
                    base_us / (out.runtime_us * srng.lognormal_noise(0.01))
                }
                _ => -1.0,
            };
            let better = round_best
                .as_ref()
                .map(|(s, _, _, _)| score > *s)
                .unwrap_or(true);
            if better {
                round_best = Some((score, score > 0.0, cfg, outcome));
            }
        }
        let (score, correct, cfg, outcome) = round_best.expect("samples > 0");
        if correct && best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
            best = Some((score, cfg.clone()));
        }
        // Verification filtering: keep the best verified candidate as the
        // next round's seed; on failure, carry correction feedback.
        let error_log = match &outcome {
            CheckOutcome::CompileError(l) | CheckOutcome::Mismatch(l) => l.clone(),
            CheckOutcome::Pass => String::new(),
        };
        if !correct {
            let (fb, st) = judge.correction(task, &cfg, &error_log, &mut rng);
            ledger.charge_call(&wf.cost, &O3, st);
            last_fb = Some((fb, error_log, true));
        } else {
            last_fb = None;
        }
        current = Some(match &best {
            Some((_, b)) if correct => b.clone(),
            _ => cfg.clone(),
        });
        rounds.push(RoundLog {
            round,
            mode: if round == 1 { "initial" } else if correct { "optimization" } else { "correction" },
            correct,
            compiled: true,
            speedup: if correct { Some(score) } else { None },
            feedback_json: String::new(),
            config: cfg,
        });
    }

    let (best_speedup, best_config) = match best {
        Some((s, c)) => (s, Some(c)),
        None => (0.0, None),
    };
    TaskResult {
        task_id: task.id(),
        level: task.level,
        correct: best_config.is_some(),
        best_speedup,
        best_config,
        rounds,
        ledger,
        oracle_checks,
        lint: crate::workflow::LintStats::default(),
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::{H200, RTX6000_ADA};
    use crate::tasks::by_id;
    use crate::workflow::{NoOracle, Strategy};

    #[test]
    fn kevin_runs_trajectories_on_h200() {
        let task = by_id("L1-95").unwrap();
        let wf = WorkflowConfig::cudaforge(&H200, 11).with_strategy(Strategy::Kevin);
        let r = run_kevin(&wf, &task, &NoOracle);
        // 16 trajectories x 8 turns of compiles.
        assert_eq!(r.ledger.compiles, (KEVIN_TRAJECTORIES * KEVIN_TURNS) as u32);
        assert_eq!(r.ledger.api_usd, 0.0); // self-hosted
        assert_eq!(r.rounds.len(), KEVIN_TURNS); // logs trajectory 0
    }

    #[test]
    fn agentic_costs_dollars_not_cents() {
        let task = by_id("L2-3").unwrap();
        let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 2)
            .with_strategy(Strategy::AgenticBaseline);
        let r = run_agentic(&wf, &task, &NoOracle);
        assert!(r.ledger.api_usd > 2.0, "agentic usd {}", r.ledger.api_usd);
        assert!(r.ledger.wall_min() > 40.0, "agentic min {}", r.ledger.wall_min());
        assert_eq!(r.rounds.len(), AGENTIC_ROUNDS);
    }

    #[test]
    fn kevin_deterministic() {
        let task = by_id("L1-3").unwrap();
        let wf = WorkflowConfig::cudaforge(&H200, 5).with_strategy(Strategy::Kevin);
        let a = run_kevin(&wf, &task, &NoOracle);
        let b = run_kevin(&wf, &task, &NoOracle);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.correct, b.correct);
    }
}
