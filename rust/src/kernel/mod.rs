//! Kernel-configuration IR — the state a CUDA expert (or the paper's Coder
//! agent) actually manipulates when optimizing a kernel.
//!
//! A `KernelConfig` is the substitute for literal CUDA C++ (DESIGN.md §2): it
//! captures launch geometry, tiling, staging, fusion, and the *latent bugs* a
//! generation may carry. The GPU simulator prices a config on a given task and
//! GPU; the transformation catalog (`transform`) is the optimization action
//! space the Judge suggests moves from.

pub mod transform;

pub use transform::{Opt, OPT_CATALOG};

use crate::gpu::GpuSpec;

/// Latent defect classes. `Compile*` fail the compilation stage; the rest
/// produce wrong outputs at the execution stage (the two-stage correctness
/// test of §2.2). Where a family is bound to real Pallas artifacts, each
/// runtime bug maps onto a genuinely-wrong artifact variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Bug {
    CompileMissingHeader,
    CompileSyntax,
    CompileWrongApi,
    LaunchMisconfig,
    RaceCondition,
    OobIndex,
    UninitValue,
    WrongConstant,
    WrongAxis,
}

pub const ALL_BUGS: [Bug; 9] = [
    Bug::CompileMissingHeader,
    Bug::CompileSyntax,
    Bug::CompileWrongApi,
    Bug::LaunchMisconfig,
    Bug::RaceCondition,
    Bug::OobIndex,
    Bug::UninitValue,
    Bug::WrongConstant,
    Bug::WrongAxis,
];

impl Bug {
    /// Inverse of `name()` (used when deserializing cached configs).
    pub fn by_name(name: &str) -> Option<Bug> {
        ALL_BUGS.iter().copied().find(|b| b.name() == name)
    }

    pub fn is_compile_error(self) -> bool {
        matches!(
            self,
            Bug::CompileMissingHeader | Bug::CompileSyntax | Bug::CompileWrongApi
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            Bug::CompileMissingHeader => "missing_header",
            Bug::CompileSyntax => "syntax_error",
            Bug::CompileWrongApi => "wrong_api_usage",
            Bug::LaunchMisconfig => "launch_misconfig",
            Bug::RaceCondition => "race_condition",
            Bug::OobIndex => "out_of_bounds_index",
            Bug::UninitValue => "uninitialized_value",
            Bug::WrongConstant => "wrong_constant",
            Bug::WrongAxis => "wrong_axis_reduction",
        }
    }

    /// Short error-log line the correctness stage surfaces for this bug —
    /// what the Judge's correction mode gets to read (Appendix A, ERROR_LOG).
    pub fn error_log(self) -> &'static str {
        match self {
            Bug::CompileMissingHeader => {
                "error: identifier \"__shfl_down_sync\" is undefined (missing #include?)"
            }
            Bug::CompileSyntax => "error: expected a \";\" near kernel body",
            Bug::CompileWrongApi => {
                "error: no instance of overloaded function matches the argument list"
            }
            Bug::LaunchMisconfig => {
                "CUDA error: invalid configuration argument (grid/block mismatch)"
            }
            Bug::RaceCondition => {
                "Outputs are not close: nondeterministic mismatch across runs"
            }
            Bug::OobIndex => "Outputs are not close: tail elements differ from reference",
            Bug::UninitValue => {
                "Outputs are not close, indicating a result mismatch (row 0 differs)"
            }
            Bug::WrongConstant => "Outputs are not close: uniform small bias vs reference",
            Bug::WrongAxis => "Outputs are not close: rows/columns appear permuted",
        }
    }

    /// How legible the failure is from the error log alone, in [0, 1] — the
    /// Judge's diagnosis probability scales with this. Compile errors carry
    /// the exact line; races are famously hard to see.
    pub fn observability(self) -> f64 {
        match self {
            Bug::CompileMissingHeader | Bug::CompileSyntax | Bug::CompileWrongApi => 0.98,
            Bug::LaunchMisconfig => 0.95,
            Bug::OobIndex => 0.80,
            Bug::UninitValue => 0.75,
            Bug::WrongAxis => 0.80,
            Bug::WrongConstant => 0.65,
            Bug::RaceCondition => 0.55,
        }
    }
}

/// One CUDA-kernel candidate, as configuration state.
///
/// Fields are what NCU + the source reveal to an expert; the simulator prices
/// them, the transforms mutate them, the bugs ride along until a correction
/// round removes them.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelConfig {
    /// Threads per block (multiple of warp size, <= 1024).
    pub block_threads: u32,
    /// Output tile computed per block.
    pub tile_m: u32,
    pub tile_n: u32,
    /// K-chunk staged per iteration (reuse classes only).
    pub tile_k: u32,
    /// Elements per 32-bit lane access (1, 2, 4 — float/float2/float4).
    pub vector_width: u32,
    /// Inner-loop unroll factor.
    pub unroll: u32,
    /// Stage operands through shared memory (VMEM in the Pallas mapping).
    pub use_smem: bool,
    /// Shared-memory tiles are padded to dodge bank conflicts.
    pub smem_padded: bool,
    /// Double-buffered global->shared pipeline.
    pub double_buffer: bool,
    /// Registers per thread the compiler settles on.
    pub regs_per_thread: u32,
    /// `__syncthreads()` per tile iteration.
    pub syncs_per_tile: u32,
    /// Reductions use warp shuffles instead of shared memory + barriers.
    pub warp_shuffle: bool,
    /// Global accesses are coalesced.
    pub coalesced: bool,
    /// Tensor cores (MXU in the Pallas mapping) engaged.
    pub use_tensor_cores: bool,
    /// How many of the task's fusable stages this kernel covers (>= 1).
    pub fused_stages: u32,
    /// Redundant full passes over the inputs (e.g. re-reading logits).
    pub extra_global_passes: u32,
    /// Single-pass online algorithm (e.g. online softmax).
    pub online_algorithm: bool,
    /// Grid-stride loop lets one block cover multiple tiles (tail smoothing).
    pub grid_stride: bool,
    /// Kernel avoids the reference's algorithmic waste (e.g. computes
    /// `B * A[:, None]` instead of materializing `diag(A) @ B`).
    pub algo_optimal: bool,
    /// Latent defects.
    pub bugs: Vec<Bug>,
}

impl KernelConfig {
    /// The configuration equivalent of a first naive-but-honest kernel: one
    /// thread per element, no staging, no fusion beyond the first stage.
    pub fn naive() -> KernelConfig {
        KernelConfig {
            block_threads: 256,
            tile_m: 16,
            tile_n: 16,
            tile_k: 8,
            vector_width: 1,
            unroll: 1,
            use_smem: false,
            smem_padded: false,
            double_buffer: false,
            regs_per_thread: 40,
            syncs_per_tile: 0,
            warp_shuffle: false,
            coalesced: false,
            use_tensor_cores: false,
            fused_stages: 1,
            extra_global_passes: 1,
            online_algorithm: false,
            grid_stride: false,
            algo_optimal: false,
            bugs: Vec::new(),
        }
    }

    /// Shared memory bytes per block implied by the staging choices.
    pub fn smem_bytes(&self) -> f64 {
        if !self.use_smem {
            return 0.0;
        }
        let pad = if self.smem_padded { 1.03 } else { 1.0 };
        let buf = if self.double_buffer { 2.0 } else { 1.0 };
        let a = (self.tile_m * self.tile_k) as f64;
        let b = (self.tile_k * self.tile_n) as f64;
        (a + b) * 4.0 * pad * buf
    }

    pub fn has_compile_error(&self) -> bool {
        self.bugs.iter().any(|b| b.is_compile_error())
    }

    pub fn is_buggy(&self) -> bool {
        !self.bugs.is_empty()
    }

    pub fn remove_bug(&mut self, bug: Bug) -> bool {
        let before = self.bugs.len();
        self.bugs.retain(|&b| b != bug);
        self.bugs.len() != before
    }

    /// Clamp every field into the legal envelope for `gpu`. Transform
    /// applications call this so *any* sequence of transforms stays valid
    /// (property-tested in `transform::tests`).
    pub fn legalize(&mut self, gpu: &GpuSpec) {
        let ws = gpu.warp_size;
        self.block_threads = self
            .block_threads
            .clamp(ws, gpu.max_threads_per_block)
            .next_multiple_of(ws);
        self.tile_m = self.tile_m.clamp(1, 256);
        self.tile_n = self.tile_n.clamp(1, 256);
        self.tile_k = self.tile_k.clamp(1, 128);
        self.vector_width = match self.vector_width {
            0 | 1 => 1,
            2 | 3 => 2,
            _ => 4,
        };
        self.unroll = self.unroll.clamp(1, 16).next_power_of_two();
        self.regs_per_thread = self.regs_per_thread.clamp(24, 255);
        self.syncs_per_tile = self.syncs_per_tile.min(32);
        self.fused_stages = self.fused_stages.max(1);
        self.extra_global_passes = self.extra_global_passes.min(4);
        // Shared-memory footprint must fit the per-block cap; shrink tile_k
        // (the staging depth) until it does.
        while self.use_smem
            && self.smem_bytes() > gpu.smem_per_block_kb * 1024.0
            && self.tile_k > 1
        {
            self.tile_k /= 2;
        }
        // Register file: a block must be schedulable at all.
        let max_regs = gpu.regs_per_sm / self.block_threads;
        self.regs_per_thread = self.regs_per_thread.min(max_regs.max(24));
        self.bugs.dedup();
    }

    /// True when the config violates hard launch limits (used as the
    /// `LaunchMisconfig` trigger and in property tests).
    pub fn is_legal(&self, gpu: &GpuSpec) -> bool {
        self.block_threads >= gpu.warp_size
            && self.block_threads <= gpu.max_threads_per_block
            && self.block_threads % gpu.warp_size == 0
            && self.smem_bytes() <= gpu.smem_per_block_kb * 1024.0
            && self.regs_per_thread >= 24
            && self.regs_per_thread <= 255
            && self.fused_stages >= 1
    }

    /// Serialize for the service layer's JSONL cache snapshots.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("block_threads", Json::num(self.block_threads)),
            ("tile_m", Json::num(self.tile_m)),
            ("tile_n", Json::num(self.tile_n)),
            ("tile_k", Json::num(self.tile_k)),
            ("vector_width", Json::num(self.vector_width)),
            ("unroll", Json::num(self.unroll)),
            ("use_smem", Json::Bool(self.use_smem)),
            ("smem_padded", Json::Bool(self.smem_padded)),
            ("double_buffer", Json::Bool(self.double_buffer)),
            ("regs_per_thread", Json::num(self.regs_per_thread)),
            ("syncs_per_tile", Json::num(self.syncs_per_tile)),
            ("warp_shuffle", Json::Bool(self.warp_shuffle)),
            ("coalesced", Json::Bool(self.coalesced)),
            ("use_tensor_cores", Json::Bool(self.use_tensor_cores)),
            ("fused_stages", Json::num(self.fused_stages)),
            ("extra_global_passes", Json::num(self.extra_global_passes)),
            ("online_algorithm", Json::Bool(self.online_algorithm)),
            ("grid_stride", Json::Bool(self.grid_stride)),
            ("algo_optimal", Json::Bool(self.algo_optimal)),
            (
                "bugs",
                Json::Arr(self.bugs.iter().map(|b| Json::str(b.name())).collect()),
            ),
        ])
    }

    /// Inverse of `to_json`. `None` on a malformed document.
    pub fn from_json(v: &crate::util::json::Json) -> Option<KernelConfig> {
        let u32_of = |k: &str| v.get(k)?.as_f64().map(|n| n as u32);
        let bool_of = |k: &str| v.get(k)?.as_bool();
        Some(KernelConfig {
            block_threads: u32_of("block_threads")?,
            tile_m: u32_of("tile_m")?,
            tile_n: u32_of("tile_n")?,
            tile_k: u32_of("tile_k")?,
            vector_width: u32_of("vector_width")?,
            unroll: u32_of("unroll")?,
            use_smem: bool_of("use_smem")?,
            smem_padded: bool_of("smem_padded")?,
            double_buffer: bool_of("double_buffer")?,
            regs_per_thread: u32_of("regs_per_thread")?,
            syncs_per_tile: u32_of("syncs_per_tile")?,
            warp_shuffle: bool_of("warp_shuffle")?,
            coalesced: bool_of("coalesced")?,
            use_tensor_cores: bool_of("use_tensor_cores")?,
            fused_stages: u32_of("fused_stages")?,
            extra_global_passes: u32_of("extra_global_passes")?,
            online_algorithm: bool_of("online_algorithm")?,
            grid_stride: bool_of("grid_stride")?,
            algo_optimal: bool_of("algo_optimal")?,
            bugs: v
                .get("bugs")?
                .as_arr()?
                .iter()
                // An unknown bug name is a malformed document, not an empty
                // bug list — dropping it would deserialize a config that
                // looks healthier than what was written (cache restores must
                // fail loudly instead).
                .map(|b| b.as_str().and_then(Bug::by_name))
                .collect::<Option<Vec<_>>>()?,
        })
    }

    /// Compact source-like fingerprint used in prompts and logs.
    pub fn describe(&self) -> String {
        format!(
            "block={} tile={}x{}x{} vec={} unroll={} smem={}{}{} regs={} syncs={} \
             shuffle={} coalesced={} tc={} fused={} extra_passes={} online={} bugs=[{}]",
            self.block_threads,
            self.tile_m,
            self.tile_n,
            self.tile_k,
            self.vector_width,
            self.unroll,
            self.use_smem,
            if self.smem_padded { "+pad" } else { "" },
            if self.double_buffer { "+dbuf" } else { "" },
            self.regs_per_thread,
            self.syncs_per_tile,
            self.warp_shuffle,
            self.coalesced,
            self.use_tensor_cores,
            self.fused_stages,
            self.extra_global_passes,
            self.online_algorithm,
            self.bugs
                .iter()
                .map(|b| b.name())
                .collect::<Vec<_>>()
                .join(","),
        )
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;

    #[test]
    fn naive_is_legal() {
        let c = KernelConfig::naive();
        assert!(c.is_legal(&RTX6000_ADA));
        assert!(!c.is_buggy());
        assert_eq!(c.smem_bytes(), 0.0);
    }

    #[test]
    fn legalize_fixes_block_threads_and_smem() {
        let mut c = KernelConfig::naive();
        c.block_threads = 1000; // not a multiple of 32
        c.use_smem = true;
        c.tile_m = 256;
        c.tile_n = 256;
        c.tile_k = 128;
        c.double_buffer = true;
        c.legalize(&RTX6000_ADA);
        assert!(c.is_legal(&RTX6000_ADA), "{}", c.describe());
    }

    #[test]
    fn compile_bug_classification() {
        let mut c = KernelConfig::naive();
        c.bugs.push(Bug::CompileSyntax);
        assert!(c.has_compile_error());
        c.bugs.clear();
        c.bugs.push(Bug::OobIndex);
        assert!(!c.has_compile_error());
        assert!(c.is_buggy());
        assert!(c.remove_bug(Bug::OobIndex));
        assert!(!c.is_buggy());
        assert!(!c.remove_bug(Bug::OobIndex));
    }

    #[test]
    fn config_json_round_trips() {
        let mut c = KernelConfig::naive();
        c.use_smem = true;
        c.tile_m = 64;
        c.warp_shuffle = true;
        c.bugs.push(Bug::OobIndex);
        let wire = c.to_json().to_string();
        let v = crate::util::json::Json::parse(&wire).unwrap();
        assert_eq!(KernelConfig::from_json(&v), Some(c));
        assert!(KernelConfig::from_json(&crate::util::json::Json::Null).is_none());
        // An unknown bug name must reject the whole document, not silently
        // deserialize a healthier-looking config.
        let corrupt = wire.replace("out_of_bounds_index", "oob_idx");
        let v = crate::util::json::Json::parse(&corrupt).unwrap();
        assert!(KernelConfig::from_json(&v).is_none());
    }

    #[test]
    fn bug_observability_ordering() {
        // Compile errors are the most legible, races the least.
        assert!(Bug::CompileSyntax.observability() > Bug::OobIndex.observability());
        assert!(Bug::OobIndex.observability() > Bug::RaceCondition.observability());
        for b in ALL_BUGS {
            assert!(!b.error_log().is_empty());
            assert!((0.0..=1.0).contains(&b.observability()));
        }
    }
}
