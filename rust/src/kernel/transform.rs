//! Transformation catalog — the optimization action space.
//!
//! Every optimization the paper's Judge ever suggests (Fig. 3, Fig. 8,
//! Appendix B.1: shared-memory staging, warp-shuffle reductions, register
//! reduction, redundant-pass elimination, fusion, tensor cores, online
//! algorithms, ...) is one `Opt`. Each knows which `Bottleneck` it addresses,
//! whether it applies to a (task, config) pair, and how it rewrites the
//! config. The Judge's optimization mode diagnoses a bottleneck from hardware
//! feedback and picks an `Opt` targeting it; the Coder applies it with
//! skill-dependent fidelity.

use crate::gpu::GpuSpec;
use crate::kernel::KernelConfig;
use crate::tasks::TaskSpec;

/// Dominant performance limiter, as the Judge names it (Fig. 3: "register- or
/// memory-limited", "compute-bound or memory-bound", ...).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Bottleneck {
    /// DRAM-bound: traffic is the wall (useful + wasted bytes).
    MemBandwidth,
    /// Long-scoreboard stalls: global latency not hidden (low occupancy or
    /// redundant passes).
    MemLatency,
    /// Wasted sectors from uncoalesced access patterns.
    Uncoalesced,
    /// Barrier-type warp stalls from `__syncthreads()`.
    BarrierStall,
    /// Occupancy capped by registers per thread.
    OccupancyRegisters,
    /// Occupancy capped by shared memory per block.
    OccupancySmem,
    /// FP32 pipe saturated while tensor pipes idle (or just compute-bound).
    ComputeBound,
    /// Short-scoreboard stalls (shared-memory bank conflicts).
    ShortScoreboard,
    /// Kernel-launch / unfused-stage overhead dominates.
    LaunchOverhead,
    /// The algorithm itself does redundant work vs the optimal one.
    AlgorithmicWaste,
    /// Near roofline; nothing actionable.
    None,
}

impl Bottleneck {
    pub fn name(self) -> &'static str {
        match self {
            Bottleneck::MemBandwidth => "memory-bandwidth-bound",
            Bottleneck::MemLatency => "memory-latency-bound",
            Bottleneck::Uncoalesced => "uncoalesced-global-access",
            Bottleneck::BarrierStall => "barrier-stall-bound",
            Bottleneck::OccupancyRegisters => "occupancy-limited-by-registers",
            Bottleneck::OccupancySmem => "occupancy-limited-by-shared-memory",
            Bottleneck::ComputeBound => "compute-bound",
            Bottleneck::ShortScoreboard => "shared-memory-bank-conflicts",
            Bottleneck::LaunchOverhead => "launch-overhead-bound",
            Bottleneck::AlgorithmicWaste => "algorithmically-redundant-work",
            Bottleneck::None => "near-roofline",
        }
    }
}

/// One optimization move.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Opt {
    CoalesceAccesses,
    VectorizeLoads,
    UseSharedMemoryTiling,
    IncreaseTileSize,
    WarpShuffleReduction,
    ReduceSyncs,
    ReduceRegisterPressure,
    ShrinkBlock,
    PadSharedMemory,
    DoubleBuffer,
    CacheInRegisters,
    FuseStages,
    UseTensorCores,
    IncreaseUnroll,
    OnlineAlgorithm,
    AlgorithmicRewrite,
    GridStrideLoop,
}

/// Full catalog, in a stable order (prompt rendering + tests rely on it).
pub const OPT_CATALOG: [Opt; 17] = [
    Opt::CoalesceAccesses,
    Opt::VectorizeLoads,
    Opt::UseSharedMemoryTiling,
    Opt::IncreaseTileSize,
    Opt::WarpShuffleReduction,
    Opt::ReduceSyncs,
    Opt::ReduceRegisterPressure,
    Opt::ShrinkBlock,
    Opt::PadSharedMemory,
    Opt::DoubleBuffer,
    Opt::CacheInRegisters,
    Opt::FuseStages,
    Opt::UseTensorCores,
    Opt::IncreaseUnroll,
    Opt::OnlineAlgorithm,
    Opt::AlgorithmicRewrite,
    Opt::GridStrideLoop,
];

impl Opt {
    pub fn name(self) -> &'static str {
        match self {
            Opt::CoalesceAccesses => "coalesce_global_accesses",
            Opt::VectorizeLoads => "vectorize_loads_float4",
            Opt::UseSharedMemoryTiling => "shared_memory_tiling",
            Opt::IncreaseTileSize => "increase_tile_size",
            Opt::WarpShuffleReduction => "warp_shuffle_reduction",
            Opt::ReduceSyncs => "reduce_syncthreads",
            Opt::ReduceRegisterPressure => "reduce_register_pressure",
            Opt::ShrinkBlock => "shrink_block_size",
            Opt::PadSharedMemory => "pad_shared_memory",
            Opt::DoubleBuffer => "double_buffer_pipeline",
            Opt::CacheInRegisters => "cache_inputs_in_registers",
            Opt::FuseStages => "fuse_adjacent_stages",
            Opt::UseTensorCores => "use_tensor_cores",
            Opt::IncreaseUnroll => "increase_unroll",
            Opt::OnlineAlgorithm => "online_single_pass_algorithm",
            Opt::AlgorithmicRewrite => "algorithmic_rewrite",
            Opt::GridStrideLoop => "grid_stride_loop",
        }
    }

    /// Judge-voice suggestion text (feeds the Coder's optimization prompt,
    /// mirroring the JSON `optimisation method` field of Appendix A).
    pub fn suggestion(self) -> &'static str {
        match self {
            Opt::CoalesceAccesses => {
                "reorder thread-to-data mapping so adjacent lanes touch adjacent \
                 addresses; eliminate strided global access"
            }
            Opt::VectorizeLoads => {
                "widen global loads/stores to float4 to cut sector requests per byte"
            }
            Opt::UseSharedMemoryTiling => {
                "stage operand tiles through shared memory to raise data reuse"
            }
            Opt::IncreaseTileSize => {
                "enlarge the per-block output tile to improve arithmetic intensity"
            }
            Opt::WarpShuffleReduction => {
                "use warp-level shuffles in the reduction phases, then a single \
                 cross-warp combine, cutting __syncthreads() per block"
            }
            Opt::ReduceSyncs => "remove redundant __syncthreads() between phases",
            Opt::ReduceRegisterPressure => {
                "reduce per-thread registers to raise resident warps and improve \
                 latency hiding"
            }
            Opt::ShrinkBlock => {
                "shrink the thread block so more blocks fit per SM (occupancy \
                 granularity)"
            }
            Opt::PadSharedMemory => {
                "pad shared-memory tiles by one element to remove bank conflicts"
            }
            Opt::DoubleBuffer => {
                "double-buffer the global->shared pipeline to overlap loads with \
                 compute"
            }
            Opt::CacheInRegisters => {
                "cache the re-read inputs in per-thread registers during the first \
                 pass, eliminating the redundant global read"
            }
            Opt::FuseStages => {
                "fuse the adjacent elementwise/reduction stage into the kernel to \
                 avoid one intermediate HBM round-trip"
            }
            Opt::UseTensorCores => {
                "map the inner product onto tensor cores (mma) with 16x16 fragments \
                 staged via shared memory"
            }
            Opt::IncreaseUnroll => {
                "unroll the inner loop to expose instruction-level parallelism"
            }
            Opt::OnlineAlgorithm => {
                "switch to a single-pass online algorithm (running max/sum) to \
                 remove one full input pass"
            }
            Opt::AlgorithmicRewrite => {
                "replace the redundant reference algorithm with the direct \
                 formulation (avoid materializing intermediate operands)"
            }
            Opt::GridStrideLoop => {
                "use a grid-stride loop so one wave of blocks covers the whole \
                 problem (smooths the tail)"
            }
        }
    }

    /// Which bottleneck this move addresses (the Judge picks moves whose
    /// target matches its diagnosis).
    pub fn target(self) -> Bottleneck {
        match self {
            Opt::CoalesceAccesses => Bottleneck::Uncoalesced,
            Opt::VectorizeLoads => Bottleneck::MemBandwidth,
            Opt::UseSharedMemoryTiling => Bottleneck::MemBandwidth,
            Opt::IncreaseTileSize => Bottleneck::MemBandwidth,
            Opt::WarpShuffleReduction => Bottleneck::BarrierStall,
            Opt::ReduceSyncs => Bottleneck::BarrierStall,
            Opt::ReduceRegisterPressure => Bottleneck::OccupancyRegisters,
            Opt::ShrinkBlock => Bottleneck::OccupancySmem,
            Opt::PadSharedMemory => Bottleneck::ShortScoreboard,
            Opt::DoubleBuffer => Bottleneck::MemLatency,
            Opt::CacheInRegisters => Bottleneck::MemLatency,
            Opt::FuseStages => Bottleneck::LaunchOverhead,
            Opt::UseTensorCores => Bottleneck::ComputeBound,
            Opt::IncreaseUnroll => Bottleneck::ComputeBound,
            Opt::OnlineAlgorithm => Bottleneck::MemBandwidth,
            Opt::AlgorithmicRewrite => Bottleneck::AlgorithmicWaste,
            Opt::GridStrideLoop => Bottleneck::LaunchOverhead,
        }
    }

    /// Can this move still do anything for (task, cfg)?
    pub fn applicable(self, task: &TaskSpec, cfg: &KernelConfig) -> bool {
        match self {
            Opt::CoalesceAccesses => !cfg.coalesced,
            Opt::VectorizeLoads => cfg.vector_width < 4,
            Opt::UseSharedMemoryTiling => !cfg.use_smem && task.op_class.has_data_reuse(),
            Opt::IncreaseTileSize => {
                task.op_class.has_data_reuse() && cfg.tile_m < 128 && cfg.tile_n < 128
            }
            Opt::WarpShuffleReduction => !cfg.warp_shuffle && cfg.syncs_per_tile >= 2,
            Opt::ReduceSyncs => cfg.syncs_per_tile >= 3,
            Opt::ReduceRegisterPressure => cfg.regs_per_thread > 48,
            Opt::ShrinkBlock => cfg.block_threads > 128,
            Opt::PadSharedMemory => cfg.use_smem && !cfg.smem_padded,
            Opt::DoubleBuffer => cfg.use_smem && !cfg.double_buffer,
            Opt::CacheInRegisters => cfg.extra_global_passes > 0,
            Opt::FuseStages => cfg.fused_stages < task.stages,
            Opt::UseTensorCores => task.tc_eligible && !cfg.use_tensor_cores,
            Opt::IncreaseUnroll => cfg.unroll < 8,
            Opt::OnlineAlgorithm => {
                task.op_class.online_eligible() && !cfg.online_algorithm
            }
            Opt::AlgorithmicRewrite => task.baseline_waste > 1.0 && !cfg.algo_optimal,
            Opt::GridStrideLoop => !cfg.grid_stride,
        }
    }

    /// Apply the move faithfully (the Coder may instead mis-apply — that is
    /// modelled in `agents::coder`, not here). Always re-legalizes.
    pub fn apply(self, cfg: &mut KernelConfig, task: &TaskSpec, gpu: &GpuSpec) {
        match self {
            Opt::CoalesceAccesses => cfg.coalesced = true,
            Opt::VectorizeLoads => cfg.vector_width = 4,
            Opt::UseSharedMemoryTiling => {
                cfg.use_smem = true;
                cfg.tile_k = cfg.tile_k.max(16);
                cfg.tile_m = cfg.tile_m.max(32);
                cfg.tile_n = cfg.tile_n.max(32);
                cfg.syncs_per_tile = cfg.syncs_per_tile.max(2);
                cfg.regs_per_thread += 16;
            }
            Opt::IncreaseTileSize => {
                cfg.tile_m *= 2;
                cfg.tile_n *= 2;
                cfg.regs_per_thread += 24;
            }
            Opt::WarpShuffleReduction => {
                cfg.warp_shuffle = true;
                // e.g. Fig. 8 round 2: "__syncthreads() per block from 16 to 2".
                cfg.syncs_per_tile = cfg.syncs_per_tile.min(2);
            }
            Opt::ReduceSyncs => {
                cfg.syncs_per_tile = cfg.syncs_per_tile.saturating_sub(2).max(1)
            }
            Opt::ReduceRegisterPressure => {
                cfg.regs_per_thread = cfg.regs_per_thread.saturating_sub(32).max(32)
            }
            Opt::ShrinkBlock => cfg.block_threads = (cfg.block_threads / 2).max(128),
            Opt::PadSharedMemory => cfg.smem_padded = true,
            Opt::DoubleBuffer => {
                cfg.double_buffer = true;
                cfg.regs_per_thread += 8;
            }
            Opt::CacheInRegisters => {
                cfg.extra_global_passes = cfg.extra_global_passes.saturating_sub(1);
                cfg.regs_per_thread += 12;
            }
            Opt::FuseStages => {
                cfg.fused_stages = (cfg.fused_stages + 1).min(task.stages)
            }
            Opt::UseTensorCores => {
                cfg.use_tensor_cores = true;
                cfg.use_smem = true;
                cfg.tile_m = cfg.tile_m.max(32).next_multiple_of(16);
                cfg.tile_n = cfg.tile_n.max(32).next_multiple_of(16);
                cfg.tile_k = cfg.tile_k.max(16).next_multiple_of(16);
                cfg.syncs_per_tile = cfg.syncs_per_tile.max(2);
            }
            Opt::IncreaseUnroll => {
                cfg.unroll *= 2;
                cfg.regs_per_thread += 8;
            }
            Opt::OnlineAlgorithm => {
                cfg.online_algorithm = true;
                cfg.extra_global_passes = cfg.extra_global_passes.saturating_sub(1);
            }
            Opt::AlgorithmicRewrite => cfg.algo_optimal = true,
            Opt::GridStrideLoop => cfg.grid_stride = true,
        }
        cfg.legalize(gpu);
    }

    /// Moves addressing `b`, in catalog order.
    pub fn for_bottleneck(b: Bottleneck) -> Vec<Opt> {
        OPT_CATALOG.iter().copied().filter(|o| o.target() == b).collect()
    }

    pub fn by_name(name: &str) -> Option<Opt> {
        OPT_CATALOG.iter().copied().find(|o| o.name() == name)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;
    use crate::tasks::{by_id, kernelbench};
    use crate::util::prop;

    #[test]
    fn every_bottleneck_has_a_move() {
        for b in [
            Bottleneck::MemBandwidth,
            Bottleneck::MemLatency,
            Bottleneck::Uncoalesced,
            Bottleneck::BarrierStall,
            Bottleneck::OccupancyRegisters,
            Bottleneck::OccupancySmem,
            Bottleneck::ComputeBound,
            Bottleneck::ShortScoreboard,
            Bottleneck::LaunchOverhead,
            Bottleneck::AlgorithmicWaste,
        ] {
            assert!(!Opt::for_bottleneck(b).is_empty(), "{b:?} unaddressed");
        }
    }

    #[test]
    fn name_round_trip() {
        for o in OPT_CATALOG {
            assert_eq!(Opt::by_name(o.name()), Some(o));
            assert!(!o.suggestion().is_empty());
        }
        assert_eq!(Opt::by_name("not_a_move"), None);
    }

    #[test]
    fn warp_shuffle_cuts_syncs_like_fig8() {
        let task = by_id("L1-95").unwrap();
        let mut cfg = KernelConfig::naive();
        cfg.syncs_per_tile = 16;
        Opt::WarpShuffleReduction.apply(&mut cfg, &task, &RTX6000_ADA);
        assert_eq!(cfg.syncs_per_tile, 2); // "from 16 to 2 (a reduction of 14)"
        assert!(cfg.warp_shuffle);
    }

    /// Property: any sequence of applicable transforms keeps the config legal
    /// and applicability is monotone (an applied move stops being applicable
    /// for idempotent moves).
    #[test]
    fn prop_transform_sequences_stay_legal() {
        let tasks = kernelbench();
        prop::check("transforms-legal", 0xC0DE, |rng| {
            let task = &tasks[rng.below(tasks.len())];
            let mut cfg = KernelConfig::naive();
            cfg.legalize(&RTX6000_ADA);
            for _ in 0..rng.range_usize(1, 12) {
                let o = OPT_CATALOG[rng.below(OPT_CATALOG.len())];
                if o.applicable(task, &cfg) {
                    o.apply(&mut cfg, task, &RTX6000_ADA);
                    prop::ensure(
                        cfg.is_legal(&RTX6000_ADA),
                        format!("illegal after {:?}: {}", o, cfg.describe()),
                    )?;
                }
            }
            Ok(())
        });
    }

    /// Property: the applicable-guard is honest. Whenever a move claims it
    /// can still improve a config (`applicable` is true), applying it must
    /// actually change the config — a move that is a no-op on configs it
    /// claims to improve would make the Judge spin on phantom suggestions.
    /// Checked along random transform walks so every catalog entry is probed
    /// against diverse intermediate states, not just the naive seed.
    #[test]
    fn prop_applicable_moves_are_never_noops() {
        let tasks = kernelbench();
        prop::check("applicable-not-noop", 0x0A11, |rng| {
            let task = &tasks[rng.below(tasks.len())];
            let mut cfg = KernelConfig::naive();
            cfg.legalize(&RTX6000_ADA);
            for _ in 0..rng.range_usize(1, 10) {
                for o in OPT_CATALOG {
                    if !o.applicable(task, &cfg) {
                        continue;
                    }
                    let mut probe = cfg.clone();
                    o.apply(&mut probe, task, &RTX6000_ADA);
                    prop::ensure(
                        probe != cfg,
                        format!("{o:?} claims applicable but is a no-op on {}", cfg.describe()),
                    )?;
                    prop::ensure(
                        probe.is_legal(&RTX6000_ADA),
                        format!("{o:?} produced illegal config {}", probe.describe()),
                    )?;
                }
                // Advance the walk one real step.
                let open: Vec<Opt> =
                    OPT_CATALOG.iter().copied().filter(|o| o.applicable(task, &cfg)).collect();
                if open.is_empty() {
                    break;
                }
                open[rng.below(open.len())].apply(&mut cfg, task, &RTX6000_ADA);
            }
            Ok(())
        });
    }

    /// Property: idempotent boolean moves are not applicable twice.
    #[test]
    fn prop_bool_moves_not_reapplicable() {
        let tasks = kernelbench();
        prop::check("bool-moves-once", 0xBEEF, |rng| {
            let task = &tasks[rng.below(tasks.len())];
            let mut cfg = KernelConfig::naive();
            for o in [
                Opt::CoalesceAccesses,
                Opt::UseSharedMemoryTiling,
                Opt::UseTensorCores,
                Opt::OnlineAlgorithm,
                Opt::GridStrideLoop,
                Opt::AlgorithmicRewrite,
                Opt::PadSharedMemory,
                Opt::DoubleBuffer,
            ] {
                if o.applicable(task, &cfg) {
                    o.apply(&mut cfg, task, &RTX6000_ADA);
                    prop::ensure(
                        !o.applicable(task, &cfg),
                        format!("{o:?} applicable twice"),
                    )?;
                }
            }
            Ok(())
        });
    }
}
