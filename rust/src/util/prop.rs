//! Property-testing mini-harness (proptest is unavailable offline).
//!
//! `check` runs a property over many seeded random cases and, on failure,
//! reports the failing case seed so the case can be replayed exactly:
//! every generator draws from a fresh `Rng::new(case_seed)`. No shrinking —
//! failures print the seed instead, which is enough to reproduce and debug.

use crate::util::rng::Rng;

/// Number of cases the repo-wide property tests run per property.
pub const DEFAULT_CASES: u64 = 128;

/// Run `prop` over `cases` deterministic cases. `base_seed` separates
/// properties from each other so adding a property never reshuffles cases
/// of the others.
pub fn check_with<F>(name: &str, base_seed: u64, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {case_seed}): {msg}"
            );
        }
    }
}

/// `check` with the default case count.
pub fn check<F>(name: &str, base_seed: u64, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_with(name, base_seed, DEFAULT_CASES, prop);
}

/// Assertion helpers returning `Result` so properties compose with `?`.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check_with("count", 1, 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check_with("fails", 2, 10, |rng| {
            ensure(rng.f64() < 0.5, "value too large")
        });
    }

    #[test]
    fn ensure_close_scales_tolerance() {
        assert!(ensure_close(1000.0, 1000.05, 1e-4, "x").is_ok());
        assert!(ensure_close(0.0, 0.5, 1e-4, "x").is_err());
    }
}
