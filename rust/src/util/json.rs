//! Minimal JSON value + parser + serializer.
//!
//! Used for three protocol surfaces (serde is unavailable offline, DESIGN.md
//! §2): the artifact `manifest.json` written by `python/compile/aot.py`, the
//! Judge's structured-feedback JSON (the paper's Appendix A output schema),
//! and the CSV/JSON result series under `results/`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted-path lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        dotted.split('.').try_fold(self, |v, k| v.get(k))
    }

    // -- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact serialization (valid JSON; floats that are integral print
    /// without the fraction so python can read them back as ints).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed by our writers;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().expect("validated non-empty utf-8 slice");
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .expect("number span is ascii by construction");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1,
          "entries": [
            {"name": "matmul_tiled", "buggy": false, "tol": 1e-4,
             "inputs": [{"shape": [128, 128], "dtype": "f32", "lo": -2.0}]}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.path("version").unwrap().as_f64(), Some(1.0));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("matmul_tiled"));
        assert_eq!(e.get("buggy").unwrap().as_bool(), Some(false));
        assert!((e.get("tol").unwrap().as_f64().unwrap() - 1e-4).abs() < 1e-12);
        let shape = e.path("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("bottleneck", Json::str("DRAM-bound (102.9% peak)")),
            ("score", Json::num(1.677)),
            ("rounds", Json::Arr(vec![Json::num(1.0), Json::num(2.5)])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("line1\nline2\t\"quoted\" \\ slash");
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }
}
