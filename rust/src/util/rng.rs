//! Deterministic, seedable PRNG (SplitMix64 seeding a xoshiro256**).
//!
//! The offline vendor set only carries `rand_core` (traits, no generators),
//! so the generator lives here. Every stochastic decision in the agent models
//! and the task suite flows through this type, which is what makes suite runs
//! bit-reproducible (`coordinator` tests assert same-seed => same results).

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (used to give each task/agent its own rng).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi) — matches the python-side input generators.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style unbiased rejection is overkill here; modulo bias is
        // negligible for our n << 2^64 and this path is hot.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller; one value per call, simple and fine here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/sd.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal multiplicative noise with multiplicative sd ~ `sigma`.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Weighted pick; weights need not be normalized (must be >= 0, sum > 0).
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_center() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.range_f64(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted_choice(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_indices(100, 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
