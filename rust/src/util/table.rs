//! ASCII table rendering for the paper-style report output.

/// Column-aligned table with a header rule, in the style of the paper's
/// tables. Cells are plain strings; numeric formatting is the caller's job.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("| ");
            for i in 0..ncol {
                // First column left-aligned, the rest right-aligned (numbers).
                if i == 0 {
                    s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    s.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
                s.push_str(" | ");
            }
            s.pop();
            s
        };
        let rule: String = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// CSV form (written under results/ so figures can be re-plotted).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helpers matching the paper's precision conventions.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["Method", "Perf"]);
        t.row(vec!["CudaForge".into(), "1.677".into()]);
        t.row(vec!["o3".into(), "0.680".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| CudaForge | 1.677 |"));
        assert!(s.contains("| o3        | 0.680 |"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x,y".into(), "1".into()]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",1\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new("T", &["a", "b"]).row(vec!["only-one".into()]);
    }
}
