//! Self-contained infrastructure (the offline vendor set only carries the
//! `xla` closure — see DESIGN.md §2): JSON, PRNG, statistics, CLI parsing,
//! ASCII tables, and a property-testing harness.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod bench;
pub mod table;
