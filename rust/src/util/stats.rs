//! Statistics helpers: the paper's evaluation metrics (median / 75th
//! percentile / mean / Fast_1) and the Pearson correlation that drives the
//! offline NCU metric-selection pipeline (Algorithms 1–2).

/// Arithmetic mean; 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with **linear interpolation between closest ranks** (the
/// Hyndman–Fan R-7 estimator, numpy's default), *not* nearest-rank: the
/// rank is `q/100 * (n-1)` and a fractional rank interpolates between the
/// two neighbouring order statistics. q in [0, 100]. NaNs are rejected by
/// debug assert; callers filter failures first.
///
/// # Small-sample behaviour
///
/// High percentiles need samples in the tail to mean anything. The
/// interpolated rank `q/100 * (n-1)` exceeds `n - 2` whenever
/// `n < (200 - q) / (100 - q)` — e.g. p99 with up to 100 samples, or p95
/// with up to 20 — and the result is then an interpolation between the
/// two largest samples, i.e. practically the max (exactly the max for
/// n = 1 or all-equal input). Service/cluster replays routinely report p99 over small
/// per-class or per-tenant slices, so read those tails as "max observed
/// latency", not as a distributional estimate. Degenerate inputs follow
/// the same convention everywhere: empty input returns 0.0, a single
/// sample is every percentile of itself.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|x| !x.is_nan()));
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Pearson correlation coefficient r(x, y); 0.0 when either side is constant
/// (the pipeline treats constant metrics as uninformative, not as errors).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 1e-300 || syy <= 1e-300 {
        return 0.0;
    }
    (sxy / (sxx.sqrt() * syy.sqrt())).clamp(-1.0, 1.0)
}

/// Geometric mean of positive values (used for speedup aggregation sanity
/// checks; the paper's headline "Perf" is the arithmetic mean).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fraction of values strictly above `threshold` (the paper's Fast_1 with
/// threshold = 1.0).
pub fn frac_above(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x > threshold).count() as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_matches_linear_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 75.0) - 3.25).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert_eq!(median(&[5.0]), 5.0);
    }

    #[test]
    fn percentile_edge_cases_at_small_n() {
        // n = 0: the documented 0.0 sentinel, for every q.
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        // n = 1: a single sample is every percentile of itself.
        for q in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.5], q), 7.5, "q={q}");
        }
        // n = 2: rank q/100 interpolates the pair; p99 is 99% of the way
        // from min to max — "practically the max".
        assert!((percentile(&[10.0, 20.0], 50.0) - 15.0).abs() < 1e-12);
        assert!((percentile(&[10.0, 20.0], 99.0) - 19.9).abs() < 1e-12);
        // Order independence: the input is sorted internally.
        assert!((percentile(&[20.0, 10.0], 99.0) - 19.9).abs() < 1e-12);
        // All-equal input: every percentile is that value, exactly.
        let flat = [3.0; 5];
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile(&flat, q), 3.0, "q={q}");
        }
    }

    #[test]
    fn p99_below_100_samples_interpolates_the_top_two() {
        // The documented small-n caveat: with n < 100 the p99 rank lands
        // past n-2, so the estimate lives between the two largest samples.
        let xs: Vec<f64> = (1..=50).map(|i| i as f64).collect();
        let p99 = percentile(&xs, 99.0);
        assert!(p99 > 49.0 && p99 <= 50.0, "p99={p99}");
        // ...and with n >= 101 it no longer touches the max at all.
        let ys: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let p99 = percentile(&ys, 99.0);
        assert!(p99 < 200.0 - 1e-9, "p99={p99}");
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn pearson_uncorrelated_near_zero() {
        let mut rng = crate::util::rng::Rng::new(5);
        let x: Vec<f64> = (0..5000).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..5000).map(|_| rng.f64()).collect();
        assert!(pearson(&x, &y).abs() < 0.05);
    }

    #[test]
    fn frac_above_counts_strictly() {
        assert!((frac_above(&[0.5, 1.0, 1.5, 2.0], 1.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_reciprocals_is_one() {
        assert!((geomean(&[2.0, 0.5]) - 1.0).abs() < 1e-12);
    }
}
