//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals, which is
//! all the `cudaforge` binary and the examples need.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked value exists");
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Every `--flag` or `--key value` given that is not in `known`, in
    /// the deterministic order (options sorted, then bare flags as given).
    /// Subcommands use this to reject typos loudly instead of silently
    /// falling back to defaults.
    pub fn unknown(&self, known: &[&str]) -> Vec<String> {
        let mut out: Vec<String> = self
            .options
            .keys()
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect();
        for f in &self.flags {
            if !known.contains(&f.as_str()) && !out.contains(f) {
                out.push(f.clone());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // NB: a bare `--flag` followed by a positional is ambiguous (the
        // token is taken as the flag's value) — callers put flags last or
        // use `=`; this is documented behaviour.
        let a = parse("bench extra --exp table1 --rounds=10 --verbose");
        assert_eq!(a.positional, vec!["bench", "extra"]);
        assert_eq!(a.get("exp"), Some("table1"));
        assert_eq!(a.get_usize("rounds", 0), 10);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("gpu", "rtx6000"), "rtx6000");
        assert_eq!(a.get_f64("tol", 1e-4), 1e-4);
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("--seed 7 --fast");
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.flag("fast"));
    }

    #[test]
    fn unknown_reports_both_options_and_flags() {
        let a = parse("serve --seed 7 --requets 60 --profiel");
        assert_eq!(a.unknown(&["seed", "requests", "profile"]), vec!["requets", "profiel"]);
        assert!(a.unknown(&["seed", "requets", "profiel"]).is_empty());
    }
}
