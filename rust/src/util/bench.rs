//! Criterion-style micro-benchmark harness (criterion itself is not in the
//! offline vendor set). Used by the `cargo bench` targets and the §Perf pass:
//! warmup, timed iterations, mean / p50 / p95 and throughput reporting.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` under timing: ~0.5 s warmup then enough iterations to cover
/// ~2 s of measurement (min 10, max `max_iters`). Prints a criterion-like
/// line and returns the stats.
pub fn bench<F: FnMut()>(name: &str, max_iters: u64, mut f: F) -> BenchResult {
    // Warmup + per-iteration estimate.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < Duration::from_millis(300) && warm_iters < max_iters {
        f();
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;
    let target_iters = ((2e9 / per_iter.max(1.0)) as u64).clamp(10, max_iters);

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[(samples.len() as f64 * 0.95) as usize % samples.len()];
    let r = BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean_ns: mean,
        p50_ns: p50,
        p95_ns: p95,
    };
    println!(
        "bench {:44} {:>12}/iter  p50 {:>12}  p95 {:>12}  ({} iters, {:>12.0}/s)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        r.iters,
        r.per_second(),
    );
    r
}

/// `black_box` shim (std::hint::black_box is stable).
pub use std::hint::black_box;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1000, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns * 1.001);
        assert!(r.iters >= 10);
    }
}
