//! Criterion-style micro-benchmark harness (criterion itself is not in the
//! offline vendor set). Used by the `cargo bench` targets and the §Perf pass:
//! warmup, timed iterations, mean / p50 / p95 and throughput reporting.
//!
//! Two environment knobs make the harness scriptable:
//!
//! - `CUDAFORGE_BENCH_FAST=1` shrinks warmup to ~50 ms and the measurement
//!   window to ~200 ms (min 3 iterations) — a smoke-test mode for CI, where
//!   the point is "the bench runs and emits sane numbers", not tight
//!   confidence intervals.
//! - `CUDAFORGE_BENCH_JSON=<path>` makes [`BenchSet::finish`] write every
//!   recorded result to `<path>` as one JSON document (see `BENCH_*.json`
//!   at the repo root for the committed reference series).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// A counting shim over the system allocator. The bench binaries install
/// it as their `#[global_allocator]` so [`BenchSet::to_json`] can report
/// `total_allocations` next to throughput — a cheap regression tripwire
/// for "this optimization quietly started cloning per request".
pub struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

// SAFETY: defers every operation verbatim to `System`; the counter is a
// relaxed atomic increment with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Allocation calls observed so far (0 unless [`CountingAlloc`] is the
/// process's global allocator).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Peak resident set size of this process in bytes, from
/// `/proc/self/status` `VmHWM` — `None` off Linux or when the file is
/// unreadable (the JSON reports `null` rather than a fake number).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line
        .trim_start_matches("VmHWM:")
        .trim()
        .trim_end_matches("kB")
        .trim()
        .parse()
        .ok()?;
    Some(kb * 1024)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// True when `CUDAFORGE_BENCH_FAST` is set to anything but empty or `0`.
fn fast_mode() -> bool {
    match std::env::var("CUDAFORGE_BENCH_FAST") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// Run `f` under timing: ~0.5 s warmup then enough iterations to cover
/// ~2 s of measurement (min 10, max `max_iters`). Prints a criterion-like
/// line and returns the stats. Under `CUDAFORGE_BENCH_FAST` the windows
/// shrink to ~50 ms / ~200 ms (min 3 iterations).
pub fn bench<F: FnMut()>(name: &str, max_iters: u64, mut f: F) -> BenchResult {
    let (warmup_ms, measure_ns, min_iters) =
        if fast_mode() { (50, 2e8, 3) } else { (300, 2e9, 10) };

    // Warmup + per-iteration estimate.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < Duration::from_millis(warmup_ms) && warm_iters < max_iters
    {
        f();
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;
    let target_iters = ((measure_ns / per_iter.max(1.0)) as u64).clamp(min_iters, max_iters);

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[(samples.len() as f64 * 0.95) as usize % samples.len()];
    let r = BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean_ns: mean,
        p50_ns: p50,
        p95_ns: p95,
    };
    println!(
        "bench {:44} {:>12}/iter  p50 {:>12}  p95 {:>12}  ({} iters, {:>12.0}/s)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        r.iters,
        r.per_second(),
    );
    r
}

/// A named collection of bench results, for suites that want a JSON series
/// next to the console lines. `record` attaches a units-per-iteration
/// figure so throughput benches (requests replayed, routes resolved) report
/// units/s rather than bare iterations/s.
pub struct BenchSet {
    suite: String,
    rows: Vec<(BenchResult, f64)>,
}

impl BenchSet {
    /// Start an empty set for the named suite (e.g. `"service"`).
    pub fn new(suite: &str) -> BenchSet {
        BenchSet { suite: suite.to_string(), rows: Vec::new() }
    }

    /// Time `f` via [`bench`] and record the result. `units_per_iter` is
    /// what one iteration processes (requests, lookups, ...); the JSON row
    /// carries both the per-iteration stats and `units_per_s`.
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        max_iters: u64,
        units_per_iter: f64,
        f: F,
    ) -> BenchResult {
        let r = bench(name, max_iters, f);
        self.rows.push((r.clone(), units_per_iter));
        r
    }

    /// Serialize every recorded row. Stable shape:
    /// `{"suite", "results": [{name, iters, mean_ns, p50_ns, p95_ns,
    /// units_per_iter, units_per_s}], "peak_rss_bytes", "total_allocations"}`
    /// — the last two are suite-level host-side footprint figures
    /// ([`peak_rss_bytes`] is `null` where `/proc` is unavailable, and
    /// `total_allocations` is 0 unless the binary installed
    /// [`CountingAlloc`]).
    pub fn to_json(&self) -> Json {
        let results = self
            .rows
            .iter()
            .map(|(r, units)| {
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("iters", Json::num(r.iters as f64)),
                    ("mean_ns", Json::num(r.mean_ns)),
                    ("p50_ns", Json::num(r.p50_ns)),
                    ("p95_ns", Json::num(r.p95_ns)),
                    ("units_per_iter", Json::num(*units)),
                    ("units_per_s", Json::num(units * r.per_second())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("suite", Json::str(&self.suite)),
            ("results", Json::Arr(results)),
            (
                "peak_rss_bytes",
                match peak_rss_bytes() {
                    Some(b) => Json::num(b as f64),
                    None => Json::Null,
                },
            ),
            ("total_allocations", Json::num(allocations() as f64)),
        ])
    }

    /// If `CUDAFORGE_BENCH_JSON` names a path, write [`BenchSet::to_json`]
    /// there (plus a trailing newline) and print a one-line confirmation.
    pub fn finish(&self) {
        if let Ok(path) = std::env::var("CUDAFORGE_BENCH_JSON") {
            if path.is_empty() {
                return;
            }
            match std::fs::write(&path, format!("{}\n", self.to_json())) {
                Ok(()) => println!("bench json: {} results -> {path}", self.rows.len()),
                Err(e) => eprintln!("bench json: failed to write {path}: {e}"),
            }
        }
    }
}

/// `black_box` shim (std::hint::black_box is stable).
pub use std::hint::black_box;

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1000, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns * 1.001);
        assert!(r.iters >= 3);
    }

    #[test]
    fn bench_set_serializes_units_per_second() {
        let mut set = BenchSet::new("unit-test");
        set.run("spin", 50, 200.0, || {
            black_box((0..64).sum::<u64>());
        });
        let doc = set.to_json();
        assert_eq!(doc.get("suite").and_then(Json::as_str), Some("unit-test"));
        let rows = doc.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.get("name").and_then(Json::as_str), Some("spin"));
        let mean = row.get("mean_ns").and_then(Json::as_f64).unwrap();
        let ups = row.get("units_per_s").and_then(Json::as_f64).unwrap();
        assert!(mean > 0.0);
        // units_per_s is exactly units * (1e9 / mean_ns).
        assert!((ups - 200.0 * 1e9 / mean).abs() < 1e-6 * ups.abs());
        // The suite-level footprint keys are always present: RSS as a
        // number (or null off Linux), allocations as a number.
        assert!(doc.get("total_allocations").and_then(Json::as_f64).is_some());
        match doc.get("peak_rss_bytes") {
            Some(Json::Null) => {}
            Some(v) => assert!(v.as_f64().unwrap() > 0.0),
            None => panic!("peak_rss_bytes key missing"),
        }
        // Round-trips through the serializer.
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn peak_rss_parses_proc_when_available() {
        // On Linux the figure exists and is at least a page; elsewhere the
        // probe degrades to None rather than inventing one.
        if let Some(b) = peak_rss_bytes() {
            assert!(b >= 4096, "VmHWM {b} implausibly small");
        }
    }
}
