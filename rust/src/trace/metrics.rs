//! Deterministic metrics over a recorded event stream.
//!
//! A [`MetricsRegistry`] is a plain bag of counters, gauges, and
//! log-bucketed [`Histogram`]s — all integer/IEEE arithmetic in event
//! order, no clocks, no sampling jitter — and [`time_series`] folds a
//! recorded replay into one CSV row per simulated tick: arrivals and
//! their outcomes, completions, busy seconds and utilization, latency
//! quantile estimates, and per-tenant served/shed counts. Because the
//! input stream is bit-identical across host thread counts and window
//! sizes, so is the CSV.
//!
//! Attribution is *lumpy but deterministic*: a flight's busy seconds and
//! member latencies land in the tick of its completion instant (not
//! spread over its run), so a single long flight can push one tick's
//! utilization above 1.0. That is the correct trade for bit-stable
//! output; smooth it downstream if needed.

use std::collections::BTreeMap;

use crate::trace::{TraceEvent, TraceMeta};

/// A log₂-bucketed histogram of nonnegative seconds. Values are rounded
/// to integer microseconds and bucketed by bit length, so recording is
/// pure integer math and quantiles are deterministic upper-bound
/// estimates (within 2× of the true value).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    buckets: [u64; 64],
    count: u64,
}

impl Histogram {
    /// Record one value (seconds; negatives clamp to zero).
    pub fn record(&mut self, v_s: f64) {
        let micros = (v_s.max(0.0) * 1e6).round() as u64;
        let b = (64 - micros.leading_zeros() as usize).min(63);
        self.buckets[b] += 1;
        self.count += 1;
    }

    /// Recorded value count.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Upper-bound estimate of the `q`-quantile (`q` in [0, 1]), in
    /// seconds. 0.0 when the histogram is empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                if b == 0 {
                    return 0.0;
                }
                return ((1u64 << b) - 1) as f64 / 1e6;
            }
        }
        0.0
    }
}

/// Named counters, gauges, and histograms. Keys are plain strings so
/// per-tenant series can be derived (`served_alpha`, …); iteration is
/// sorted (BTreeMap), so rendering order is deterministic.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// Add `by` to counter `name` (created at zero).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set gauge `name`.
    pub fn set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Add `v` to gauge `name` (created at zero).
    pub fn add(&mut self, name: &str, v: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    /// Record `v_s` into histogram `name` (created empty).
    pub fn observe(&mut self, name: &str, v_s: f64) {
        self.hists.entry(name.to_string()).or_default().record(v_s);
    }

    /// Counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value (0.0 when absent).
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Histogram quantile (0.0 when absent/empty).
    pub fn quantile(&self, name: &str, q: f64) -> f64 {
        self.hists.get(name).map(|h| h.quantile(q)).unwrap_or(0.0)
    }
}

/// Fold a recorded event stream into the per-tick time-series CSV.
///
/// One row per `meta.tick_s` of simulated time from tick 0 through the
/// last event's tick (quiet ticks emit zero rows, so row count is a
/// pure function of the trace span). Columns: `tick_end_s`, arrival
/// outcomes, completions, busy seconds, utilization over
/// `nodes × sim_workers` nominal slots, latency quantile estimates, and
/// — when `meta.tenants` is nonempty — per-tenant served/shed counts.
pub fn time_series(meta: &TraceMeta, events: &[TraceEvent]) -> String {
    let tick_s = if meta.tick_s > 0.0 { meta.tick_s } else { TraceMeta::DEFAULT_TICK_S };
    let slots = (meta.nodes.max(1) * meta.sim_workers.max(1)) as f64;

    let mut header = vec![
        "tick_end_s".to_string(),
        "arrivals".to_string(),
        "hits".to_string(),
        "joins".to_string(),
        "enqueued".to_string(),
        "sheds".to_string(),
        "shed_depth".to_string(),
        "shed_quota".to_string(),
        "shed_routing".to_string(),
        "shed_rate".to_string(),
        "completions".to_string(),
        "busy_s".to_string(),
        "utilization".to_string(),
        "latency_p50_s".to_string(),
        "latency_p95_s".to_string(),
    ];
    for t in &meta.tenants {
        header.push(format!("served_{t}"));
        header.push(format!("shed_{t}"));
    }
    let mut out = header.join(",");
    out.push('\n');

    // Tenant attribution: admissions name their tenant index; completion
    // members are resolved through the seq → tenant map built from them.
    let mut tenant_of: BTreeMap<u64, usize> = BTreeMap::new();
    let tenant_name = |i: usize| -> Option<&str> { meta.tenants.get(i).map(|s| s.as_str()) };

    let mut row = |m: &MetricsRegistry, tick_end: f64| -> String {
        let busy = m.gauge("busy_s");
        let mut cols = vec![
            format!("{tick_end:.0}"),
            m.counter("arrivals").to_string(),
            m.counter("hits").to_string(),
            m.counter("joins").to_string(),
            m.counter("enqueued").to_string(),
            m.counter("sheds").to_string(),
            m.counter("shed_depth").to_string(),
            m.counter("shed_quota").to_string(),
            m.counter("shed_routing").to_string(),
            m.counter("shed_rate").to_string(),
            m.counter("completions").to_string(),
            format!("{busy:.3}"),
            format!("{:.4}", busy / (slots * tick_s)),
            format!("{:.6}", m.quantile("latency_s", 0.50)),
            format!("{:.6}", m.quantile("latency_s", 0.95)),
        ];
        for t in &meta.tenants {
            cols.push(m.counter(&format!("served_{t}")).to_string());
            cols.push(m.counter(&format!("shed_{t}")).to_string());
        }
        cols.join(",")
    };

    let mut tick = 0usize;
    let mut m = MetricsRegistry::default();
    for ev in events {
        let ev_tick = (ev.at_s / tick_s).floor().max(0.0) as usize;
        while tick < ev_tick {
            out.push_str(&row(&m, (tick + 1) as f64 * tick_s));
            out.push('\n');
            m = MetricsRegistry::default();
            tick += 1;
        }
        match ev.kind {
            "request.admit" => {
                m.inc("arrivals", 1);
                let seq = ev.get("seq").and_then(|v| v.as_f64()).unwrap_or(-1.0) as u64;
                let tenant = ev.get("tenant").and_then(|v| v.as_usize()).unwrap_or(0);
                tenant_of.insert(seq, tenant);
                match ev.get("outcome").and_then(|v| v.as_str()).unwrap_or("") {
                    "hit" => {
                        m.inc("hits", 1);
                        if let Some(l) = ev.get("latency_s").and_then(|v| v.as_f64()) {
                            m.observe("latency_s", l);
                        }
                        if let Some(t) = tenant_name(tenant) {
                            m.inc(&format!("served_{t}"), 1);
                        }
                    }
                    "join-waiting" | "join-running" => m.inc("joins", 1),
                    "enqueue" => m.inc("enqueued", 1),
                    "shed" => {
                        m.inc("sheds", 1);
                        let reason = ev.get("reason").and_then(|v| v.as_str()).unwrap_or("");
                        match reason {
                            "depth" => m.inc("shed_depth", 1),
                            "quota" => m.inc("shed_quota", 1),
                            "routing" => m.inc("shed_routing", 1),
                            "rate" => m.inc("shed_rate", 1),
                            _ => {}
                        }
                        if let Some(t) = tenant_name(tenant) {
                            m.inc(&format!("shed_{t}"), 1);
                        }
                    }
                    _ => {}
                }
            }
            "flight.complete" => {
                m.inc("completions", 1);
                if let Some(s) = ev.get("service_s").and_then(|v| v.as_f64()) {
                    m.add("busy_s", s);
                }
                if let Some(members) = ev.get("members").and_then(|v| v.as_arr()) {
                    for mem in members {
                        let arrival =
                            mem.get("arrival_s").and_then(|v| v.as_f64()).unwrap_or(ev.at_s);
                        m.observe("latency_s", ev.at_s - arrival);
                        let seq =
                            mem.get("seq").and_then(|v| v.as_f64()).unwrap_or(-1.0) as u64;
                        if let Some(t) =
                            tenant_of.get(&seq).copied().and_then(tenant_name)
                        {
                            m.inc(&format!("served_{t}"), 1);
                        }
                    }
                }
            }
            _ => {}
        }
    }
    out.push_str(&row(&m, (tick + 1) as f64 * tick_s));
    out.push('\n');
    out
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn histogram_quantiles_are_upper_bounds() {
        let mut h = Histogram::default();
        for v in [0.001, 0.002, 0.004, 0.1, 3.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        let p50 = h.quantile(0.5);
        assert!(p50 >= 0.004 && p50 <= 0.008, "p50 {p50}");
        let p100 = h.quantile(1.0);
        assert!(p100 >= 3.0 && p100 <= 6.0, "p100 {p100}");
        assert_eq!(Histogram::default().quantile(0.5), 0.0);
    }

    #[test]
    fn time_series_buckets_by_tick_and_tenant() {
        let mut meta = TraceMeta::new("cluster", 1, 1);
        meta.tenants = vec!["alpha".to_string(), "beta".to_string()];
        meta.tick_s = 10.0;
        let admit = |at: f64, seq: f64, tenant: f64, outcome: &'static str| {
            let mut ev = TraceEvent::new(at, "request.admit", 0)
                .field("seq", Json::num(seq))
                .field("tenant", Json::num(tenant))
                .field("outcome", Json::str(outcome));
            if outcome == "hit" {
                ev = ev.field("latency_s", Json::num(0.05));
            }
            if outcome == "shed" {
                ev = ev.field("reason", Json::str("quota"));
            }
            ev
        };
        let events = vec![
            admit(1.0, 0.0, 0.0, "hit"),
            admit(2.0, 1.0, 1.0, "enqueue"),
            admit(3.0, 2.0, 1.0, "shed"),
            TraceEvent::new(25.0, "flight.complete", 0)
                .field("service_s", Json::num(5.0))
                .field(
                    "members",
                    Json::Arr(vec![Json::obj(vec![
                        ("seq", Json::num(1.0)),
                        ("arrival_s", Json::num(2.0)),
                    ])]),
                ),
        ];
        let csv = time_series(&meta, &events);
        let lines: Vec<&str> = csv.lines().collect();
        // Header + ticks ending at 10, 20, 30.
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("tick_end_s,arrivals,"));
        assert!(lines[0].ends_with("served_alpha,shed_alpha,served_beta,shed_beta"));
        // Tick 1: 3 arrivals — one hit (alpha served), one enqueue, one
        // quota shed (beta).
        let t1: Vec<&str> = lines[1].split(',').collect();
        assert_eq!(t1[1], "3");
        assert_eq!(t1[2], "1");
        assert_eq!(t1[4], "1");
        assert_eq!(t1[7], "1", "shed_quota");
        assert_eq!(t1[15], "1", "served_alpha");
        assert_eq!(t1[18], "1", "shed_beta");
        // Tick 2 is quiet.
        assert!(lines[2].starts_with("20,0,0,"));
        // Tick 3: the completion serves beta's queued request.
        let t3: Vec<&str> = lines[3].split(',').collect();
        assert_eq!(t3[10], "1", "completions");
        assert_eq!(t3[11], "5.000", "busy_s");
        assert_eq!(t3[17], "1", "served_beta");
    }
}
