//! Deterministic flight recorder for the replay engines.
//!
//! Every decision point in the global event loop — admissions, warm-start
//! lookups, flight starts/completions, lint short-circuits, cache
//! evictions, refill landings, membership changes, autoscale ticks — can
//! emit a structured [`TraceEvent`] stamped with its *simulated* instant.
//! Emission goes through a [`TraceSink`]: the default [`NullSink`] makes
//! the whole layer free (events are built lazily and never constructed
//! when the sink is disabled), while the opt-in [`Recorder`] buffers the
//! full event stream in memory and writes it out once, after the replay.
//!
//! # The determinism contract
//!
//! Events are emitted **only** from the deterministic event-loop path —
//! never from the speculative OS-thread pool — and carry simulated
//! timestamps, so the recorded stream is bit-identical regardless of the
//! host `threads` count and the `window` batch size, exactly like the
//! report it narrates. Host wall-clock appears in exactly one place: the
//! opt-in self-[`profile`]r, whose output goes to the console and never
//! into a trace artifact.
//!
//! # Artifacts
//!
//! [`write_dir`] materializes one recorded replay as three files:
//!
//! - `events.jsonl` — a build-stamped header line followed by one JSON
//!   object per event, in emission (= simulated event) order.
//! - `chrome_trace.json` — a Chrome trace-event file ([`chrome`]):
//!   load it in Perfetto / `chrome://tracing` for a per-node, per-GPU-slot
//!   timeline of every flight.
//! - `metrics.csv` — the [`metrics`] time-series: per-tick counters and
//!   gauges (arrivals, hit/shed rates, utilization, latency quantiles,
//!   per-tenant served) sampled from the same event stream.
//!
//! `cudaforge trace --explain <fingerprint>` ([`explain`]) reconstructs
//! one fingerprint's causal story from `events.jsonl`.

pub mod chrome;
pub mod explain;
pub mod metrics;
pub mod profile;

use std::fs;
use std::path::Path;

use crate::util::json::Json;

/// Schema tag stamped into every `events.jsonl` header.
pub const SCHEMA: &str = "cudaforge.trace.v1";

/// One structured event at a simulated instant.
///
/// `fields` is an ordered list of event-specific key/value pairs; the
/// vocabulary per `kind` is documented in `docs/OBSERVABILITY.md`. Field
/// keys must not collide with the envelope keys `at_s` / `kind` / `node`.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Simulated instant of the event, seconds.
    pub at_s: f64,
    /// Event kind, e.g. `"request.admit"` or `"flight.complete"`.
    pub kind: &'static str,
    /// The node the event happened on (0 on the single-node service).
    pub node: usize,
    /// Event-specific payload.
    pub fields: Vec<(&'static str, Json)>,
}

impl TraceEvent {
    /// A new event with an empty payload.
    pub fn new(at_s: f64, kind: &'static str, node: usize) -> TraceEvent {
        TraceEvent { at_s, kind, node, fields: Vec::new() }
    }

    /// Builder-style field append.
    pub fn field(mut self, key: &'static str, value: Json) -> TraceEvent {
        self.fields.push((key, value));
        self
    }

    /// Look up a payload field by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.fields.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }

    /// The event as one JSON object (envelope + payload, keys sorted by
    /// the JSON layer).
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("at_s", Json::num(self.at_s)),
            ("kind", Json::str(self.kind)),
            ("node", Json::num(self.node as f64)),
        ];
        for (k, v) in &self.fields {
            pairs.push((k, v.clone()));
        }
        Json::obj(pairs)
    }
}

/// Where emitted events go. Implementations must be cheap when disabled:
/// [`Observer::emit`] consults [`TraceSink::enabled`] before even
/// *constructing* the event.
pub trait TraceSink {
    /// Whether this sink wants events at all (`false` short-circuits
    /// event construction).
    fn enabled(&self) -> bool {
        true
    }
    /// Record one event. Called in deterministic event order.
    fn record(&mut self, ev: TraceEvent);
}

/// The default sink: tracing off. Replays through a `NullSink` are
/// bit-identical to replays without any observer (regression-tested).
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn record(&mut self, _ev: TraceEvent) {}
}

/// The recording sink: buffers every event in memory, in emission order.
/// Artifacts are written once, after the replay, by [`write_dir`] — so
/// no I/O interleaves with the event loop.
#[derive(Default)]
pub struct Recorder {
    /// The recorded stream, in emission order.
    pub events: Vec<TraceEvent>,
}

impl TraceSink for Recorder {
    fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }
}

/// The handle threaded through a replay: a sink for trace events plus an
/// optional wall-clock [`profile::Profiler`]. Both replay loops take an
/// `&mut Observer`; the plain `replay` entry points pass a [`NullSink`]
/// observer, which makes the whole layer a no-op.
pub struct Observer<'s> {
    sink: &'s mut dyn TraceSink,
    /// Opt-in host-side self-profiling (`--profile`). Wall-clock stage
    /// timers only — never feeds trace artifacts.
    pub profiler: Option<profile::Profiler>,
}

impl<'s> Observer<'s> {
    /// An observer writing to `sink`, with profiling off.
    pub fn new(sink: &'s mut dyn TraceSink) -> Observer<'s> {
        Observer { sink, profiler: None }
    }

    /// Whether the sink is recording (used to skip work that only exists
    /// to feed events).
    pub fn enabled(&self) -> bool {
        self.sink.enabled()
    }

    /// Emit one event. The closure runs only when the sink is enabled,
    /// so a disabled observer never constructs the event at all.
    pub fn emit(&mut self, build: impl FnOnce() -> TraceEvent) {
        if self.sink.enabled() {
            self.sink.record(build());
        }
    }

    /// Enter a profiling stage (no-op without a profiler).
    pub fn enter(&mut self, stage: profile::Stage) {
        if let Some(p) = &mut self.profiler {
            p.enter(stage);
        }
    }

    /// Exit a profiling stage (no-op without a profiler).
    pub fn exit(&mut self, stage: profile::Stage) {
        if let Some(p) = &mut self.profiler {
            p.exit(stage);
        }
    }
}

/// Replay-level metadata stamped into the `events.jsonl` header and used
/// by the metrics/chrome exporters (slot counts, tenant names).
#[derive(Clone, Debug)]
pub struct TraceMeta {
    /// Which replay loop produced the stream: `"service"` or `"cluster"`.
    pub layer: &'static str,
    /// Simulated nodes (1 on the single-node service).
    pub nodes: usize,
    /// Simulated GPU workers per node.
    pub sim_workers: usize,
    /// Tenant names in tenant-index order (empty on the single-node
    /// service, which has no tenant identity).
    pub tenants: Vec<String>,
    /// Metrics sampling tick, simulated seconds.
    pub tick_s: f64,
}

impl TraceMeta {
    /// Default metrics tick: 300 simulated seconds.
    pub const DEFAULT_TICK_S: f64 = 300.0;

    /// Metadata for a replay of `layer` over `nodes`×`sim_workers` slots.
    pub fn new(layer: &'static str, nodes: usize, sim_workers: usize) -> TraceMeta {
        TraceMeta { layer, nodes, sim_workers, tenants: Vec::new(), tick_s: Self::DEFAULT_TICK_S }
    }

    /// The `events.jsonl` header object (schema + build stamp + shape).
    pub fn header_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("version", Json::str(crate::version())),
            (
                "features",
                Json::Arr(crate::features().iter().map(|f| Json::str(*f)).collect()),
            ),
            ("layer", Json::str(self.layer)),
            ("nodes", Json::num(self.nodes as f64)),
            ("sim_workers", Json::num(self.sim_workers as f64)),
            (
                "tenants",
                Json::Arr(self.tenants.iter().map(|t| Json::str(t.as_str())).collect()),
            ),
            ("tick_s", Json::num(self.tick_s)),
        ])
    }
}

/// Build stamp shared by trace headers and snapshot manifests: crate
/// version plus enabled cargo features.
pub fn build_stamp() -> String {
    let feats = crate::features();
    if feats.is_empty() {
        format!("cudaforge {}", crate::version())
    } else {
        format!("cudaforge {} +{}", crate::version(), feats.join("+"))
    }
}

/// Serialize the recorded stream as JSONL: one header line, then one
/// line per event, in emission order.
pub fn events_jsonl(meta: &TraceMeta, events: &[TraceEvent]) -> String {
    let mut out = String::new();
    out.push_str(&meta.header_json().to_string());
    out.push('\n');
    for ev in events {
        out.push_str(&ev.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Write one recorded replay into `dir` as `events.jsonl`,
/// `chrome_trace.json`, and `metrics.csv`. Creates `dir` if needed.
pub fn write_dir(dir: &Path, meta: &TraceMeta, events: &[TraceEvent]) -> anyhow::Result<()> {
    fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("creating trace dir {}: {e}", dir.display()))?;
    let write = |name: &str, body: String| -> anyhow::Result<()> {
        let path = dir.join(name);
        fs::write(&path, body).map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    };
    write("events.jsonl", events_jsonl(meta, events))?;
    write("chrome_trace.json", chrome::chrome_trace(meta, events).to_string())?;
    write("metrics.csv", metrics::time_series(meta, events))?;
    Ok(())
}
