//! `cudaforge trace --explain <fingerprint>` — reconstruct one
//! fingerprint's causal story from a recorded `events.jsonl`.
//!
//! The flight recorder stamps every decision with the request
//! fingerprint it concerns, so filtering the event log by fingerprint
//! and narrating the survivors in order *is* the request's causal chain:
//! admission outcome (hit / join / enqueue / shed-with-reason), the
//! warm-start decision with its margin arithmetic spelled out, the
//! flight's start and completion (with every settled member), lint
//! short-circuits, and the cache afterlife (refill landings, eviction).

use std::fs;
use std::path::Path;

use crate::util::json::Json;

/// Render the causal story of `fp` from parsed event-log lines (header
/// line excluded). Returns a "no events" message when nothing matches.
pub fn explain_events(lines: &[Json], fp: &str) -> String {
    let mut body = String::new();
    let mut n = 0usize;
    for ev in lines {
        if ev.get("fp").and_then(|v| v.as_str()) != Some(fp) {
            continue;
        }
        n += 1;
        let at = ev.get("at_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let node = ev.get("node").and_then(|v| v.as_usize()).unwrap_or(0);
        let kind = ev.get("kind").and_then(|v| v.as_str()).unwrap_or("?");
        body.push_str(&format!("  t={at:>11.1}s  node {node}  {}\n", narrate(kind, ev)));
    }
    if n == 0 {
        return format!("no recorded events for fingerprint {fp}\n");
    }
    format!("Causal story for fingerprint {fp} — {n} event(s)\n{body}")
}

/// Read `DIR/events.jsonl` and render the causal story of `fp`.
pub fn explain_dir(dir: &Path, fp: &str) -> anyhow::Result<String> {
    let path = dir.join("events.jsonl");
    let raw = fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let mut lines = Vec::new();
    for (i, line) in raw.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("{}:{}: bad event line: {e:?}", path.display(), i + 1))?;
        if i == 0 && j.get("schema").is_some() {
            continue; // the build-stamped header line
        }
        lines.push(j);
    }
    Ok(explain_events(&lines, fp))
}

fn num(ev: &Json, key: &str) -> f64 {
    ev.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

fn int(ev: &Json, key: &str) -> i64 {
    num(ev, key) as i64
}

fn text<'a>(ev: &'a Json, key: &str) -> &'a str {
    ev.get(key).and_then(|v| v.as_str()).unwrap_or("?")
}

/// One human-readable line per event.
fn narrate(kind: &str, ev: &Json) -> String {
    match kind {
        "request.admit" => {
            let head = format!(
                "request #{} ({}, task {} on {})",
                int(ev, "seq"),
                text(ev, "priority"),
                text(ev, "task"),
                text(ev, "gpu"),
            );
            match text(ev, "outcome") {
                "hit" => format!(
                    "{head} → cache HIT, answered from the shard in {:.2}s",
                    num(ev, "latency_s")
                ),
                "join-waiting" => {
                    format!("{head} → joined an identical flight waiting for a worker")
                }
                "join-running" => {
                    format!("{head} → joined an identical flight already on a worker")
                }
                "enqueue" => {
                    format!("{head} → miss: new flight enqueued (backlog {})", int(ev, "depth"))
                }
                "shed" => match text(ev, "reason") {
                    "depth" => format!(
                        "{head} → SHED: backlog {} at the admission-control bound",
                        int(ev, "depth")
                    ),
                    "quota" => format!(
                        "{head} → SHED: tenant over fair share (backlog {} ≥ quota {})",
                        int(ev, "backlog"),
                        int(ev, "quota")
                    ),
                    "routing" => format!("{head} → SHED: no alive node owns this key"),
                    "rate" => format!(
                        "{head} → SHED: tenant over rate limit ({:.2} tokens; retry \
                         admitted at t={:.1}s)",
                        num(ev, "tokens"),
                        num(ev, "retry_at_s"),
                    ),
                    r => format!("{head} → SHED ({r})"),
                },
                o => format!("{head} → {o}"),
            }
        }
        "warm.lookup" => match text(ev, "picked") {
            "none" => "warm lookup: no usable cross-GPU seed → cold run".to_string(),
            "own" => {
                let own = num(ev, "own_speedup");
                if ev.get("remote_speedup").is_some() {
                    let margin = num(ev, "margin");
                    format!(
                        "warm lookup: own seed wins — remote {:.3}x (node {}) ≤ \
                         own {:.3}x × (1 + {:.3}) = {:.3}x",
                        num(ev, "remote_speedup"),
                        int(ev, "remote_node"),
                        own,
                        margin,
                        own * (1.0 + margin),
                    )
                } else {
                    format!(
                        "warm lookup: local seed {:.3}x from {} (fp {})",
                        own,
                        text(ev, "source_gpu"),
                        text(ev, "source_fp"),
                    )
                }
            }
            "remote" => {
                let own = num(ev, "own_speedup");
                let margin = num(ev, "margin");
                format!(
                    "warm lookup: remote seed wins — node {} offers {:.3}x > \
                     own {:.3}x × (1 + {:.3}) = {:.3}x (transfer billed)",
                    int(ev, "remote_node"),
                    num(ev, "remote_speedup"),
                    own,
                    margin,
                    own * (1.0 + margin),
                )
            }
            p => format!("warm lookup: {p}"),
        },
        "flight.start" => {
            // Traces recorded before fair dispatch carry no deficit math;
            // narrate it only when the fields are present.
            let fair = if ev.get("deficit").is_some() {
                format!(
                    " — picked by fair dispatch: tenant {} deficit {:.3}s ≥ \
                     vclock {:.3}s at weight {:.1}",
                    int(ev, "tenant"),
                    num(ev, "deficit"),
                    num(ev, "vtime"),
                    num(ev, "weight"),
                )
            } else {
                String::new()
            };
            format!(
                "flight starts (leader #{}): service {:.1}s{}{fair}",
                int(ev, "leader_seq"),
                num(ev, "service_s"),
                if ev.get("warm").and_then(|v| v.as_bool()).unwrap_or(false) {
                    ", warm-seeded"
                } else {
                    ", cold"
                },
            )
        }
        "flight.complete" => {
            let members =
                ev.get("members").and_then(|v| v.as_arr()).map(|m| m.len()).unwrap_or(0);
            format!(
                "flight completes (started t={:.1}s): {members} member(s) settle{}",
                num(ev, "start_s"),
                if ev.get("cached").and_then(|v| v.as_bool()).unwrap_or(false) {
                    ", result cached"
                } else {
                    ", result not cacheable"
                },
            )
        }
        "lint.short_circuit" => format!(
            "lint gate repaired the candidate before compile — {} correctness round(s) saved",
            int(ev, "checks_saved")
        ),
        "cache.evict" => "evicted from the shard under capacity pressure".to_string(),
        "cache.refill" => format!(
            "result lands in this node's shard (cross-node refill from node {})",
            int(ev, "from_node")
        ),
        k => k.to_string(),
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::trace::TraceEvent;

    #[test]
    fn margin_arithmetic_is_spelled_out() {
        let fp = "00000000deadbeef";
        let lines = vec![
            TraceEvent::new(10.0, "request.admit", 1)
                .field("seq", Json::num(4.0))
                .field("fp", Json::str(fp))
                .field("priority", Json::str("standard"))
                .field("task", Json::str("L1-95"))
                .field("gpu", Json::str("a100"))
                .field("outcome", Json::str("enqueue"))
                .field("depth", Json::num(2.0))
                .to_json(),
            TraceEvent::new(11.0, "warm.lookup", 1)
                .field("fp", Json::str(fp))
                .field("picked", Json::str("remote"))
                .field("own_speedup", Json::num(1.52))
                .field("remote_speedup", Json::num(1.8))
                .field("remote_node", Json::num(2.0))
                .field("margin", Json::num(0.1))
                .to_json(),
        ];
        let story = explain_events(&lines, fp);
        assert!(story.contains("2 event(s)"), "{story}");
        assert!(story.contains("new flight enqueued"), "{story}");
        assert!(story.contains("1.800x > own 1.520x × (1 + 0.100) = 1.672x"), "{story}");
        assert!(explain_events(&lines, "ffffffffffffffff").contains("no recorded events"));
    }

    #[test]
    fn rate_sheds_and_deficit_math_are_narrated() {
        let fp = "00000000cafef00d";
        let lines = vec![
            TraceEvent::new(5.0, "request.admit", 0)
                .field("seq", Json::num(9.0))
                .field("fp", Json::str(fp))
                .field("priority", Json::str("interactive"))
                .field("task", Json::str("L1-3"))
                .field("gpu", Json::str("a100"))
                .field("outcome", Json::str("shed"))
                .field("reason", Json::str("rate"))
                .field("tokens", Json::num(0.0))
                .field("retry_at_s", Json::num(12.5))
                .to_json(),
            TraceEvent::new(6.0, "flight.start", 0)
                .field("fp", Json::str(fp))
                .field("leader_seq", Json::num(3.0))
                .field("service_s", Json::num(40.0))
                .field("tenant", Json::num(1.0))
                .field("deficit", Json::num(2.5))
                .field("vtime", Json::num(2.0))
                .field("weight", Json::num(3.0))
                .to_json(),
        ];
        let story = explain_events(&lines, fp);
        assert!(
            story.contains("over rate limit (0.00 tokens; retry admitted at t=12.5s)"),
            "{story}"
        );
        assert!(
            story.contains("tenant 1 deficit 2.500s ≥ vclock 2.000s at weight 3.0"),
            "{story}"
        );
        // Pre-fair-dispatch traces (no deficit field) still narrate.
        let old = vec![TraceEvent::new(6.0, "flight.start", 0)
            .field("fp", Json::str(fp))
            .field("leader_seq", Json::num(3.0))
            .field("service_s", Json::num(40.0))
            .to_json()];
        let story = explain_events(&old, fp);
        assert!(story.contains("flight starts (leader #3): service 40.0s, cold"), "{story}");
        assert!(!story.contains("fair dispatch"), "{story}");
    }
}
