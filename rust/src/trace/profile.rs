//! Host-side replay self-profiling (`--profile`).
//!
//! The one deliberate exception to the simulated-time rule: a
//! [`Profiler`] measures host wall-clock per replay *stage* so ROADMAP's
//! replay-speed work has a baseline to attack. Stage attribution is
//! **self-time**: entering a nested stage (say [`Stage::WarmLookup`]
//! inside [`Stage::EventHeap`]) pauses the outer stage's clock, so the
//! per-stage seconds sum to (almost exactly) the instrumented span and
//! never double-count. Output goes to the console only — wall-clock
//! never enters a trace artifact, which is how the recorded stream stays
//! bit-identical across host thread counts.

use std::time::Instant;

use crate::util::table::Table;

/// The instrumented stages of a replay, in display order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Window-batched speculative workflow runs on the OS thread pool
    /// (includes the join — this is where miss-heavy traces spend
    /// almost everything).
    Speculation,
    /// Per-arrival admission: cache probe, single-flight join, shed
    /// decision (excluding the nested stages below).
    Admission,
    /// Request fingerprint hashing.
    Fingerprint,
    /// Warm-start candidate lookup at flight start.
    WarmLookup,
    /// Event-time workflow runs (speculation misses run inline here;
    /// speculation hits are a memo take).
    Workflow,
    /// Draining the simulated event heap: start/completion dispatch and
    /// event-loop bookkeeping (excluding the nested stages above).
    EventHeap,
    /// Report assembly after the drain.
    Report,
}

/// Every stage, in display order.
pub const ALL_STAGES: [Stage; 7] = [
    Stage::Speculation,
    Stage::Admission,
    Stage::Fingerprint,
    Stage::WarmLookup,
    Stage::Workflow,
    Stage::EventHeap,
    Stage::Report,
];

impl Stage {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Speculation => "speculation",
            Stage::Admission => "admission",
            Stage::Fingerprint => "fingerprint hashing",
            Stage::WarmLookup => "warm lookup",
            Stage::Workflow => "workflow runs",
            Stage::EventHeap => "event heap",
            Stage::Report => "report assembly",
        }
    }

    fn idx(self) -> usize {
        match self {
            Stage::Speculation => 0,
            Stage::Admission => 1,
            Stage::Fingerprint => 2,
            Stage::WarmLookup => 3,
            Stage::Workflow => 4,
            Stage::EventHeap => 5,
            Stage::Report => 6,
        }
    }
}

/// Self-time stage timers over one replay. Construct before the replay,
/// [`Profiler::finish`] after it; the replay loops call
/// [`Profiler::enter`]/[`Profiler::exit`] around each stage.
pub struct Profiler {
    started: Instant,
    /// Open stages, innermost last. Each entry's `Instant` is the mark
    /// self-time accrues from (reset whenever a nested stage closes).
    stack: Vec<(Stage, Instant)>,
    totals: [f64; ALL_STAGES.len()],
}

impl Default for Profiler {
    fn default() -> Self {
        Profiler::new()
    }
}

impl Profiler {
    /// Start the wall clock.
    pub fn new() -> Profiler {
        Profiler { started: Instant::now(), stack: Vec::new(), totals: [0.0; ALL_STAGES.len()] }
    }

    /// Open `stage`, pausing the enclosing stage's self-time clock.
    pub fn enter(&mut self, stage: Stage) {
        let now = Instant::now();
        if let Some((outer, mark)) = self.stack.last_mut() {
            self.totals[outer.idx()] += now.duration_since(*mark).as_secs_f64();
            *mark = now;
        }
        self.stack.push((stage, now));
    }

    /// Close `stage`, resuming the enclosing stage's clock.
    pub fn exit(&mut self, stage: Stage) {
        let now = Instant::now();
        if let Some((top, mark)) = self.stack.pop() {
            debug_assert_eq!(top, stage, "mismatched profiler exit");
            self.totals[top.idx()] += now.duration_since(mark).as_secs_f64();
        }
        if let Some((_, mark)) = self.stack.last_mut() {
            *mark = now;
        }
    }

    /// Stop the wall clock and return the stage breakdown.
    pub fn finish(self) -> ProfileReport {
        ProfileReport { totals: self.totals, wall_s: self.started.elapsed().as_secs_f64() }
    }
}

/// The finished stage breakdown: per-stage self-time plus total wall
/// time from profiler construction to [`Profiler::finish`].
pub struct ProfileReport {
    totals: [f64; ALL_STAGES.len()],
    /// Total wall seconds over the profiled span.
    pub wall_s: f64,
}

impl ProfileReport {
    /// Self-time of one stage, seconds.
    pub fn stage_s(&self, stage: Stage) -> f64 {
        self.totals[stage.idx()]
    }

    /// Sum of all stage self-times, seconds. The acceptance bound: this
    /// is within 10% of [`ProfileReport::wall_s`] on the bench traces.
    pub fn stage_sum_s(&self) -> f64 {
        self.totals.iter().sum()
    }

    /// The console table: one row per stage plus unattributed time and
    /// the wall total.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Replay self-profile — host wall-clock by stage",
            &["Stage", "Seconds", "% of wall"],
        );
        let pct_of = |s: f64| {
            if self.wall_s > 0.0 {
                format!("{:.1}%", 100.0 * s / self.wall_s)
            } else {
                "-".to_string()
            }
        };
        for stage in ALL_STAGES {
            let s = self.stage_s(stage);
            t.row(vec![stage.name().to_string(), format!("{s:.4}"), pct_of(s)]);
        }
        let other = (self.wall_s - self.stage_sum_s()).max(0.0);
        t.row(vec!["(unattributed)".to_string(), format!("{other:.4}"), pct_of(other)]);
        t.row(vec!["total wall".to_string(), format!("{:.4}", self.wall_s), pct_of(self.wall_s)]);
        t
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn nested_stages_accrue_self_time() {
        let mut p = Profiler::new();
        p.enter(Stage::EventHeap);
        std::thread::sleep(std::time::Duration::from_millis(5));
        p.enter(Stage::Workflow);
        std::thread::sleep(std::time::Duration::from_millis(5));
        p.exit(Stage::Workflow);
        p.exit(Stage::EventHeap);
        let r = p.finish();
        assert!(r.stage_s(Stage::EventHeap) > 0.0);
        assert!(r.stage_s(Stage::Workflow) > 0.0);
        // Self-time: the sum never exceeds the wall span.
        assert!(r.stage_sum_s() <= r.wall_s + 1e-6);
        let rendered = r.table().render();
        assert!(rendered.contains("workflow runs"));
        assert!(rendered.contains("total wall"));
    }
}
