//! Chrome trace-event exporter (Perfetto / `chrome://tracing`).
//!
//! [`chrome_trace`] renders a recorded replay as a trace-event JSON
//! object: every `flight.complete` becomes a complete (`ph: "X"`) span —
//! one process (`pid`) per node, one thread (`tid`) per simulated GPU
//! slot — and every other event becomes a thread-scoped instant
//! (`ph: "i"`) on the node's track 0. Slot assignment is reconstructed
//! greedily (earliest-free slot wins, lowest index on ties), which
//! reproduces the fleet's actual worker occupancy because the simulator
//! itself dispatches in start order onto any free worker. Timestamps are
//! simulated microseconds; the output is sorted by `(ts, emission
//! order)`, so `ts` is monotonic — CI checks that with `jq`.

use crate::trace::{build_stamp, TraceEvent, TraceMeta};
use crate::util::json::Json;

/// Render a recorded event stream as one Chrome trace-event JSON object
/// (`{"traceEvents": [...], "otherData": {...}}`).
pub fn chrome_trace(meta: &TraceMeta, events: &[TraceEvent]) -> Json {
    let to_us = |s: f64| (s * 1e6).round();
    // (ts_us, emission order, rendered event) for the final sort.
    let mut rows: Vec<(f64, usize, Json)> = Vec::with_capacity(events.len());
    // Greedy per-node slot reconstruction: free_at seconds per slot.
    let mut slots: Vec<Vec<f64>> = vec![Vec::new(); meta.nodes.max(1)];

    for (order, ev) in events.iter().enumerate() {
        if ev.kind == "flight.complete" {
            let start_s = ev.get("start_s").and_then(|v| v.as_f64()).unwrap_or(ev.at_s);
            let dur_s = (ev.at_s - start_s).max(0.0);
            if ev.node >= slots.len() {
                slots.resize(ev.node + 1, Vec::new());
            }
            let free = &mut slots[ev.node];
            let slot = match free.iter().position(|&t| t <= start_s + 1e-9) {
                Some(i) => i,
                None => {
                    free.push(0.0);
                    free.len() - 1
                }
            };
            free[slot] = start_s + dur_s;
            let name = ev
                .get("fp")
                .and_then(|v| v.as_str())
                .map(|fp| format!("flight {fp}"))
                .unwrap_or_else(|| "flight".to_string());
            rows.push((
                to_us(start_s),
                order,
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("cat", Json::str("flight")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(to_us(start_s))),
                    ("dur", Json::num(to_us(dur_s))),
                    ("pid", Json::num(ev.node as f64)),
                    ("tid", Json::num((slot + 1) as f64)),
                    ("args", args_of(ev)),
                ]),
            ));
        } else {
            rows.push((
                to_us(ev.at_s),
                order,
                Json::obj(vec![
                    ("name", Json::str(ev.kind)),
                    ("cat", Json::str(category_of(ev.kind))),
                    ("ph", Json::str("i")),
                    ("s", Json::str("t")),
                    ("ts", Json::num(to_us(ev.at_s))),
                    ("pid", Json::num(ev.node as f64)),
                    ("tid", Json::num(0.0)),
                    ("args", args_of(ev)),
                ]),
            ));
        }
    }
    rows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

    Json::obj(vec![
        ("traceEvents", Json::Arr(rows.into_iter().map(|(_, _, j)| j).collect())),
        (
            "otherData",
            Json::obj(vec![
                ("build", Json::str(build_stamp())),
                ("layer", Json::str(meta.layer)),
                ("nodes", Json::num(meta.nodes as f64)),
                ("sim_workers", Json::num(meta.sim_workers as f64)),
            ]),
        ),
    ])
}

/// Event payload as the span/instant `args` object.
fn args_of(ev: &TraceEvent) -> Json {
    Json::obj(ev.fields.iter().map(|(k, v)| (*k, v.clone())).collect())
}

/// Track category per event kind (Perfetto groups by these).
fn category_of(kind: &str) -> &'static str {
    match kind.split('.').next() {
        Some("request") => "admission",
        Some("warm") => "warm-start",
        Some("cache") => "cache",
        Some("lint") => "lint",
        Some("membership") => "membership",
        Some("autoscale") => "autoscale",
        _ => "event",
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn complete(at_s: f64, start_s: f64, node: usize, fp: &str) -> TraceEvent {
        TraceEvent::new(at_s, "flight.complete", node)
            .field("fp", Json::str(fp.to_string()))
            .field("start_s", Json::num(start_s))
    }

    #[test]
    fn spans_pack_onto_slots_and_ts_is_monotonic() {
        let meta = TraceMeta::new("service", 1, 2);
        // Two overlapping flights need two slots; a third after both
        // complete reuses slot 1.
        let events = vec![
            TraceEvent::new(0.0, "request.admit", 0).field("outcome", Json::str("enqueue")),
            complete(10.0, 0.0, 0, "aaaa"),
            complete(12.0, 1.0, 0, "bbbb"),
            complete(30.0, 20.0, 0, "cccc"),
        ];
        let j = chrome_trace(&meta, &events);
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(evs.len(), 4);
        let ts: Vec<f64> = evs.iter().map(|e| e.get("ts").unwrap().as_f64().unwrap()).collect();
        let mut sorted = ts.clone();
        sorted.sort_by(f64::total_cmp);
        assert_eq!(ts, sorted, "ts must be monotonic");
        let spans: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(spans.len(), 3);
        let tid_of = |fp: &str| {
            spans
                .iter()
                .find(|s| s.get("name").and_then(|n| n.as_str()) == Some(&format!("flight {fp}")))
                .and_then(|s| s.get("tid"))
                .and_then(|t| t.as_usize())
                .unwrap()
        };
        assert_eq!(tid_of("aaaa"), 1);
        assert_eq!(tid_of("bbbb"), 2, "overlapping flight needs its own slot");
        assert_eq!(tid_of("cccc"), 1, "a freed slot is reused");
        // Instants are thread-scoped and carry the scope key.
        let inst = evs.iter().find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i")).unwrap();
        assert_eq!(inst.get("s").and_then(|s| s.as_str()), Some("t"));
    }
}
