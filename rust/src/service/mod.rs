//! The kernel-optimization service layer.
//!
//! Everything below `service/` exists for one reason: the paper's per-kernel
//! economics (≈26.5 min, ≈$0.30 — Table 3) price a *cold* Coder/Judge loop,
//! but production traffic is dominated by repeats. A deployment serving many
//! users answers most requests from work it has already done. This module
//! simulates that deployment on top of the existing workflow engine:
//!
//! - [`fingerprint`] — content addresses: a stable digest of
//!   (task workload, GPU, models, strategy, rounds) identifying a request.
//! - [`cache`] — bounded LRU result cache keyed by fingerprint, with JSONL
//!   snapshot/restore so restarts are warm.
//! - [`queue`] — priority admission with single-flight dedup: concurrent
//!   identical requests share one workflow run.
//! - [`pool`] — the worker pool shared with `coordinator::run_suite`.
//! - [`traffic`] — deterministic Zipf-distributed synthetic traces.
//! - [`KernelService`] — the service loop: admit a window of requests,
//!   dedup, warm-start misses from cross-GPU near-hits, dispatch to the
//!   pool, account latency/cost, refill the cache.
//!
//! All reported quantities are in *simulated* time (the cost model's wall
//! clock), accumulated in arrival/flight order — so a replay's report is
//! bit-identical regardless of how many OS threads crunch it.

pub mod cache;
pub mod fingerprint;
pub mod pool;
pub mod queue;
pub mod traffic;

use crate::agents::ModelProfile;
use crate::service::cache::{CacheEntry, ResultCache};
use crate::service::fingerprint::Fingerprint;
use crate::service::queue::{JobQueue, Request};
use crate::service::traffic::TrafficRequest;
use crate::tasks::TaskSpec;
use crate::util::stats::{mean, percentile};
use crate::workflow::{
    run_task, CorrectnessOracle, EarlyStop, Strategy, TaskResult, WarmStart, WorkflowConfig,
};

/// Service deployment parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Result-cache capacity (entries).
    pub capacity: usize,
    /// Requests per arrival window — the scope of single-flight dedup (a
    /// window models "requests that arrive while the current batch runs").
    pub window: usize,
    /// OS worker threads for crunching flights. Affects wall-clock only,
    /// never the report.
    pub threads: usize,
    pub strategy: Strategy,
    pub rounds: usize,
    pub coder: ModelProfile,
    pub judge: ModelProfile,
    /// Workflow seed shared by every run (fingerprints exclude seeds, so one
    /// fingerprint must always resolve to one result).
    pub seed: u64,
    /// Early-stop policy applied to warm-started runs.
    pub warm_early_stop: EarlyStop,
    /// Simulated seconds to serve a request straight from the cache.
    pub hit_latency_s: f64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            capacity: 1024,
            window: 32,
            threads: crate::coordinator::default_threads(),
            strategy: Strategy::CudaForge,
            rounds: 10,
            coder: crate::agents::profiles::O3,
            judge: crate::agents::profiles::O3,
            seed: 7,
            warm_early_stop: EarlyStop::default(),
            hit_latency_s: 0.05,
        }
    }
}

/// Everything the operator wants on one screen after a replay. All fields
/// are simulated-time / request-count aggregates, deterministic per
/// (trace, config) — `PartialEq` so tests can assert replay invariance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceReport {
    pub requests: usize,
    /// Workflow runs actually executed (cache misses after dedup).
    pub flights_run: usize,
    pub cache_hits: u64,
    /// Requests served by joining an in-flight duplicate (single-flight).
    pub shared: u64,
    pub evictions: u64,
    /// Runs seeded from a cross-GPU cached kernel.
    pub warm_started: usize,
    /// Requests served without a fresh workflow run / total.
    pub hit_rate: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub mean_latency_s: f64,
    /// API dollars actually spent on workflow runs.
    pub api_usd_spent: f64,
    /// `api_usd_cold - api_usd_spent`: what caching + dedup + warm starts
    /// avoided paying.
    pub api_usd_saved: f64,
    /// The all-cold counterfactual: every request priced at a cold run of
    /// its fingerprint (warm runs priced at their source's cold cost).
    pub api_usd_cold: f64,
    /// Mean 1-based round at which cold runs first measured their best.
    pub mean_rounds_to_best_cold: f64,
    /// Same, for warm-started runs. The warm-start payoff is
    /// `mean_rounds_to_best_warm < mean_rounds_to_best_cold`.
    pub mean_rounds_to_best_warm: f64,
    /// Simulated busy time across all runs (the fleet-size-free unit).
    pub gpu_hours: f64,
    pub requests_per_gpu_hour: f64,
}

/// The long-lived service: a cache plus the admission/dispatch loop.
pub struct KernelService {
    pub config: ServiceConfig,
    cache: ResultCache,
}

impl KernelService {
    pub fn new(config: ServiceConfig) -> KernelService {
        let cache = ResultCache::new(config.capacity);
        KernelService { config, cache }
    }

    /// Start with a restored cache (warm restart from a snapshot).
    pub fn with_cache(config: ServiceConfig, cache: ResultCache) -> KernelService {
        KernelService { config, cache }
    }

    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    pub fn fingerprint_of(&self, task: &TaskSpec, gpu: &crate::gpu::GpuSpec) -> Fingerprint {
        fingerprint::of_request(
            task,
            gpu,
            &self.config.coder,
            &self.config.judge,
            self.config.strategy,
            self.config.rounds,
        )
    }

    /// Prepare one flight's workflow. Returns the config plus, for
    /// warm-started runs, the warm source's cold-run cost (the counterfactual
    /// baseline its cheap run stands in for).
    fn workflow_for(
        &self,
        req: &TrafficRequest,
        task: &TaskSpec,
    ) -> (WorkflowConfig, Option<f64>) {
        let c = &self.config;
        let mut wf = WorkflowConfig::cudaforge(req.gpu, c.seed)
            .with_strategy(c.strategy)
            .with_rounds(c.rounds);
        wf.coder = c.coder;
        wf.judge = c.judge;
        let warm = self.cache.warm_candidate(
            &task.id(),
            req.gpu.key,
            c.strategy.name(),
            c.coder.name,
            c.judge.name,
        );
        match warm {
            Some(entry) => {
                let source_gpu = crate::gpu::by_key(&entry.gpu_key)
                    .map(|g| g.key)
                    .unwrap_or("unknown");
                let cold_ref = entry.cold_api_usd;
                wf = wf
                    .with_warm_start(WarmStart {
                        config: entry.best_config.clone(),
                        source_gpu,
                        source_speedup: entry.best_speedup,
                    })
                    .with_early_stop(c.warm_early_stop);
                (wf, Some(cold_ref))
            }
            None => (wf, None),
        }
    }

    /// Replay a traffic trace through the service. `trace[i].task_index`
    /// indexes into `tasks`. Deterministic per (config, trace) — the OS
    /// thread count changes wall-clock only.
    pub fn replay(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
    ) -> ServiceReport {
        let window = self.config.window.max(1);
        // Counters are deltas against the cache's lifetime stats, so a
        // service replayed twice (e.g. after a snapshot restore) reports
        // each replay on its own.
        let stats0 = self.cache.stats;

        let mut latencies = vec![0.0f64; trace.len()];
        let mut api_spent = 0.0;
        // The all-cold counterfactual: for every request, what a cold run of
        // its fingerprint costs (hits and followers credit the producing
        // run's cold reference; warm flights credit their source's).
        let mut api_cold = 0.0;
        let mut busy_s = 0.0;
        let mut flights_run = 0usize;
        let mut warm_started = 0usize;
        let mut shared = 0u64;
        let mut cold_rounds: Vec<f64> = Vec::new();
        let mut warm_rounds: Vec<f64> = Vec::new();

        let mut queue = JobQueue::new();
        for (w0, win) in trace.chunks(window).enumerate().map(|(i, w)| (i * window, w)) {
            // ---- admission: cache lookups + single-flight coalescing ------
            for (off, req) in win.iter().enumerate() {
                let seq = (w0 + off) as u64;
                let fp = self.fingerprint_of(&tasks[req.task_index], req.gpu);
                if let Some(entry) = self.cache.get(fp) {
                    latencies[seq as usize] = self.config.hit_latency_s;
                    api_cold += entry.cold_api_usd;
                } else {
                    queue.push(Request { seq, fingerprint: fp, priority: req.priority });
                }
            }

            // ---- dispatch: drain flights, warm-start, run on the pool -----
            let flights = queue.drain();
            let prepared: Vec<(WorkflowConfig, usize, Option<f64>)> = flights
                .iter()
                .map(|f| {
                    let req = &trace[f.leader_seq as usize];
                    let (wf, warm_cold_ref) = self.workflow_for(req, &tasks[req.task_index]);
                    if warm_cold_ref.is_some() {
                        warm_started += 1;
                    }
                    (wf, req.task_index, warm_cold_ref)
                })
                .collect();
            let results: Vec<TaskResult> = pool::run_indexed(
                prepared.len(),
                self.config.threads,
                |i| run_task(&prepared[i].0, &tasks[prepared[i].1], oracle),
            );

            // ---- accounting + cache refill, in flight order ---------------
            for ((flight, (wf, task_index, warm_cold_ref)), result) in
                flights.iter().zip(&prepared).zip(&results)
            {
                flights_run += 1;
                api_spent += result.ledger.api_usd;
                // A warm flight's cold counterfactual is its source's cold
                // cost; a cold flight is its own counterfactual.
                let cold_ref = warm_cold_ref.unwrap_or(result.ledger.api_usd);
                api_cold += cold_ref;
                busy_s += result.ledger.wall_s;
                latencies[flight.leader_seq as usize] = result.ledger.wall_s;
                for seq in &flight.follower_seqs {
                    // Followers wait out the leader's run but pay nothing.
                    latencies[*seq as usize] = result.ledger.wall_s;
                    api_cold += cold_ref;
                    shared += 1;
                }
                if let Some(r2b) = result.rounds_to_best() {
                    if wf.warm_start.is_some() {
                        warm_rounds.push(r2b as f64);
                    } else {
                        cold_rounds.push(r2b as f64);
                    }
                }
                if result.correct {
                    if let Some(best_config) = result.best_config.clone() {
                        let task = &tasks[*task_index];
                        self.cache.insert(CacheEntry {
                            fingerprint: flight.fingerprint,
                            task_id: task.id(),
                            gpu_key: wf.gpu.key.to_string(),
                            strategy: self.config.strategy.name().to_string(),
                            coder: self.config.coder.name.to_string(),
                            judge: self.config.judge.name.to_string(),
                            best_speedup: result.best_speedup,
                            best_config,
                            api_usd: result.ledger.api_usd,
                            cold_api_usd: cold_ref,
                            wall_s: result.ledger.wall_s,
                            rounds_to_best: result.rounds_to_best().unwrap_or(0),
                        });
                    }
                }
            }
        }

        let hits = self.cache.stats.hits - stats0.hits;
        let evictions = self.cache.stats.evictions - stats0.evictions;
        let gpu_hours = busy_s / 3600.0;
        ServiceReport {
            requests: trace.len(),
            flights_run,
            cache_hits: hits,
            shared,
            evictions,
            warm_started,
            hit_rate: if trace.is_empty() {
                0.0
            } else {
                (hits + shared) as f64 / trace.len() as f64
            },
            p50_latency_s: percentile(&latencies, 50.0),
            p95_latency_s: percentile(&latencies, 95.0),
            mean_latency_s: mean(&latencies),
            api_usd_spent: api_spent,
            api_usd_saved: api_cold - api_spent,
            api_usd_cold: api_cold,
            mean_rounds_to_best_cold: mean(&cold_rounds),
            mean_rounds_to_best_warm: mean(&warm_rounds),
            gpu_hours,
            requests_per_gpu_hour: if gpu_hours > 0.0 {
                trace.len() as f64 / gpu_hours
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::traffic::{generate, TrafficConfig};
    use crate::tasks;
    use crate::workflow::NoOracle;

    fn small_service(threads: usize) -> KernelService {
        KernelService::new(ServiceConfig {
            threads,
            window: 16,
            ..ServiceConfig::default()
        })
    }

    #[test]
    fn zipf_replay_mostly_hits() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 400, ..TrafficConfig::default() },
        );
        let mut svc = small_service(2);
        let report = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(report.requests, 400);
        assert!(report.hit_rate > 0.5, "hit rate {}", report.hit_rate);
        assert!(report.flights_run < 400);
        assert!(report.api_usd_saved > 0.0);
        assert!(
            (report.api_usd_cold - report.api_usd_spent - report.api_usd_saved).abs()
                < 1e-9
        );
        // Hits answer in ~hit_latency; misses in ~half-hour of simulated
        // time. With >50% hits the median collapses, the p95 does not.
        assert!(report.p50_latency_s < report.p95_latency_s);
    }

    #[test]
    fn accounting_identities_hold() {
        let suite = tasks::dstar();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 120, ..TrafficConfig::default() },
        );
        let mut svc = small_service(2);
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(
            r.cache_hits + r.shared + r.flights_run as u64,
            r.requests as u64,
            "every request is a hit, a follower, or a flight"
        );
        assert!(r.gpu_hours > 0.0);
        assert!(r.requests_per_gpu_hour > 0.0);
    }

    #[test]
    fn eviction_pressure_counts() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 200, ..TrafficConfig::default() },
        );
        let mut svc = KernelService::new(ServiceConfig {
            capacity: 8, // far below the distinct-fingerprint count
            threads: 2,
            window: 16,
            ..ServiceConfig::default()
        });
        let tiny = svc.replay(&trace, &suite, &NoOracle);
        assert!(tiny.evictions > 0, "tiny cache must evict");

        let mut big = KernelService::new(ServiceConfig {
            capacity: 4096,
            threads: 2,
            window: 16,
            ..ServiceConfig::default()
        });
        let roomy = big.replay(&trace, &suite, &NoOracle);
        assert_eq!(roomy.evictions, 0);
        assert!(roomy.hit_rate >= tiny.hit_rate);
    }
}
