//! The kernel-optimization service layer: one node of the deployment.
//!
//! Everything below `service/` exists for one reason: the paper's per-kernel
//! economics (≈26.5 min, ≈$0.30 — Table 3) price a *cold* Coder/Judge loop,
//! but production traffic is dominated by repeats. A deployment serving many
//! users answers most requests from work it has already done. This module
//! simulates one *node* of that deployment on top of the existing workflow
//! engine:
//!
//! - [`fingerprint`] — content addresses: a stable digest of
//!   (task workload, GPU, models, strategy, rounds) identifying a request.
//! - [`cache`] — bounded LRU result cache keyed by fingerprint, with JSONL
//!   snapshot/restore so restarts are warm.
//! - [`queue`] — request priority classes (admission itself is event-driven
//!   and lives on the simulated fleet).
//! - [`traffic`] — deterministic Zipf-distributed synthetic traces with
//!   Poisson arrival times and per-request tenant identity.
//! - [`pool`] — the OS-thread pool shared with `coordinator::run_suite`,
//!   plus [`pool::FleetSim`], the simulated GPU-worker fleet.
//! - [`KernelService`] — the single-node service loop over the
//!   discrete-event model described next.
//!
//! # One node vs. the cluster
//!
//! [`KernelService`] owns exactly one cache and one simulated fleet — the
//! single-node picture. The ROADMAP's target of millions of users is served
//! by `crate::cluster`, which instantiates *N* of these building blocks
//! (one `ResultCache` shard, one `FleetSim` slice per simulated node),
//! routes fingerprints across them with rendezvous hashing, meters
//! per-tenant fair-share quotas under overload, replays elastic-membership
//! scenarios (node failures *and* joins with planned rebalance), and
//! persists/restores shard-aware snapshots whose per-shard files reuse this
//! module's [`cache`] wire format. The cluster layer deliberately reuses
//! this module's machinery unchanged: a 1-node, 1-tenant cluster replay is
//! bit-identical to [`KernelService::replay`] (an invariant the integration
//! tests assert), so every latency/SLO property validated here transfers to
//! the sharded deployment. [`ServiceConfig`] doubles as the *per-node*
//! parameter block of `cluster::ClusterConfig`; the request-shaping helpers
//! ([`ServiceConfig::fingerprint_of`], [`ServiceConfig::base_workflow`],
//! [`ServiceConfig::warm_start_from`]) and the per-flight accounting block
//! (`settle_flight_completion`) are shared by both replay loops so the
//! two layers can never drift apart on what a request means or costs.
//!
//! # The latency model, and dispatch-time causality
//!
//! `replay` runs a discrete-event simulation. Each trace request carries a
//! simulated arrival instant; a finite fleet of `ServiceConfig::sim_workers`
//! simulated GPU workers serves per-priority queues non-preemptively. A
//! request's reported latency is therefore *queue wait + service time*, not
//! bare service time: with one simulated worker and two concurrent misses,
//! the second request's latency includes the first run's entire remaining
//! time. Cache hits bypass the fleet (they are answered by the cache node in
//! `hit_latency_s`); followers — whether joined onto waiting or running
//! work — inherit the leader's *remaining* time, `completion - their own
//! arrival`.
//!
//! Admission is event-driven, one arrival at a time: each request is
//! admitted (cache lookup, single-flight join, admission control) at its own
//! simulated instant, and a flight's side effects — the cache refill, the
//! cold reference that prices the counterfactual, its eligibility as a
//! warm-start source — land exactly at the flight's simulated *completion*
//! instant, interleaved with arrivals and starts in timestamp order. A
//! request can therefore warm-start from a flight that completed moments
//! before it started, and can never observe a result whose producing flight
//! is still running. `ServiceConfig::window` is purely an OS-thread
//! batching knob (how many arrivals are speculatively pre-run per
//! [`pool::run_indexed`] batch); it has no effect on any reported number.
//! Under overload — more than `queue_depth` flights waiting for a worker —
//! batch-class requests that would open a *new* flight are shed and counted
//! as `rejected`; joins and more urgent classes are always admitted. On top
//! of the corrected clock, [`SloTargets`] defines per-priority latency
//! targets and the report carries per-class p50/p95/p99 and SLO attainment,
//! so sweeping `sim_workers` answers "how many GPUs does this traffic need".
//!
//! All reported quantities are in *simulated* time (the cost model's wall
//! clock), accumulated in event order — so a replay's report is
//! bit-identical regardless of how many OS `threads` crunch it, and
//! regardless of the `window` batch size.

pub mod cache;
pub mod fingerprint;
pub mod pool;
pub mod queue;
pub mod ratelimit;
pub mod traffic;

use std::collections::{BTreeMap, BTreeSet};

use crate::agents::ModelProfile;
use crate::service::cache::{CacheEntry, ResultCache};
use crate::service::fingerprint::Fingerprint;
use crate::service::pool::{
    run_indexed, DispatchSnapshot, FleetHooks, FleetSim, MemberList, SimCompletion, SimFlight,
};
use crate::service::queue::{Priority, ALL_PRIORITIES};
use crate::service::ratelimit::{RateDecision, RateLimiter, RatePolicy};
use crate::service::traffic::TrafficRequest;
use crate::tasks::TaskSpec;
use crate::trace::profile::Stage;
use crate::trace::{NullSink, Observer, TraceEvent};
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};
use crate::workflow::{
    run_task, CorrectnessOracle, EarlyStop, Strategy, TaskResult, WarmStart, WorkflowConfig,
};

/// Per-priority latency targets (seconds). Interactive traffic is only
/// inside its budget when it hits the cache; standard tolerates one cold
/// run; batch tolerates a day of queueing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloTargets {
    /// Latency target for interactive traffic, seconds.
    pub interactive_s: f64,
    /// Latency target for standard traffic, seconds.
    pub standard_s: f64,
    /// Latency target for batch traffic, seconds.
    pub batch_s: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        SloTargets { interactive_s: 120.0, standard_s: 2.0 * 3600.0, batch_s: 24.0 * 3600.0 }
    }
}

impl SloTargets {
    /// The latency target for priority class `p`, seconds.
    pub fn target_s(&self, p: Priority) -> f64 {
        match p {
            Priority::Interactive => self.interactive_s,
            Priority::Standard => self.standard_s,
            Priority::Batch => self.batch_s,
        }
    }
}

/// Service deployment parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Result-cache capacity (entries).
    pub capacity: usize,
    /// Arrivals per speculative OS-thread batch: predicted misses are
    /// pre-run `window` arrivals at a time on the host pool, and the event
    /// loop reuses a pre-run result whenever its own event-time lookup
    /// derives the identical workflow. Affects wall-clock only, never the
    /// report.
    pub window: usize,
    /// OS worker threads for crunching flights. Affects wall-clock only,
    /// never the report.
    pub threads: usize,
    /// Simulated GPU workers serving the flight queue — the fleet the
    /// latency model sizes. Decoupled from `threads`: this changes reported
    /// queue waits, never host wall-clock.
    pub sim_workers: usize,
    /// Admission control: once this many flights wait for a simulated
    /// worker, batch-priority requests that would open a new flight are
    /// shed. `usize::MAX` disables shedding.
    pub queue_depth: usize,
    /// Per-priority latency targets the report scores attainment against.
    pub slo: SloTargets,
    /// Workflow strategy every request runs under.
    pub strategy: Strategy,
    /// Optimization round budget per workflow run.
    pub rounds: usize,
    /// Coder model profile.
    pub coder: ModelProfile,
    /// Judge model profile.
    pub judge: ModelProfile,
    /// Workflow seed shared by every run (fingerprints exclude seeds, so one
    /// fingerprint must always resolve to one result).
    pub seed: u64,
    /// Early-stop policy applied to warm-started runs.
    pub warm_early_stop: EarlyStop,
    /// Simulated seconds to serve a request straight from the cache.
    pub hit_latency_s: f64,
    /// Static-analysis gate applied to every workflow run (`None` = lint
    /// off, bit-identical to the pre-analyzer service). When set it joins
    /// the request fingerprint: linted and unlinted runs never share cache
    /// entries.
    pub lint: Option<crate::workflow::LintGate>,
    /// Deficit-weighted-fair dispatch within each priority class (the
    /// default). Off = the historical strict `(priority, arrival)` order —
    /// bit-identical to the pre-DWFQ scheduler, and to the fair scheduler
    /// under single-tenant traffic.
    pub fair_dispatch: bool,
    /// Per-tenant dispatch weights indexed by tenant id (missing or
    /// non-positive entries fall back to 1.0). Empty = every tenant equal.
    /// The cluster fills this from its tenant quota shares so admission
    /// metering and dispatch fairness agree on who deserves what.
    pub tenant_weights: Vec<f64>,
    /// Front-door token-bucket refill rate, tokens per simulated second per
    /// tenant. `None` (default) disables rate limiting — bit-identical to
    /// the pre-limiter service.
    pub tenant_rate: Option<f64>,
    /// Front-door bucket capacity (tokens). `None` defaults to one
    /// second's worth of tokens, at least 1. Ignored without `tenant_rate`.
    pub tenant_burst: Option<f64>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            capacity: 1024,
            window: 32,
            threads: crate::coordinator::default_threads(),
            sim_workers: 8,
            queue_depth: usize::MAX,
            slo: SloTargets::default(),
            strategy: Strategy::CudaForge,
            rounds: 10,
            coder: crate::agents::profiles::O3,
            judge: crate::agents::profiles::O3,
            seed: 7,
            warm_early_stop: EarlyStop::default(),
            hit_latency_s: 0.05,
            lint: None,
            fair_dispatch: true,
            tenant_weights: Vec::new(),
            tenant_rate: None,
            tenant_burst: None,
        }
    }
}

impl ServiceConfig {
    /// Content address of one request under this config. Shared by the
    /// single-node and cluster replay loops so both key their caches and
    /// single-flight joins identically.
    pub fn fingerprint_of(&self, task: &TaskSpec, gpu: &crate::gpu::GpuSpec) -> Fingerprint {
        let base =
            fingerprint::of_request(task, gpu, &self.coder, &self.judge, self.strategy, self.rounds);
        match self.lint {
            None => base,
            Some(g) => fingerprint::with_lint(base, g.repair_confidence, g.max_repairs_per_round),
        }
    }

    /// The workflow a cold run of one request executes (no warm start yet).
    pub fn base_workflow(&self, gpu: &'static crate::gpu::GpuSpec) -> WorkflowConfig {
        let mut wf = WorkflowConfig::cudaforge(gpu, self.seed)
            .with_strategy(self.strategy)
            .with_rounds(self.rounds);
        wf.coder = self.coder;
        wf.judge = self.judge;
        if let Some(g) = self.lint {
            wf = wf.with_lint(g);
        }
        wf
    }

    /// Seed a workflow from a cached cross-GPU kernel, applying this
    /// config's warm-run early-stop policy.
    pub fn warm_start_from(&self, wf: WorkflowConfig, entry: &CacheEntry) -> WorkflowConfig {
        let source_gpu = crate::gpu::by_key(&entry.gpu_key).map(|g| g.key).unwrap_or("unknown");
        wf.with_warm_start(WarmStart {
            config: entry.best_config.clone(),
            source_gpu,
            source_speedup: entry.best_speedup,
        })
        .with_early_stop(self.warm_early_stop)
    }
}

/// Latency/SLO aggregates for one priority class. Rejected requests have no
/// latency and are excluded from the percentiles; they are scored separately.
#[derive(Clone, Debug, PartialEq)]
pub struct PriorityClassReport {
    /// The priority class these aggregates cover.
    pub priority: Priority,
    /// Requests of this class in the trace (served + rejected).
    pub requests: usize,
    /// Requests of this class shed by admission control.
    pub rejected: u64,
    /// Median latency over served requests of this class, seconds.
    pub p50_latency_s: f64,
    /// 95th-percentile latency of this class, seconds.
    pub p95_latency_s: f64,
    /// 99th-percentile latency of this class, seconds.
    pub p99_latency_s: f64,
    /// The class's SLO latency target.
    pub slo_target_s: f64,
    /// Fraction of *served* requests within the target (1.0 when the class
    /// is empty — a vacuous SLO holds).
    pub slo_attainment: f64,
}

/// Everything the operator wants on one screen after a replay. All fields
/// are simulated-time / request-count aggregates, deterministic per
/// (trace, config) — `PartialEq` so tests can assert replay invariance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceReport {
    /// Requests in the replayed trace.
    pub requests: usize,
    /// Workflow runs actually executed (cache misses after dedup).
    pub flights_run: usize,
    /// Requests answered straight from the result cache.
    pub cache_hits: u64,
    /// Requests served by joining an in-flight duplicate (single-flight).
    pub shared: u64,
    /// Entries evicted under capacity pressure during the replay.
    pub evictions: u64,
    /// Requests shed by admission control under overload.
    pub rejected: u64,
    /// Executed runs that were seeded from a cross-GPU cached kernel.
    pub warm_started: usize,
    /// Warm-started runs that still produced a correct kernel.
    pub warm_correct: usize,
    /// Requests served without a fresh workflow run / total.
    pub hit_rate: f64,
    /// Median served latency (queue wait + service time), seconds.
    pub p50_latency_s: f64,
    /// 95th-percentile served latency, seconds.
    pub p95_latency_s: f64,
    /// 99th-percentile served latency, seconds.
    pub p99_latency_s: f64,
    /// Mean served latency, seconds.
    pub mean_latency_s: f64,
    /// Mean simulated seconds executed flights waited for a GPU worker.
    pub mean_queue_wait_s: f64,
    /// Deepest flight backlog observed across admission decisions (every
    /// decision samples it — hits, joins, and sheds included, so a backlog
    /// sitting at its maximum while work is shed still registers).
    pub peak_queue_depth: usize,
    /// Busy time / (sim_workers × makespan): how loaded the fleet was.
    pub utilization: f64,
    /// Per-priority latency percentiles and SLO attainment.
    pub per_priority: Vec<PriorityClassReport>,
    /// API dollars actually spent on workflow runs.
    pub api_usd_spent: f64,
    /// `api_usd_cold - api_usd_spent`: what caching + dedup + warm starts
    /// avoided paying.
    pub api_usd_saved: f64,
    /// The all-cold counterfactual: every served request priced at a cold
    /// run of its own fingerprint — the first same-GPU cold run's spend,
    /// falling back to the run's own spend when no cold run was measured.
    pub api_usd_cold: f64,
    /// Mean 1-based round at which cold runs first measured their best.
    pub mean_rounds_to_best_cold: f64,
    /// Same, for warm-started runs. The warm-start payoff is
    /// `mean_rounds_to_best_warm < mean_rounds_to_best_cold`.
    pub mean_rounds_to_best_warm: f64,
    /// Simulated busy time across all runs (the fleet-size-free unit).
    pub gpu_hours: f64,
    /// Trace requests per simulated GPU-hour of work — the throughput the
    /// cache/dedup machinery buys.
    pub requests_per_gpu_hour: f64,
    /// Flights where the pre-compile static-analysis gate repaired a real
    /// bug, saving that flight a correctness-test round (0 with lint off).
    pub lint_short_circuits: u64,
    /// Requests throttled by the front-door token bucket (shed reason
    /// `rate`; a subset of `rejected`). 0 with the limiter off.
    pub rate_limited: u64,
}

/// Per-replay aggregates shared by the single-node and cluster replay
/// loops: admission fills in hit latencies, the completion hook fills in
/// everything priced per flight.
pub(crate) struct ReplayStats {
    /// `None` = not yet served (still in flight, or shed).
    pub latencies: Vec<Option<f64>>,
    pub api_spent: f64,
    pub api_cold: f64,
    pub flights_run: usize,
    pub warm_started: usize,
    pub warm_correct: usize,
    pub shared: u64,
    pub cold_rounds: Vec<f64>,
    pub warm_rounds: Vec<f64>,
    /// Flights where the static-analysis gate repaired a real bug before
    /// the compile stage (0 whenever lint is off).
    pub lint_short_circuits: u64,
}

impl ReplayStats {
    pub(crate) fn new(requests: usize) -> ReplayStats {
        ReplayStats {
            latencies: vec![None; requests],
            api_spent: 0.0,
            api_cold: 0.0,
            flights_run: 0,
            warm_started: 0,
            warm_correct: 0,
            shared: 0,
            cold_rounds: Vec::new(),
            warm_rounds: Vec::new(),
            lint_short_circuits: 0,
        }
    }
}

/// The per-flight accounting block shared by [`KernelService::replay`] and
/// `cluster::ClusterService::replay` (previously hand-synced between the
/// two; now they cannot drift): at the flight's simulated completion
/// instant, settle every member's latency, price the per-fingerprint cold
/// counterfactual, track warm-start convergence, and assemble the cache
/// entry the producing node refills. The caller inserts the returned entry
/// into whichever cache (shard) owns the fingerprint.
#[allow(clippy::too_many_arguments)]
pub(crate) fn settle_flight_completion(
    config: &ServiceConfig,
    stats: &mut ReplayStats,
    cold_cost: &mut BTreeMap<Fingerprint, f64>,
    task: &TaskSpec,
    gpu_key: &str,
    flight: &SimFlight,
    done: SimCompletion,
    warm: bool,
    result: &TaskResult,
) -> Option<CacheEntry> {
    // No answer is faster than a cache hit: member latencies floor there (a
    // follower can join moments before the flight lands).
    for (seq, arrival) in flight.members.iter() {
        stats.latencies[seq as usize] =
            Some((done.completion_s - arrival).max(config.hit_latency_s));
    }
    stats.shared += (flight.members.len() - 1) as u64;
    stats.flights_run += 1;
    stats.api_spent += result.ledger.api_usd;
    // Counterfactual pricing is per-fingerprint: a warm run stands in for
    // the first measured cold run of the *same* fingerprint, or for itself
    // when none exists. The source GPU's cold cost never leaks across
    // fingerprints.
    let cold_ref = if warm {
        cold_cost.get(&flight.fingerprint).copied().unwrap_or(result.ledger.api_usd)
    } else {
        cold_cost.entry(flight.fingerprint).or_insert(result.ledger.api_usd);
        result.ledger.api_usd
    };
    stats.api_cold += cold_ref * flight.members.len() as f64;
    // Warm-start bookkeeping covers *executed* flights only, and
    // correctness is tracked so a warm seed that stops converging is
    // visible in the report.
    if warm {
        stats.warm_started += 1;
        if result.correct {
            stats.warm_correct += 1;
        }
    }
    if let Some(r2b) = result.rounds_to_best() {
        if warm {
            stats.warm_rounds.push(r2b as f64);
        } else {
            stats.cold_rounds.push(r2b as f64);
        }
    }
    if result.lint.checks_saved > 0 {
        stats.lint_short_circuits += 1;
    }
    CacheEntry::from_run(
        flight.fingerprint,
        task.id(),
        gpu_key,
        config.strategy.name(),
        config.coder.name,
        config.judge.name,
        result,
        cold_ref,
    )
}

/// The `request.admit` trace event shared by the single-node and cluster
/// admission loops: one per arrival, stamped with the decision (`outcome`)
/// and the backlog depth sampled right after it. Callers append
/// outcome-specific fields (hit latency, shed reason, quota math).
pub(crate) fn admit_event(
    at_s: f64,
    node: usize,
    seq: u64,
    fp: Fingerprint,
    req: &TrafficRequest,
    task: &TaskSpec,
    depth: usize,
    outcome: &'static str,
) -> TraceEvent {
    TraceEvent::new(at_s, "request.admit", node)
        .field("seq", Json::num(seq as f64))
        .field("fp", Json::str(fp.to_string()))
        .field("tenant", Json::num(req.tenant as f64))
        .field("priority", Json::str(req.priority.name()))
        .field("task", Json::str(task.id()))
        .field("gpu", Json::str(req.gpu.key))
        .field("depth", Json::num(depth as f64))
        .field("outcome", Json::str(outcome))
}

/// The `flight.complete` trace event shared by both completion hooks:
/// emitted at the flight's simulated completion instant, carrying the
/// span (`start_s` → the event's `at_s`) and every settled member.
pub(crate) fn flight_complete_event(
    node: usize,
    flight: &SimFlight,
    done: SimCompletion,
    warm: bool,
    correct: bool,
    cached: bool,
) -> TraceEvent {
    TraceEvent::new(done.completion_s, "flight.complete", node)
        .field("fp", Json::str(flight.fingerprint.to_string()))
        .field("leader_seq", Json::num(flight.leader_seq as f64))
        .field("start_s", Json::num(done.start_s))
        .field("service_s", Json::num(done.completion_s - done.start_s))
        .field("warm", Json::Bool(warm))
        .field("correct", Json::Bool(correct))
        .field("cached", Json::Bool(cached))
        .field(
            "members",
            Json::Arr(
                flight
                    .members
                    .iter()
                    .map(|(seq, arrival)| {
                        Json::obj(vec![
                            ("seq", Json::num(seq as f64)),
                            ("arrival_s", Json::num(arrival)),
                        ])
                    })
                    .collect(),
            ),
        )
}

/// Per-priority latency/SLO aggregates over a replayed trace (shared by the
/// single-node and cluster reports).
pub(crate) fn per_priority_report(
    trace: &[TrafficRequest],
    latencies: &[Option<f64>],
    slo: &SloTargets,
    rejected_by_class: &[u64; 3],
) -> Vec<PriorityClassReport> {
    // One scratch buffer serves every class's percentile input, so the
    // report costs a constant number of allocations regardless of trace
    // length. `percentile` sorts a copy internally, so collecting in
    // arrival order matches the old per-class filter — bit-identical.
    let mut class: Vec<f64> = Vec::new();
    let mut out = Vec::with_capacity(ALL_PRIORITIES.len());
    for p in ALL_PRIORITIES.iter() {
        class.clear();
        let mut requests = 0usize;
        for (r, l) in trace.iter().zip(latencies) {
            if r.priority == *p {
                requests += 1;
                if let Some(l) = *l {
                    class.push(l);
                }
            }
        }
        let target = slo.target_s(*p);
        let attainment = if class.is_empty() {
            1.0
        } else {
            class.iter().filter(|l| **l <= target).count() as f64 / class.len() as f64
        };
        out.push(PriorityClassReport {
            priority: *p,
            requests,
            rejected: rejected_by_class[*p as usize],
            p50_latency_s: percentile(&class, 50.0),
            p95_latency_s: percentile(&class, 95.0),
            p99_latency_s: percentile(&class, 99.0),
            slo_target_s: target,
            slo_attainment: attainment,
        });
    }
    out
}

/// Compute every request's fingerprint exactly once per replay: distinct
/// `(task, gpu)` pairs are hashed once and the per-request column is filled
/// from the memo. The u64 [`Fingerprint`] itself is the interned id — it
/// keys every downstream probe (cache, router, single-flight, warm lookup)
/// without a secondary id space, and stays the on-disk snapshot format.
pub(crate) fn intern_fingerprints(
    config: &ServiceConfig,
    trace: &[TrafficRequest],
    tasks: &[TaskSpec],
) -> Vec<Fingerprint> {
    let mut memo: BTreeMap<(usize, &str), Fingerprint> = BTreeMap::new();
    trace
        .iter()
        .map(|req| {
            *memo
                .entry((req.task_index, req.gpu.key))
                .or_insert_with(|| config.fingerprint_of(&tasks[req.task_index], req.gpu))
        })
        .collect()
}

/// Deterministic run memo. `run_task` is a pure function of its workflow,
/// task, and oracle, so a result computed speculatively (window-batched on
/// the OS-thread pool) stands in for the event-time run whenever the event
/// loop derives the *identical* workflow. Purely a host-time optimization:
/// reported numbers never depend on what is (or is not) memoized. Bounded
/// by construction: the event loop *takes* an entry when it consumes it,
/// and each window boundary prunes entries whose fingerprint no longer has
/// a waiting or running flight (mispredicted speculations), so residency is
/// the waiting backlog plus one window's speculation — never the trace.
type MemoizedRuns = Vec<(Option<WarmStart>, TaskResult)>;

#[derive(Default)]
pub(crate) struct RunMemo {
    runs: BTreeMap<Fingerprint, MemoizedRuns>,
}

impl RunMemo {
    pub(crate) fn get(&self, fp: Fingerprint, warm: &Option<WarmStart>) -> Option<&TaskResult> {
        self.runs.get(&fp)?.iter().find(|(w, _)| w == warm).map(|(_, r)| r)
    }

    /// Remove and return the memoized result for `(fp, warm)`. Consumption
    /// is removal: a flight's result is used exactly once, at its start.
    pub(crate) fn take(
        &mut self,
        fp: Fingerprint,
        warm: &Option<WarmStart>,
    ) -> Option<TaskResult> {
        let runs = self.runs.get_mut(&fp)?;
        let i = runs.iter().position(|(w, _)| w == warm)?;
        let (_, result) = runs.swap_remove(i);
        if runs.is_empty() {
            self.runs.remove(&fp);
        }
        Some(result)
    }

    pub(crate) fn insert(&mut self, fp: Fingerprint, warm: Option<WarmStart>, result: TaskResult) {
        let runs = self.runs.entry(fp).or_default();
        if !runs.iter().any(|(w, _)| *w == warm) {
            runs.push((warm, result));
        }
    }

    /// Drop every entry whose fingerprint fails `keep` — the window-boundary
    /// sweep that discards speculations that never became flights.
    pub(crate) fn retain(&mut self, mut keep: impl FnMut(Fingerprint) -> bool) {
        self.runs.retain(|fp, _| keep(*fp));
    }
}

/// Speculatively batch-run an arrival window's predicted misses on the OS
/// thread pool — `ServiceConfig::window` is purely this batching knob. The
/// predictor returns the workflow a new flight for the request would run
/// *if it were admitted right now*, or `None` when the request is predicted
/// to hit the cache or join an existing flight. Mispredictions cost
/// wall-clock only: the event loop re-runs them inline with the true
/// event-time workflow.
pub(crate) fn speculate_window(
    memo: &mut RunMemo,
    threads: usize,
    tasks: &[TaskSpec],
    oracle: &dyn CorrectnessOracle,
    win: &[TrafficRequest],
    win_fps: &[Fingerprint],
    mut predict: impl FnMut(Fingerprint, &TrafficRequest) -> Option<WorkflowConfig>,
) {
    debug_assert_eq!(win.len(), win_fps.len(), "fingerprint column aligns with the window");
    let mut seen: BTreeSet<Fingerprint> = BTreeSet::new();
    let mut spec: Vec<(Fingerprint, WorkflowConfig, usize)> = Vec::new();
    for (req, &fp) in win.iter().zip(win_fps) {
        if !seen.insert(fp) {
            continue;
        }
        let Some(wf) = predict(fp, req) else { continue };
        if memo.get(fp, &wf.warm_start).is_none() {
            spec.push((fp, wf, req.task_index));
        }
    }
    let results = run_indexed(spec.len(), threads, |i| {
        run_task(&spec[i].1, &tasks[spec[i].2], oracle)
    });
    for ((fp, wf, _), r) in spec.into_iter().zip(results) {
        memo.insert(fp, wf.warm_start, r);
    }
}

/// A flight's run, carried from its start event to its completion event
/// (shared by the single-node and cluster replay contexts).
pub(crate) struct PendingRun {
    pub(crate) result: TaskResult,
    pub(crate) warm: bool,
}

/// The single-node replay context. Implements [`FleetHooks`]: start events
/// pick the warm seed against event-time cache state and run (or look up)
/// the workflow; completion events apply the flight's side effects at its
/// completion instant via [`settle_flight_completion`].
struct ServiceHooks<'a, 'o> {
    config: &'a ServiceConfig,
    trace: &'a [TrafficRequest],
    tasks: &'a [TaskSpec],
    oracle: &'a dyn CorrectnessOracle,
    cache: &'a mut ResultCache,
    cold_cost: &'a mut BTreeMap<Fingerprint, f64>,
    stats: ReplayStats,
    memo: RunMemo,
    pending: BTreeMap<u64, PendingRun>,
    /// Causality audit: the completion instant of each fingerprint's
    /// producing flight *this replay* (absent = resident before the replay
    /// started, available from t = 0).
    visible_at: BTreeMap<Fingerprint, f64>,
    /// The flight recorder. Every emission below happens on the
    /// deterministic event-loop path, at a simulated instant — never from
    /// the speculative OS-thread pool.
    obs: &'a mut Observer<'o>,
}

impl FleetHooks for ServiceHooks<'_, '_> {
    fn on_start(&mut self, flight: &SimFlight, start_s: f64, fair: DispatchSnapshot) -> f64 {
        let req = &self.trace[flight.leader_seq as usize];
        let task = &self.tasks[req.task_index];
        let c = self.config;
        let base = c.base_workflow(req.gpu);
        self.obs.enter(Stage::WarmLookup);
        let cand = self.cache.warm_candidate(
            &task.id(),
            req.gpu.key,
            c.strategy.name(),
            c.coder.name,
            c.judge.name,
        );
        self.obs.exit(Stage::WarmLookup);
        let fp = flight.fingerprint;
        let leader = flight.leader_seq;
        self.obs.emit(|| {
            let ev = TraceEvent::new(start_s, "warm.lookup", 0)
                .field("fp", Json::str(fp.to_string()))
                .field("leader_seq", Json::num(leader as f64));
            match cand {
                Some(e) => ev
                    .field("picked", Json::str("own"))
                    .field("own_speedup", Json::num(e.best_speedup))
                    .field("source_fp", Json::str(e.fingerprint.to_string()))
                    .field("source_gpu", Json::str(e.gpu_key.clone())),
                None => ev.field("picked", Json::str("none")),
            }
        });
        let wf = match cand {
            Some(entry) => {
                // The causality contract: a warm seed's producing flight
                // completed no later than this flight's start.
                if let Some(done) = self.visible_at.get(&entry.fingerprint) {
                    debug_assert!(
                        *done <= start_s,
                        "warm seed {} completes at {done} > consumer start {start_s}",
                        entry.fingerprint,
                    );
                }
                c.warm_start_from(base, entry)
            }
            None => base,
        };
        self.obs.enter(Stage::Workflow);
        let result = match self.memo.take(flight.fingerprint, &wf.warm_start) {
            Some(r) => r,
            // Speculation missed (e.g. an earlier completion changed the
            // warm seed since the batch was predicted): run inline with the
            // true event-time workflow.
            None => run_task(&wf, task, self.oracle),
        };
        self.obs.exit(Stage::Workflow);
        let service_s = result.ledger.wall_s;
        let warm = wf.warm_start.is_some();
        let members = flight.members.len();
        let tenant = flight.tenant;
        self.obs.emit(|| {
            TraceEvent::new(start_s, "flight.start", 0)
                .field("fp", Json::str(fp.to_string()))
                .field("leader_seq", Json::num(leader as f64))
                .field("service_s", Json::num(service_s))
                .field("warm", Json::Bool(warm))
                .field("members", Json::num(members as f64))
                .field("tenant", Json::num(tenant as f64))
                .field("deficit", Json::num(fair.deficit_s))
                .field("vtime", Json::num(fair.vtime_s))
                .field("weight", Json::num(fair.weight))
        });
        self.pending.insert(
            flight.leader_seq,
            PendingRun { result, warm },
        );
        service_s
    }

    fn on_complete(&mut self, flight: &SimFlight, done: SimCompletion) {
        let run = self
            .pending
            .remove(&flight.leader_seq)
            .expect("a completion follows its start");
        let req = &self.trace[flight.leader_seq as usize];
        let task = &self.tasks[req.task_index];
        let lint_saved = run.result.lint.checks_saved;
        let correct = run.result.correct;
        let entry = settle_flight_completion(
            self.config,
            &mut self.stats,
            self.cold_cost,
            task,
            req.gpu.key,
            flight,
            done,
            run.warm,
            &run.result,
        );
        let cached = entry.is_some();
        self.obs.emit(|| flight_complete_event(0, flight, done, run.warm, correct, cached));
        if lint_saved > 0 {
            let fp = flight.fingerprint;
            let leader = flight.leader_seq;
            self.obs.emit(|| {
                TraceEvent::new(done.completion_s, "lint.short_circuit", 0)
                    .field("fp", Json::str(fp.to_string()))
                    .field("leader_seq", Json::num(leader as f64))
                    .field("checks_saved", Json::num(lint_saved as f64))
            });
        }
        if let Some(e) = entry {
            self.visible_at.insert(e.fingerprint, done.completion_s);
            if let Some(evicted) = self.cache.insert(e) {
                self.obs.emit(|| {
                    TraceEvent::new(done.completion_s, "cache.evict", 0)
                        .field("fp", Json::str(evicted.to_string()))
                });
            }
        }
    }
}

/// The long-lived service: a cache plus the admission/dispatch loop.
pub struct KernelService {
    /// The deployment parameters the service was built with.
    pub config: ServiceConfig,
    cache: ResultCache,
    /// First measured *cold*-run spend per fingerprint — the counterfactual
    /// price a warm run of the same fingerprint stands in for. Never
    /// inherited across fingerprints (a warm chain must not propagate its
    /// source GPU's cold cost).
    cold_cost: BTreeMap<Fingerprint, f64>,
}

impl KernelService {
    /// A cold service (empty cache) under `config`.
    pub fn new(config: ServiceConfig) -> KernelService {
        let cache = ResultCache::new(config.capacity);
        KernelService { config, cache, cold_cost: BTreeMap::new() }
    }

    /// Start with a restored cache (warm restart from a snapshot). The
    /// cold-cost registry starts empty: warm runs fall back to their own
    /// spend as the counterfactual until a cold run is measured.
    pub fn with_cache(config: ServiceConfig, cache: ResultCache) -> KernelService {
        KernelService { config, cache, cold_cost: BTreeMap::new() }
    }

    /// The service's result cache (introspection/snapshotting).
    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    /// Content address of one request under this service's config.
    pub fn fingerprint_of(&self, task: &TaskSpec, gpu: &crate::gpu::GpuSpec) -> Fingerprint {
        self.config.fingerprint_of(task, gpu)
    }

    /// Replay a traffic trace through the service. `trace[i].task_index`
    /// indexes into `tasks`, and arrivals must be nondecreasing (as
    /// [`traffic::generate`] produces). Deterministic per (config, trace) —
    /// the OS thread count and the `window` batch size change wall-clock
    /// only.
    pub fn replay(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
    ) -> ServiceReport {
        let mut sink = NullSink;
        let mut obs = Observer::new(&mut sink);
        self.replay_observed(trace, tasks, oracle, &mut obs)
    }

    /// [`KernelService::replay`] with a flight recorder attached: every
    /// admission decision, warm lookup, flight span, lint short-circuit,
    /// and eviction is emitted through `obs` at its simulated instant.
    /// With a [`NullSink`] observer this is exactly `replay` (the no-op
    /// path is regression-tested bit-identical); with a
    /// [`crate::trace::Recorder`] the recorded stream is itself
    /// deterministic across OS thread counts and window sizes.
    pub fn replay_observed(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
        obs: &mut Observer<'_>,
    ) -> ServiceReport {
        let window = self.config.window.max(1);
        let sim_workers = self.config.sim_workers.max(1);
        debug_assert!(
            trace.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
            "trace must be sorted by arrival time"
        );
        // Counters are deltas against the cache's lifetime stats, so a
        // service replayed twice (e.g. after a snapshot restore) reports
        // each replay on its own.
        let stats0 = self.cache.stats;
        let config = &self.config;
        let cache = &mut self.cache;
        let cold_cost = &mut self.cold_cost;

        let mut rejected = 0u64;
        let mut rejected_by_class = [0u64; 3];
        let mut rate_limited = 0u64;
        let mut peak_depth = 0usize;
        let mut limiter =
            RateLimiter::new(RatePolicy::from_config(config.tenant_rate, config.tenant_burst));

        // Intern once, probe by id: each distinct (task, gpu) pair is
        // hashed exactly once, and the admission loop reads the per-request
        // column instead of recomputing digests per arrival.
        obs.enter(Stage::Fingerprint);
        let fps = intern_fingerprints(config, trace, tasks);
        obs.exit(Stage::Fingerprint);

        let mut fleet = FleetSim::new(sim_workers);
        fleet.set_fair_dispatch(config.fair_dispatch);
        fleet.set_tenant_weights(&config.tenant_weights);
        let mut hooks = ServiceHooks {
            config,
            trace,
            tasks,
            oracle,
            cache,
            cold_cost,
            stats: ReplayStats::new(trace.len()),
            memo: RunMemo::default(),
            pending: BTreeMap::new(),
            visible_at: BTreeMap::new(),
            obs: &mut *obs,
        };

        for (w0, win) in trace.chunks(window).enumerate().map(|(i, w)| (i * window, w)) {
            // ---- speculation: batch-run predicted misses on OS threads ---
            hooks.obs.enter(Stage::Speculation);
            {
                let cache: &ResultCache = hooks.cache;
                let fleet = &fleet;
                // Sweep speculations that never became flights (their
                // request hit, joined, or was shed) so the memo stays
                // bounded by the backlog, not the trace.
                hooks.memo.retain(|fp| fleet.is_waiting(fp) || fleet.is_running(fp));
                speculate_window(
                    &mut hooks.memo,
                    config.threads,
                    tasks,
                    oracle,
                    win,
                    &fps[w0..w0 + win.len()],
                    |fp, req| {
                        if cache.peek(fp).is_some()
                            || fleet.is_waiting(fp)
                            || fleet.is_running(fp)
                        {
                            return None;
                        }
                        // A batch request arriving into a full backlog will
                        // be shed — don't burn a speculative run on it.
                        if req.priority == Priority::Batch
                            && fleet.depth() >= config.queue_depth
                        {
                            return None;
                        }
                        let base = config.base_workflow(req.gpu);
                        Some(
                            match cache.warm_candidate(
                                &tasks[req.task_index].id(),
                                req.gpu.key,
                                config.strategy.name(),
                                config.coder.name,
                                config.judge.name,
                            ) {
                                Some(entry) => config.warm_start_from(base, entry),
                                None => base,
                            },
                        )
                    },
                );
            }
            hooks.obs.exit(Stage::Speculation);

            // ---- admission: event-driven, one arrival at a time ----------
            hooks.obs.enter(Stage::Admission);
            for (off, req) in win.iter().enumerate() {
                let seq = (w0 + off) as u64;
                let now = req.arrival_s;
                // Fire every start and completion due by `now` first, so
                // this arrival observes exactly the flights completed by its
                // own instant — never results still being computed.
                hooks.obs.enter(Stage::EventHeap);
                fleet.advance(now, &mut hooks);
                hooks.obs.exit(Stage::EventHeap);
                hooks.obs.enter(Stage::Fingerprint);
                let fp = fps[seq as usize];
                hooks.obs.exit(Stage::Fingerprint);
                let task = &tasks[req.task_index];
                // Front door first: a throttled request never reaches the
                // cache, the single-flight table, or admission control — the
                // limiter protects all of them.
                if let RateDecision::Throttle { tokens, retry_at_s } =
                    limiter.check(req.tenant, now)
                {
                    rejected += 1;
                    rejected_by_class[req.priority as usize] += 1;
                    rate_limited += 1;
                    let depth = fleet.depth();
                    hooks.obs.emit(|| {
                        admit_event(now, 0, seq, fp, req, task, depth, "shed")
                            .field("reason", Json::str("rate"))
                            .field("tokens", Json::num(tokens))
                            .field("retry_at_s", Json::num(retry_at_s))
                    });
                    peak_depth = peak_depth.max(fleet.depth());
                    continue;
                }
                // Single-flight joins first: identical work waiting or on a
                // worker is shared, not redone (and a join can escalate a
                // waiting flight's priority). Joiners settle with the flight
                // at its completion.
                let joined_waiting = fleet.join_waiting(fp, seq, now, req.priority);
                if joined_waiting || fleet.join_running(fp, seq, now) {
                    let outcome =
                        if joined_waiting { "join-waiting" } else { "join-running" };
                    let depth = fleet.depth();
                    hooks
                        .obs
                        .emit(|| admit_event(now, 0, seq, fp, req, task, depth, outcome));
                } else if let Some(entry) = hooks.cache.get(fp) {
                    if let Some(done) = hooks.visible_at.get(&fp) {
                        debug_assert!(
                            *done <= now,
                            "cache hit on {fp}: producing flight completes at {done} > arrival {now}",
                        );
                    }
                    hooks.stats.latencies[seq as usize] = Some(config.hit_latency_s);
                    hooks.stats.api_cold += entry.cold_api_usd;
                    let depth = fleet.depth();
                    hooks.obs.emit(|| {
                        admit_event(now, 0, seq, fp, req, task, depth, "hit")
                            .field("latency_s", Json::num(config.hit_latency_s))
                    });
                } else if req.priority == Priority::Batch && fleet.depth() >= config.queue_depth
                {
                    // Admission control: a new batch flight past the bound
                    // is shed (a duplicate would have joined above, so this
                    // request really would grow the backlog).
                    rejected += 1;
                    rejected_by_class[req.priority as usize] += 1;
                    let depth = fleet.depth();
                    hooks.obs.emit(|| {
                        admit_event(now, 0, seq, fp, req, task, depth, "shed")
                            .field("reason", Json::str("depth"))
                    });
                } else {
                    fleet.submit(SimFlight {
                        fingerprint: fp,
                        priority: req.priority,
                        leader_seq: seq,
                        tenant: req.tenant,
                        arrival_s: now,
                        members: MemberList::one(seq, now),
                    });
                    let depth = fleet.depth();
                    hooks
                        .obs
                        .emit(|| admit_event(now, 0, seq, fp, req, task, depth, "enqueue"));
                }
                // Every admission decision samples the backlog — including
                // hits, joins, and sheds, so a backlog pinned at its
                // maximum while work is shed still registers.
                peak_depth = peak_depth.max(fleet.depth());
            }
            hooks.obs.exit(Stage::Admission);
        }
        // Drain: serve everything still waiting or running at end of trace.
        hooks.obs.enter(Stage::EventHeap);
        fleet.advance(f64::INFINITY, &mut hooks);
        hooks.obs.exit(Stage::EventHeap);
        debug_assert!(hooks.pending.is_empty(), "every started flight completed");

        let ReplayStats {
            latencies,
            api_spent,
            api_cold,
            flights_run,
            warm_started,
            warm_correct,
            shared,
            cold_rounds,
            warm_rounds,
            lint_short_circuits,
        } = hooks.stats;
        hooks.obs.enter(Stage::Report);
        let served: Vec<f64> = latencies.iter().filter_map(|l| *l).collect();
        debug_assert_eq!(
            served.len() + rejected as usize,
            trace.len(),
            "every request is served or rejected"
        );
        let per_priority = per_priority_report(trace, &latencies, &config.slo, &rejected_by_class);

        let hits = hooks.cache.stats.hits - stats0.hits;
        let evictions = hooks.cache.stats.evictions - stats0.evictions;
        let gpu_hours = fleet.busy_s() / 3600.0;
        let makespan = fleet.makespan_s();
        let report = ServiceReport {
            requests: trace.len(),
            flights_run,
            cache_hits: hits,
            shared,
            evictions,
            rejected,
            warm_started,
            warm_correct,
            hit_rate: if trace.is_empty() {
                0.0
            } else {
                (hits + shared) as f64 / trace.len() as f64
            },
            p50_latency_s: percentile(&served, 50.0),
            p95_latency_s: percentile(&served, 95.0),
            p99_latency_s: percentile(&served, 99.0),
            mean_latency_s: mean(&served),
            mean_queue_wait_s: fleet.mean_queue_wait_s(),
            peak_queue_depth: peak_depth,
            utilization: if makespan > 0.0 {
                fleet.busy_s() / (sim_workers as f64 * makespan)
            } else {
                0.0
            },
            per_priority,
            api_usd_spent: api_spent,
            api_usd_saved: api_cold - api_spent,
            api_usd_cold: api_cold,
            mean_rounds_to_best_cold: mean(&cold_rounds),
            mean_rounds_to_best_warm: mean(&warm_rounds),
            gpu_hours,
            requests_per_gpu_hour: if gpu_hours > 0.0 {
                trace.len() as f64 / gpu_hours
            } else {
                0.0
            },
            lint_short_circuits,
            rate_limited,
        };
        hooks.obs.exit(Stage::Report);
        report
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu;
    use crate::service::traffic::{generate, TrafficConfig};
    use crate::tasks;
    use crate::workflow::NoOracle;

    fn small_service(threads: usize) -> KernelService {
        KernelService::new(ServiceConfig {
            threads,
            window: 16,
            ..ServiceConfig::default()
        })
    }

    /// A hand-built request at an explicit simulated instant.
    fn req_at(
        task_index: usize,
        gpu_key: &str,
        priority: Priority,
        arrival_s: f64,
    ) -> TrafficRequest {
        TrafficRequest {
            task_index,
            gpu: gpu::by_key(gpu_key).unwrap(),
            priority,
            tenant: 0,
            arrival_s,
        }
    }

    #[test]
    fn zipf_replay_mostly_hits() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 400, ..TrafficConfig::default() },
        );
        let mut svc = small_service(2);
        let report = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(report.requests, 400);
        assert!(report.hit_rate > 0.5, "hit rate {}", report.hit_rate);
        assert!(report.flights_run < 400);
        assert!(report.api_usd_saved > 0.0);
        assert!(
            (report.api_usd_cold - report.api_usd_spent - report.api_usd_saved).abs()
                < 1e-9
        );
        // Hits answer in ~hit_latency; misses in ~half-hour of simulated
        // time plus queue wait. With >50% hits the median collapses, the
        // tail does not.
        assert!(report.p50_latency_s < report.p95_latency_s);
        assert!(report.p95_latency_s <= report.p99_latency_s);
        assert!(report.utilization > 0.0 && report.utilization <= 1.0);
    }

    #[test]
    fn accounting_identities_hold() {
        let suite = tasks::dstar();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 120, ..TrafficConfig::default() },
        );
        let mut svc = small_service(2);
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(
            r.cache_hits + r.shared + r.flights_run as u64 + r.rejected,
            r.requests as u64,
            "every request is a hit, a follower, a flight, or shed"
        );
        assert!(r.gpu_hours > 0.0);
        assert!(r.requests_per_gpu_hour > 0.0);
        assert_eq!(r.per_priority.len(), 3);
        assert_eq!(
            r.per_priority.iter().map(|c| c.requests).sum::<usize>(),
            r.requests
        );
        for c in &r.per_priority {
            assert!((0.0..=1.0).contains(&c.slo_attainment), "{c:?}");
        }
    }

    #[test]
    fn eviction_pressure_counts() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 200, ..TrafficConfig::default() },
        );
        let mut svc = KernelService::new(ServiceConfig {
            capacity: 8, // far below the distinct-fingerprint count
            threads: 2,
            window: 16,
            ..ServiceConfig::default()
        });
        let tiny = svc.replay(&trace, &suite, &NoOracle);
        assert!(tiny.evictions > 0, "tiny cache must evict");

        let mut big = KernelService::new(ServiceConfig {
            capacity: 4096,
            threads: 2,
            window: 16,
            ..ServiceConfig::default()
        });
        let roomy = big.replay(&trace, &suite, &NoOracle);
        assert_eq!(roomy.evictions, 0);
        assert!(roomy.hit_rate >= tiny.hit_rate);
    }

    #[test]
    fn queue_wait_is_charged_on_a_saturated_fleet() {
        // Four distinct tasks arrive together; one simulated worker must
        // serialize them, so tail latency strictly exceeds any single run's
        // service time — the bug this model replaced reported bare wall_s.
        let suite = tasks::kernelbench();
        let mk = |sim_workers: usize| {
            KernelService::new(ServiceConfig {
                threads: 1,
                window: 16,
                sim_workers,
                ..ServiceConfig::default()
            })
        };
        let trace: Vec<TrafficRequest> = (0..4)
            .map(|i| req_at(i, "rtx6000", Priority::Standard, 0.0))
            .collect();

        // Per-task solo replays: latency == that task's bare service time.
        let max_single_wall_s = (0..4)
            .map(|i| {
                let solo = [req_at(i, "rtx6000", Priority::Standard, 0.0)];
                let r = mk(1).replay(&solo, &suite, &NoOracle);
                assert_eq!(r.flights_run, 1);
                assert_eq!(r.mean_queue_wait_s, 0.0, "a lone flight never waits");
                r.p95_latency_s
            })
            .fold(0.0f64, f64::max);

        let one_worker = mk(1).replay(&trace, &suite, &NoOracle);
        assert_eq!(one_worker.flights_run, 4);
        assert!(
            one_worker.p95_latency_s > max_single_wall_s,
            "p95 {} must exceed the longest single run {max_single_wall_s}",
            one_worker.p95_latency_s
        );
        assert!(one_worker.mean_queue_wait_s > 0.0);
        // The first flight starts at its arrival instant (event-driven
        // dispatch), so the deepest observed backlog is the other three.
        assert!(one_worker.peak_queue_depth >= 3);

        // With a worker per flight nothing queues: every latency is a bare
        // service time again, so the tail falls back to <= the max run.
        let wide = mk(4).replay(&trace, &suite, &NoOracle);
        assert_eq!(wide.mean_queue_wait_s, 0.0);
        assert!(wide.p95_latency_s <= max_single_wall_s + 1e-9);
        assert!(wide.p95_latency_s < one_worker.p95_latency_s);
    }

    #[test]
    fn overload_sheds_batch_but_never_interactive() {
        let suite = tasks::kernelbench();
        // 12 distinct flights hit a 1-worker fleet with room for 2 queued
        // flights: batch arrivals beyond the bound are shed, interactive
        // arrivals are always admitted.
        let trace: Vec<TrafficRequest> = (0..12)
            .map(|i| {
                let p = if i % 4 == 3 { Priority::Interactive } else { Priority::Batch };
                req_at(i, "rtx6000", p, i as f64)
            })
            .collect();
        let mut svc = KernelService::new(ServiceConfig {
            threads: 1,
            window: 4,
            sim_workers: 1,
            queue_depth: 2,
            ..ServiceConfig::default()
        });
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert!(r.rejected > 0, "overload must shed batch work");
        assert_eq!(
            r.cache_hits + r.shared + r.flights_run as u64 + r.rejected,
            r.requests as u64
        );
        let by_class = |p: Priority| {
            r.per_priority.iter().find(|c| c.priority == p).unwrap().rejected
        };
        assert_eq!(by_class(Priority::Interactive), 0);
        assert_eq!(by_class(Priority::Standard), 0);
        assert_eq!(by_class(Priority::Batch), r.rejected);
        // The backlog sat at its maximum while batch work was shed — the
        // shed decisions themselves sample the peak.
        assert!(r.peak_queue_depth >= 2);

        // Unbounded queue, same traffic: nothing is shed.
        let mut open = KernelService::new(ServiceConfig {
            threads: 1,
            window: 4,
            sim_workers: 1,
            ..ServiceConfig::default()
        });
        assert_eq!(open.replay(&trace, &suite, &NoOracle).rejected, 0);
    }

    #[test]
    fn warm_chain_counterfactual_is_priced_per_fingerprint() {
        // A 3-GPU warm chain: cold on rtx6000, then warm on a100 (seeded
        // from rtx6000), then warm on h100. Arrivals are spaced far beyond
        // any run's service time, so each link's producing flight completes
        // before the next starts — the chain is causally possible. The old
        // accounting inherited the *source GPU's* cold cost transitively,
        // inventing savings; the fix prices each fingerprint against its
        // own cold run, falling back to the run's own spend.
        let suite = tasks::kernelbench();
        let config = ServiceConfig { threads: 1, ..ServiceConfig::default() };
        // Deterministically pick a task whose cold rtx6000 run caches a
        // usable kernel (correct, speedup > 0) under this config, so the
        // chain is guaranteed to warm-start.
        let anchor = (0..suite.len())
            .find(|i| {
                let wf = config.base_workflow(gpu::by_key("rtx6000").unwrap());
                let r = run_task(&wf, &suite[*i], &NoOracle);
                r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
            })
            .expect("some task solves cold on rtx6000");

        let trace = vec![
            req_at(anchor, "rtx6000", Priority::Standard, 0.0),
            req_at(anchor, "a100", Priority::Standard, 100_000.0),
            req_at(anchor, "h100", Priority::Standard, 200_000.0),
        ];
        let mut svc = KernelService::new(config);
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.flights_run, 3);
        assert_eq!(r.warm_started, 2, "a100 and h100 runs must warm-start");
        assert!(r.warm_correct <= r.warm_started);

        for gpu_key in ["rtx6000", "a100", "h100"] {
            let fp = svc.fingerprint_of(&suite[anchor], gpu::by_key(gpu_key).unwrap());
            // Warm links are cached only when their run stayed correct; the
            // cold anchor is guaranteed by the probe above.
            if let Some(entry) = svc.cache().peek(fp) {
                assert_eq!(
                    entry.cold_api_usd, entry.api_usd,
                    "{gpu_key}: no prior cold run of this fingerprint exists, \
                     so the counterfactual is the run's own spend"
                );
            } else {
                assert_ne!(gpu_key, "rtx6000", "the cold anchor must be cached");
            }
        }
        // No hits, no followers, and every flight priced at its own spend:
        // the chain must not claim fictitious savings (the old code credited
        // each warm run with the rtx6000 run's cold cost).
        assert!(
            r.api_usd_saved.abs() < 1e-9,
            "fictitious savings {}",
            r.api_usd_saved
        );

        // A repeat of the cold fingerprint is a hit credited at the true
        // cold price — real savings now appear.
        let again = vec![req_at(anchor, "rtx6000", Priority::Standard, 300_000.0)];
        let r2 = svc.replay(&again, &suite, &NoOracle);
        assert_eq!(r2.cache_hits, 1);
        assert!(r2.api_usd_saved > 0.0);
    }

    #[test]
    fn front_door_rate_limit_sheds_before_admission() {
        let suite = tasks::kernelbench();
        // Five distinct interactive requests in one burst instant against a
        // 1 token / 100 s bucket with burst 2: exactly two admitted, three
        // throttled — and throttling outranks the "interactive is never
        // shed" admission rule because throttled work never reaches it.
        let trace: Vec<TrafficRequest> = (0..5)
            .map(|i| req_at(i, "rtx6000", Priority::Interactive, 0.0))
            .collect();
        let mut svc = KernelService::new(ServiceConfig {
            threads: 1,
            window: 4,
            sim_workers: 2,
            tenant_rate: Some(0.01),
            tenant_burst: Some(2.0),
            ..ServiceConfig::default()
        });
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.rate_limited, 3);
        assert_eq!(r.rejected, 3, "all sheds were throttles");
        assert_eq!(r.flights_run, 2);
        assert_eq!(
            r.cache_hits + r.shared + r.flights_run as u64 + r.rejected,
            r.requests as u64
        );

        // Limiter off: the identical trace is served in full.
        let mut open = KernelService::new(ServiceConfig {
            threads: 1,
            window: 4,
            sim_workers: 2,
            ..ServiceConfig::default()
        });
        let r = open.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.rate_limited, 0);
        assert_eq!(r.rejected, 0);
    }
}
