//! The kernel-optimization service layer: one node of the deployment.
//!
//! Everything below `service/` exists for one reason: the paper's per-kernel
//! economics (≈26.5 min, ≈$0.30 — Table 3) price a *cold* Coder/Judge loop,
//! but production traffic is dominated by repeats. A deployment serving many
//! users answers most requests from work it has already done. This module
//! simulates one *node* of that deployment on top of the existing workflow
//! engine:
//!
//! - [`fingerprint`] — content addresses: a stable digest of
//!   (task workload, GPU, models, strategy, rounds) identifying a request.
//! - [`cache`] — bounded LRU result cache keyed by fingerprint, with JSONL
//!   snapshot/restore so restarts are warm.
//! - [`queue`] — priority admission with single-flight dedup: concurrent
//!   identical requests share one workflow run.
//! - [`traffic`] — deterministic Zipf-distributed synthetic traces with
//!   Poisson arrival times and per-request tenant identity.
//! - [`pool`] — the OS-thread pool shared with `coordinator::run_suite`,
//!   plus [`pool::FleetSim`], the simulated GPU-worker fleet.
//! - [`KernelService`] — the single-node service loop over the
//!   discrete-event model described next.
//!
//! # One node vs. the cluster
//!
//! [`KernelService`] owns exactly one cache, one flight queue, and one
//! simulated fleet — the single-node picture. The ROADMAP's target of
//! millions of users is served by `crate::cluster`, which instantiates *N*
//! of these building blocks (one `ResultCache` shard, one `JobQueue`, one
//! `FleetSim` slice per simulated node), routes fingerprints across them
//! with rendezvous hashing, meters per-tenant fair-share quotas under
//! overload, and replays node-failure/rebalance scenarios. The cluster
//! layer deliberately reuses this module's types unchanged: a 1-node,
//! 1-tenant cluster replay is bit-identical to [`KernelService::replay`]
//! (an invariant the integration tests assert), so every latency/SLO
//! property validated here transfers to the sharded deployment.
//! [`ServiceConfig`] doubles as the *per-node* parameter block of
//! `cluster::ClusterConfig`; the request-shaping helpers
//! ([`ServiceConfig::fingerprint_of`], [`ServiceConfig::base_workflow`],
//! [`ServiceConfig::warm_start_from`]) are shared by both replay loops so
//! the two layers can never drift apart on what a request means.
//!
//! # The latency model
//!
//! `replay` runs a discrete-event simulation. Each trace request carries a
//! simulated arrival instant; a finite fleet of `ServiceConfig::sim_workers`
//! simulated GPU workers serves per-priority queues non-preemptively. A
//! request's reported latency is therefore *queue wait + service time*, not
//! bare service time: with one simulated worker and two concurrent misses,
//! the second request's latency includes the first run's entire remaining
//! time. Cache hits bypass the fleet (they are answered by the cache node in
//! `hit_latency_s`); followers — whether coalesced at admission or joined
//! onto waiting/running work later — inherit the leader's *remaining* time,
//! `completion - their own arrival`.
//!
//! Admission is windowed: `window` requests are admitted (cache lookups +
//! single-flight coalescing + admission control) before their flights are
//! dispatched, modelling "requests that arrive while the current batch
//! runs". Under overload — more than `queue_depth` flights waiting for a
//! worker — batch-class requests that would open a *new* flight are shed and
//! counted as `rejected`; joins and more urgent classes are always admitted.
//! On top of the corrected clock, [`SloTargets`] defines per-priority latency
//! targets and the report carries per-class p50/p95/p99 and SLO attainment,
//! so sweeping `sim_workers` answers "how many GPUs does this traffic need".
//!
//! All reported quantities are in *simulated* time (the cost model's wall
//! clock), accumulated in arrival/flight order — so a replay's report is
//! bit-identical regardless of how many OS `threads` crunch it.

pub mod cache;
pub mod fingerprint;
pub mod pool;
pub mod queue;
pub mod traffic;

use std::collections::BTreeMap;

use crate::agents::ModelProfile;
use crate::service::cache::{CacheEntry, ResultCache};
use crate::service::fingerprint::Fingerprint;
use crate::service::pool::{FleetSim, SimFlight};
use crate::service::queue::{JobQueue, Priority, Request, ALL_PRIORITIES};
use crate::service::traffic::TrafficRequest;
use crate::tasks::TaskSpec;
use crate::util::stats::{mean, percentile};
use crate::workflow::{
    run_task, CorrectnessOracle, EarlyStop, Strategy, TaskResult, WarmStart, WorkflowConfig,
};

/// Per-priority latency targets (seconds). Interactive traffic is only
/// inside its budget when it hits the cache; standard tolerates one cold
/// run; batch tolerates a day of queueing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloTargets {
    pub interactive_s: f64,
    pub standard_s: f64,
    pub batch_s: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        SloTargets { interactive_s: 120.0, standard_s: 2.0 * 3600.0, batch_s: 24.0 * 3600.0 }
    }
}

impl SloTargets {
    pub fn target_s(&self, p: Priority) -> f64 {
        match p {
            Priority::Interactive => self.interactive_s,
            Priority::Standard => self.standard_s,
            Priority::Batch => self.batch_s,
        }
    }
}

/// Service deployment parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Result-cache capacity (entries).
    pub capacity: usize,
    /// Requests per arrival window — the scope of single-flight dedup (a
    /// window models "requests that arrive while the current batch runs").
    pub window: usize,
    /// OS worker threads for crunching flights. Affects wall-clock only,
    /// never the report.
    pub threads: usize,
    /// Simulated GPU workers serving the flight queue — the fleet the
    /// latency model sizes. Decoupled from `threads`: this changes reported
    /// queue waits, never host wall-clock.
    pub sim_workers: usize,
    /// Admission control: once this many flights wait for a simulated
    /// worker, batch-priority requests that would open a new flight are
    /// shed. `usize::MAX` disables shedding.
    pub queue_depth: usize,
    /// Per-priority latency targets the report scores attainment against.
    pub slo: SloTargets,
    pub strategy: Strategy,
    pub rounds: usize,
    pub coder: ModelProfile,
    pub judge: ModelProfile,
    /// Workflow seed shared by every run (fingerprints exclude seeds, so one
    /// fingerprint must always resolve to one result).
    pub seed: u64,
    /// Early-stop policy applied to warm-started runs.
    pub warm_early_stop: EarlyStop,
    /// Simulated seconds to serve a request straight from the cache.
    pub hit_latency_s: f64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            capacity: 1024,
            window: 32,
            threads: crate::coordinator::default_threads(),
            sim_workers: 8,
            queue_depth: usize::MAX,
            slo: SloTargets::default(),
            strategy: Strategy::CudaForge,
            rounds: 10,
            coder: crate::agents::profiles::O3,
            judge: crate::agents::profiles::O3,
            seed: 7,
            warm_early_stop: EarlyStop::default(),
            hit_latency_s: 0.05,
        }
    }
}

impl ServiceConfig {
    /// Content address of one request under this config. Shared by the
    /// single-node and cluster replay loops so both key their caches and
    /// single-flight queues identically.
    pub fn fingerprint_of(&self, task: &TaskSpec, gpu: &crate::gpu::GpuSpec) -> Fingerprint {
        fingerprint::of_request(task, gpu, &self.coder, &self.judge, self.strategy, self.rounds)
    }

    /// The workflow a cold run of one request executes (no warm start yet).
    pub fn base_workflow(&self, gpu: &'static crate::gpu::GpuSpec) -> WorkflowConfig {
        let mut wf = WorkflowConfig::cudaforge(gpu, self.seed)
            .with_strategy(self.strategy)
            .with_rounds(self.rounds);
        wf.coder = self.coder;
        wf.judge = self.judge;
        wf
    }

    /// Seed a workflow from a cached cross-GPU kernel, applying this
    /// config's warm-run early-stop policy.
    pub fn warm_start_from(&self, wf: WorkflowConfig, entry: &CacheEntry) -> WorkflowConfig {
        let source_gpu = crate::gpu::by_key(&entry.gpu_key).map(|g| g.key).unwrap_or("unknown");
        wf.with_warm_start(WarmStart {
            config: entry.best_config.clone(),
            source_gpu,
            source_speedup: entry.best_speedup,
        })
        .with_early_stop(self.warm_early_stop)
    }
}

/// Latency/SLO aggregates for one priority class. Rejected requests have no
/// latency and are excluded from the percentiles; they are scored separately.
#[derive(Clone, Debug, PartialEq)]
pub struct PriorityClassReport {
    pub priority: Priority,
    /// Requests of this class in the trace (served + rejected).
    pub requests: usize,
    /// Requests of this class shed by admission control.
    pub rejected: u64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    /// The class's SLO latency target.
    pub slo_target_s: f64,
    /// Fraction of *served* requests within the target (1.0 when the class
    /// is empty — a vacuous SLO holds).
    pub slo_attainment: f64,
}

/// Everything the operator wants on one screen after a replay. All fields
/// are simulated-time / request-count aggregates, deterministic per
/// (trace, config) — `PartialEq` so tests can assert replay invariance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceReport {
    pub requests: usize,
    /// Workflow runs actually executed (cache misses after dedup).
    pub flights_run: usize,
    pub cache_hits: u64,
    /// Requests served by joining an in-flight duplicate (single-flight).
    pub shared: u64,
    pub evictions: u64,
    /// Requests shed by admission control under overload.
    pub rejected: u64,
    /// Executed runs that were seeded from a cross-GPU cached kernel.
    pub warm_started: usize,
    /// Warm-started runs that still produced a correct kernel.
    pub warm_correct: usize,
    /// Requests served without a fresh workflow run / total.
    pub hit_rate: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_latency_s: f64,
    /// Mean simulated seconds executed flights waited for a GPU worker.
    pub mean_queue_wait_s: f64,
    /// Deepest flight queue observed at any admission instant.
    pub peak_queue_depth: usize,
    /// Busy time / (sim_workers × makespan): how loaded the fleet was.
    pub utilization: f64,
    /// Per-priority latency percentiles and SLO attainment.
    pub per_priority: Vec<PriorityClassReport>,
    /// API dollars actually spent on workflow runs.
    pub api_usd_spent: f64,
    /// `api_usd_cold - api_usd_spent`: what caching + dedup + warm starts
    /// avoided paying.
    pub api_usd_saved: f64,
    /// The all-cold counterfactual: every served request priced at a cold
    /// run of its own fingerprint — the first same-GPU cold run's spend,
    /// falling back to the run's own spend when no cold run was measured.
    pub api_usd_cold: f64,
    /// Mean 1-based round at which cold runs first measured their best.
    pub mean_rounds_to_best_cold: f64,
    /// Same, for warm-started runs. The warm-start payoff is
    /// `mean_rounds_to_best_warm < mean_rounds_to_best_cold`.
    pub mean_rounds_to_best_warm: f64,
    /// Simulated busy time across all runs (the fleet-size-free unit).
    pub gpu_hours: f64,
    pub requests_per_gpu_hour: f64,
}

/// The long-lived service: a cache plus the admission/dispatch loop.
pub struct KernelService {
    pub config: ServiceConfig,
    cache: ResultCache,
    /// First measured *cold*-run spend per fingerprint — the counterfactual
    /// price a warm run of the same fingerprint stands in for. Never
    /// inherited across fingerprints (a warm chain must not propagate its
    /// source GPU's cold cost).
    cold_cost: BTreeMap<Fingerprint, f64>,
}

impl KernelService {
    pub fn new(config: ServiceConfig) -> KernelService {
        let cache = ResultCache::new(config.capacity);
        KernelService { config, cache, cold_cost: BTreeMap::new() }
    }

    /// Start with a restored cache (warm restart from a snapshot). The
    /// cold-cost registry starts empty: warm runs fall back to their own
    /// spend as the counterfactual until a cold run is measured.
    pub fn with_cache(config: ServiceConfig, cache: ResultCache) -> KernelService {
        KernelService { config, cache, cold_cost: BTreeMap::new() }
    }

    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    pub fn fingerprint_of(&self, task: &TaskSpec, gpu: &crate::gpu::GpuSpec) -> Fingerprint {
        self.config.fingerprint_of(task, gpu)
    }

    /// Prepare one flight's workflow, warm-starting from the best cached
    /// cross-GPU kernel when one exists.
    fn workflow_for(&self, req: &TrafficRequest, task: &TaskSpec) -> WorkflowConfig {
        let c = &self.config;
        let wf = c.base_workflow(req.gpu);
        let warm = self.cache.warm_candidate(
            &task.id(),
            req.gpu.key,
            c.strategy.name(),
            c.coder.name,
            c.judge.name,
        );
        match warm {
            Some(entry) => c.warm_start_from(wf, entry),
            None => wf,
        }
    }

    /// Replay a traffic trace through the service. `trace[i].task_index`
    /// indexes into `tasks`, and arrivals must be nondecreasing (as
    /// [`traffic::generate`] produces). Deterministic per (config, trace) —
    /// the OS thread count changes wall-clock only.
    pub fn replay(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
    ) -> ServiceReport {
        let window = self.config.window.max(1);
        let sim_workers = self.config.sim_workers.max(1);
        debug_assert!(
            trace.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
            "trace must be sorted by arrival time"
        );
        // Counters are deltas against the cache's lifetime stats, so a
        // service replayed twice (e.g. after a snapshot restore) reports
        // each replay on its own.
        let stats0 = self.cache.stats;

        // `None` = not served (shed, or a bug the debug_assert below catches).
        let mut latencies: Vec<Option<f64>> = vec![None; trace.len()];
        // No answer is faster than a cache hit. This also floors followers
        // whose flight — dispatched at window granularity — started before
        // they arrived and finished quickly.
        let hit_latency_s = self.config.hit_latency_s;
        let mut api_spent = 0.0;
        // The all-cold counterfactual: for every served request, what a cold
        // run of its own fingerprint costs (hits, followers, and joins credit
        // the producing flight's cold reference).
        let mut api_cold = 0.0;
        let mut flights_run = 0usize;
        let mut warm_started = 0usize;
        let mut warm_correct = 0usize;
        let mut shared = 0u64;
        let mut rejected = 0u64;
        let mut rejected_by_class = [0u64; 3];
        let mut peak_depth = 0usize;
        let mut cold_rounds: Vec<f64> = Vec::new();
        let mut warm_rounds: Vec<f64> = Vec::new();

        let mut queue = JobQueue::new();
        let mut fleet = FleetSim::new(sim_workers);
        for (w0, win) in trace.chunks(window).enumerate().map(|(i, w)| (i * window, w)) {
            // ---- admission: event-driven, one arrival at a time ----------
            for (off, req) in win.iter().enumerate() {
                let seq = (w0 + off) as u64;
                let now = req.arrival_s;
                // Serve every flight whose simulated start is due by `now`,
                // settling the latency of each of its members.
                fleet.advance(now, &mut |f, done| {
                    for (s, arr) in &f.members {
                        latencies[*s as usize] =
                            Some((done.completion_s - arr).max(hit_latency_s));
                    }
                });
                let fp = self.fingerprint_of(&tasks[req.task_index], req.gpu);
                // Single-flight joins first: identical work queued or on a
                // worker is shared, not redone (and a join can escalate a
                // waiting flight's priority).
                if let Some(cold_ref) = fleet.join_waiting(fp, seq, now, req.priority) {
                    shared += 1;
                    api_cold += cold_ref;
                    continue;
                }
                if let Some((completion_s, cold_ref)) = fleet.in_flight(fp, now) {
                    // The leader is mid-run: wait out its *remaining* time.
                    latencies[seq as usize] = Some((completion_s - now).max(hit_latency_s));
                    shared += 1;
                    api_cold += cold_ref;
                    continue;
                }
                if let Some(entry) = self.cache.get(fp) {
                    latencies[seq as usize] = Some(self.config.hit_latency_s);
                    api_cold += entry.cold_api_usd;
                    continue;
                }
                // Miss: admission control, then queue (or coalesce).
                let depth = fleet.depth() + queue.len();
                if req.priority == Priority::Batch
                    && depth >= self.config.queue_depth
                    && !queue.contains(fp)
                {
                    queue.reject();
                    rejected += 1;
                    rejected_by_class[req.priority as usize] += 1;
                    continue;
                }
                queue.push(Request {
                    seq,
                    fingerprint: fp,
                    priority: req.priority,
                    tenant: req.tenant,
                });
                peak_depth = peak_depth.max(fleet.depth() + queue.len());
            }

            // ---- dispatch: crunch the window's flights on OS threads -----
            let flights = queue.drain();
            let prepared: Vec<(WorkflowConfig, usize)> = flights
                .iter()
                .map(|f| {
                    let req = &trace[f.leader_seq as usize];
                    (self.workflow_for(req, &tasks[req.task_index]), req.task_index)
                })
                .collect();
            let results: Vec<TaskResult> = pool::run_indexed(
                prepared.len(),
                self.config.threads,
                |i| run_task(&prepared[i].0, &tasks[prepared[i].1], oracle),
            );

            // ---- accounting + cache refill + fleet submission ------------
            for ((flight, (wf, task_index)), result) in
                flights.iter().zip(&prepared).zip(&results)
            {
                flights_run += 1;
                api_spent += result.ledger.api_usd;
                let warm = wf.warm_start.is_some();
                // Counterfactual pricing is per-fingerprint: a warm run
                // stands in for the first measured cold run of the *same*
                // fingerprint, or for itself when none exists. The source
                // GPU's cold cost never leaks across fingerprints.
                let cold_ref = if warm {
                    self.cold_cost
                        .get(&flight.fingerprint)
                        .copied()
                        .unwrap_or(result.ledger.api_usd)
                } else {
                    self.cold_cost
                        .entry(flight.fingerprint)
                        .or_insert(result.ledger.api_usd);
                    result.ledger.api_usd
                };
                api_cold += cold_ref * flight.members() as f64;
                shared += flight.follower_seqs.len() as u64;
                // Warm-start bookkeeping covers *executed* flights only, and
                // correctness is tracked so a warm seed that stops converging
                // is visible in the report.
                if warm {
                    warm_started += 1;
                    if result.correct {
                        warm_correct += 1;
                    }
                }
                if let Some(r2b) = result.rounds_to_best() {
                    if warm {
                        warm_rounds.push(r2b as f64);
                    } else {
                        cold_rounds.push(r2b as f64);
                    }
                }
                if result.correct {
                    if let Some(best_config) = result.best_config.clone() {
                        let task = &tasks[*task_index];
                        self.cache.insert(CacheEntry {
                            fingerprint: flight.fingerprint,
                            task_id: task.id(),
                            gpu_key: wf.gpu.key.to_string(),
                            strategy: self.config.strategy.name().to_string(),
                            coder: self.config.coder.name.to_string(),
                            judge: self.config.judge.name.to_string(),
                            best_speedup: result.best_speedup,
                            best_config,
                            api_usd: result.ledger.api_usd,
                            cold_api_usd: cold_ref,
                            wall_s: result.ledger.wall_s,
                            rounds_to_best: result.rounds_to_best().unwrap_or(0),
                        });
                    }
                }
                let leader_arrival = trace[flight.leader_seq as usize].arrival_s;
                let mut members = Vec::with_capacity(flight.members());
                members.push((flight.leader_seq, leader_arrival));
                members.extend(
                    flight
                        .follower_seqs
                        .iter()
                        .map(|s| (*s, trace[*s as usize].arrival_s)),
                );
                fleet.submit(SimFlight {
                    fingerprint: flight.fingerprint,
                    priority: flight.priority,
                    leader_seq: flight.leader_seq,
                    tenant: flight.tenant,
                    arrival_s: leader_arrival,
                    service_s: result.ledger.wall_s,
                    members,
                    cold_ref,
                });
            }
        }
        // Drain: serve everything still queued at end of trace.
        fleet.advance(f64::INFINITY, &mut |f, done| {
            for (s, arr) in &f.members {
                latencies[*s as usize] = Some((done.completion_s - arr).max(hit_latency_s));
            }
        });

        let served: Vec<f64> = latencies.iter().filter_map(|l| *l).collect();
        debug_assert_eq!(
            served.len() + rejected as usize,
            trace.len(),
            "every request is served or rejected"
        );
        let per_priority: Vec<PriorityClassReport> = ALL_PRIORITIES
            .iter()
            .map(|p| {
                let class: Vec<f64> = trace
                    .iter()
                    .zip(&latencies)
                    .filter(|(r, _)| r.priority == *p)
                    .filter_map(|(_, l)| *l)
                    .collect();
                let target = self.config.slo.target_s(*p);
                let attainment = if class.is_empty() {
                    1.0
                } else {
                    class.iter().filter(|l| **l <= target).count() as f64 / class.len() as f64
                };
                PriorityClassReport {
                    priority: *p,
                    requests: trace.iter().filter(|r| r.priority == *p).count(),
                    rejected: rejected_by_class[*p as usize],
                    p50_latency_s: percentile(&class, 50.0),
                    p95_latency_s: percentile(&class, 95.0),
                    p99_latency_s: percentile(&class, 99.0),
                    slo_target_s: target,
                    slo_attainment: attainment,
                }
            })
            .collect();

        let hits = self.cache.stats.hits - stats0.hits;
        let evictions = self.cache.stats.evictions - stats0.evictions;
        let gpu_hours = fleet.busy_s() / 3600.0;
        let makespan = fleet.makespan_s();
        ServiceReport {
            requests: trace.len(),
            flights_run,
            cache_hits: hits,
            shared,
            evictions,
            rejected,
            warm_started,
            warm_correct,
            hit_rate: if trace.is_empty() {
                0.0
            } else {
                (hits + shared) as f64 / trace.len() as f64
            },
            p50_latency_s: percentile(&served, 50.0),
            p95_latency_s: percentile(&served, 95.0),
            p99_latency_s: percentile(&served, 99.0),
            mean_latency_s: mean(&served),
            mean_queue_wait_s: fleet.mean_queue_wait_s(),
            peak_queue_depth: peak_depth,
            utilization: if makespan > 0.0 {
                fleet.busy_s() / (sim_workers as f64 * makespan)
            } else {
                0.0
            },
            per_priority,
            api_usd_spent: api_spent,
            api_usd_saved: api_cold - api_spent,
            api_usd_cold: api_cold,
            mean_rounds_to_best_cold: mean(&cold_rounds),
            mean_rounds_to_best_warm: mean(&warm_rounds),
            gpu_hours,
            requests_per_gpu_hour: if gpu_hours > 0.0 {
                trace.len() as f64 / gpu_hours
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu;
    use crate::service::traffic::{generate, TrafficConfig};
    use crate::tasks;
    use crate::workflow::NoOracle;

    fn small_service(threads: usize) -> KernelService {
        KernelService::new(ServiceConfig {
            threads,
            window: 16,
            ..ServiceConfig::default()
        })
    }

    /// A hand-built request at an explicit simulated instant.
    fn req_at(
        task_index: usize,
        gpu_key: &str,
        priority: Priority,
        arrival_s: f64,
    ) -> TrafficRequest {
        TrafficRequest {
            task_index,
            gpu: gpu::by_key(gpu_key).unwrap(),
            priority,
            tenant: 0,
            arrival_s,
        }
    }

    #[test]
    fn zipf_replay_mostly_hits() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 400, ..TrafficConfig::default() },
        );
        let mut svc = small_service(2);
        let report = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(report.requests, 400);
        assert!(report.hit_rate > 0.5, "hit rate {}", report.hit_rate);
        assert!(report.flights_run < 400);
        assert!(report.api_usd_saved > 0.0);
        assert!(
            (report.api_usd_cold - report.api_usd_spent - report.api_usd_saved).abs()
                < 1e-9
        );
        // Hits answer in ~hit_latency; misses in ~half-hour of simulated
        // time plus queue wait. With >50% hits the median collapses, the
        // tail does not.
        assert!(report.p50_latency_s < report.p95_latency_s);
        assert!(report.p95_latency_s <= report.p99_latency_s);
        assert!(report.utilization > 0.0 && report.utilization <= 1.0);
    }

    #[test]
    fn accounting_identities_hold() {
        let suite = tasks::dstar();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 120, ..TrafficConfig::default() },
        );
        let mut svc = small_service(2);
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(
            r.cache_hits + r.shared + r.flights_run as u64 + r.rejected,
            r.requests as u64,
            "every request is a hit, a follower, a flight, or shed"
        );
        assert!(r.gpu_hours > 0.0);
        assert!(r.requests_per_gpu_hour > 0.0);
        assert_eq!(r.per_priority.len(), 3);
        assert_eq!(
            r.per_priority.iter().map(|c| c.requests).sum::<usize>(),
            r.requests
        );
        for c in &r.per_priority {
            assert!((0.0..=1.0).contains(&c.slo_attainment), "{c:?}");
        }
    }

    #[test]
    fn eviction_pressure_counts() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 200, ..TrafficConfig::default() },
        );
        let mut svc = KernelService::new(ServiceConfig {
            capacity: 8, // far below the distinct-fingerprint count
            threads: 2,
            window: 16,
            ..ServiceConfig::default()
        });
        let tiny = svc.replay(&trace, &suite, &NoOracle);
        assert!(tiny.evictions > 0, "tiny cache must evict");

        let mut big = KernelService::new(ServiceConfig {
            capacity: 4096,
            threads: 2,
            window: 16,
            ..ServiceConfig::default()
        });
        let roomy = big.replay(&trace, &suite, &NoOracle);
        assert_eq!(roomy.evictions, 0);
        assert!(roomy.hit_rate >= tiny.hit_rate);
    }

    #[test]
    fn queue_wait_is_charged_on_a_saturated_fleet() {
        // Four distinct tasks arrive together; one simulated worker must
        // serialize them, so tail latency strictly exceeds any single run's
        // service time — the bug this model replaced reported bare wall_s.
        let suite = tasks::kernelbench();
        let mk = |sim_workers: usize| {
            KernelService::new(ServiceConfig {
                threads: 1,
                window: 16,
                sim_workers,
                ..ServiceConfig::default()
            })
        };
        let trace: Vec<TrafficRequest> = (0..4)
            .map(|i| req_at(i, "rtx6000", Priority::Standard, 0.0))
            .collect();

        // Per-task solo replays: latency == that task's bare service time.
        let max_single_wall_s = (0..4)
            .map(|i| {
                let solo = [req_at(i, "rtx6000", Priority::Standard, 0.0)];
                let r = mk(1).replay(&solo, &suite, &NoOracle);
                assert_eq!(r.flights_run, 1);
                assert_eq!(r.mean_queue_wait_s, 0.0, "a lone flight never waits");
                r.p95_latency_s
            })
            .fold(0.0f64, f64::max);

        let one_worker = mk(1).replay(&trace, &suite, &NoOracle);
        assert_eq!(one_worker.flights_run, 4);
        assert!(
            one_worker.p95_latency_s > max_single_wall_s,
            "p95 {} must exceed the longest single run {max_single_wall_s}",
            one_worker.p95_latency_s
        );
        assert!(one_worker.mean_queue_wait_s > 0.0);
        assert!(one_worker.peak_queue_depth >= 4);

        // With a worker per flight nothing queues: every latency is a bare
        // service time again, so the tail falls back to <= the max run.
        let wide = mk(4).replay(&trace, &suite, &NoOracle);
        assert_eq!(wide.mean_queue_wait_s, 0.0);
        assert!(wide.p95_latency_s <= max_single_wall_s + 1e-9);
        assert!(wide.p95_latency_s < one_worker.p95_latency_s);
    }

    #[test]
    fn overload_sheds_batch_but_never_interactive() {
        let suite = tasks::kernelbench();
        // 12 distinct flights hit a 1-worker fleet with room for 2 queued
        // flights: batch arrivals beyond the bound are shed, interactive
        // arrivals are always admitted.
        let trace: Vec<TrafficRequest> = (0..12)
            .map(|i| {
                let p = if i % 4 == 3 { Priority::Interactive } else { Priority::Batch };
                req_at(i, "rtx6000", p, i as f64)
            })
            .collect();
        let mut svc = KernelService::new(ServiceConfig {
            threads: 1,
            window: 4,
            sim_workers: 1,
            queue_depth: 2,
            ..ServiceConfig::default()
        });
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert!(r.rejected > 0, "overload must shed batch work");
        assert_eq!(
            r.cache_hits + r.shared + r.flights_run as u64 + r.rejected,
            r.requests as u64
        );
        let by_class = |p: Priority| {
            r.per_priority.iter().find(|c| c.priority == p).unwrap().rejected
        };
        assert_eq!(by_class(Priority::Interactive), 0);
        assert_eq!(by_class(Priority::Standard), 0);
        assert_eq!(by_class(Priority::Batch), r.rejected);

        // Unbounded queue, same traffic: nothing is shed.
        let mut open = KernelService::new(ServiceConfig {
            threads: 1,
            window: 4,
            sim_workers: 1,
            ..ServiceConfig::default()
        });
        assert_eq!(open.replay(&trace, &suite, &NoOracle).rejected, 0);
    }

    #[test]
    fn warm_chain_counterfactual_is_priced_per_fingerprint() {
        // A 3-GPU warm chain: cold on rtx6000, then warm on a100 (seeded
        // from rtx6000), then warm on h100. The old accounting inherited the
        // *source GPU's* cold cost transitively, inventing savings; the fix
        // prices each fingerprint against its own cold run, falling back to
        // the run's own spend.
        let suite = tasks::kernelbench();
        let config = ServiceConfig {
            threads: 1,
            window: 1, // each request its own window, so warm starts chain
            ..ServiceConfig::default()
        };
        // Deterministically pick a task whose cold rtx6000 run caches a
        // usable kernel (correct, speedup > 0) under this config, so the
        // chain is guaranteed to warm-start.
        let probe = KernelService::new(config.clone());
        let anchor = (0..suite.len())
            .find(|i| {
                let req = req_at(*i, "rtx6000", Priority::Standard, 0.0);
                let wf = probe.workflow_for(&req, &suite[*i]);
                let r = run_task(&wf, &suite[*i], &NoOracle);
                r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
            })
            .expect("some task solves cold on rtx6000");

        let trace = vec![
            req_at(anchor, "rtx6000", Priority::Standard, 0.0),
            req_at(anchor, "a100", Priority::Standard, 10.0),
            req_at(anchor, "h100", Priority::Standard, 20.0),
        ];
        let mut svc = KernelService::new(config);
        let r = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.flights_run, 3);
        assert_eq!(r.warm_started, 2, "a100 and h100 runs must warm-start");
        assert!(r.warm_correct <= r.warm_started);

        for gpu_key in ["rtx6000", "a100", "h100"] {
            let fp = svc.fingerprint_of(&suite[anchor], gpu::by_key(gpu_key).unwrap());
            // Warm links are cached only when their run stayed correct; the
            // cold anchor is guaranteed by the probe above.
            if let Some(entry) = svc.cache().peek(fp) {
                assert_eq!(
                    entry.cold_api_usd, entry.api_usd,
                    "{gpu_key}: no prior cold run of this fingerprint exists, \
                     so the counterfactual is the run's own spend"
                );
            } else {
                assert_ne!(gpu_key, "rtx6000", "the cold anchor must be cached");
            }
        }
        // No hits, no followers, and every flight priced at its own spend:
        // the chain must not claim fictitious savings (the old code credited
        // each warm run with the rtx6000 run's cold cost).
        assert!(
            r.api_usd_saved.abs() < 1e-9,
            "fictitious savings {}",
            r.api_usd_saved
        );

        // A repeat of the cold fingerprint is a hit credited at the true
        // cold price — real savings now appear.
        let again = vec![req_at(anchor, "rtx6000", Priority::Standard, 30.0)];
        let r2 = svc.replay(&again, &suite, &NoOracle);
        assert_eq!(r2.cache_hits, 1);
        assert!(r2.api_usd_saved > 0.0);
    }
}
