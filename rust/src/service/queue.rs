//! Request priority classes.
//!
//! Earlier revisions also kept a standalone `JobQueue` here: requests were
//! admitted into it during an arrival window and handed to the simulated
//! fleet in a batch at the window boundary. That two-stage shape was the
//! window-granularity causality bug — a flight could not start (or become
//! visible to later arrivals) until its window drained. Single-flight
//! coalescing, priority escalation, and the waiting backlog now live
//! directly on [`crate::service::pool::FleetSim`], where they are
//! event-driven: a flight exists from its leader's arrival instant and its
//! side effects land at its simulated completion instant. What remains here
//! is the vocabulary both layers share: the priority classes and their
//! drain order.
//!
//! Flights drain most-urgent-first; *within* a priority class the default
//! order is tenant-fair — a deficit-weighted-fair queue on
//! [`crate::service::pool::FleetSim`] picks the eligible flight whose
//! leader tenant has the smallest weight-normalized service deficit (ties
//! by tenant index, then leader arrival order), so one tenant's admitted
//! backlog cannot starve another's. With a single tenant, or with fair
//! dispatch configured off, the order degenerates to the historical strict
//! leader-arrival tie-break. A flight's priority is the most urgent
//! priority among its members, so a batch request that later attracts an
//! interactive follower jumps the line.

/// Request urgency classes (lower = more urgent).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// A user is waiting at a prompt.
    Interactive,
    /// Normal API traffic.
    Standard,
    /// Offline sweeps, precomputation.
    Batch,
}

/// Every priority class, most urgent first (the drain order).
pub const ALL_PRIORITIES: [Priority; 3] =
    [Priority::Interactive, Priority::Standard, Priority::Batch];

impl Priority {
    /// Lower-case display name (reports and CLI output).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priorities_order_most_urgent_first() {
        assert!(Priority::Interactive < Priority::Standard);
        assert!(Priority::Standard < Priority::Batch);
        // Escalation takes the most urgent of two classes.
        assert_eq!(Priority::Batch.min(Priority::Interactive), Priority::Interactive);
        assert_eq!(
            ALL_PRIORITIES.map(|p| p.name()),
            ["interactive", "standard", "batch"]
        );
    }
}
