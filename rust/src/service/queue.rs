//! Job queue: request admission, priorities, and single-flight dedup.
//!
//! Requests that miss the cache are admitted here. Concurrent requests for
//! the same fingerprint coalesce into one *flight*: the first arrival is the
//! leader and actually runs the workflow; later arrivals become followers
//! and share the leader's result (and its cost) when it lands. A flight's
//! priority is the most urgent priority among its members, so a batch
//! request that later attracts an interactive follower jumps the line.
//!
//! Draining is deterministic: flights come out ordered by (priority,
//! arrival sequence), never by map iteration order.

use std::collections::BTreeMap;

use crate::service::fingerprint::Fingerprint;

/// Request urgency classes (lower = more urgent).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// A user is waiting at a prompt.
    Interactive,
    /// Normal API traffic.
    Standard,
    /// Offline sweeps, precomputation.
    Batch,
}

pub const ALL_PRIORITIES: [Priority; 3] =
    [Priority::Interactive, Priority::Standard, Priority::Batch];

impl Priority {
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }
}

/// One admitted request (already known to miss the cache).
#[derive(Clone, Debug)]
pub struct Request {
    /// Arrival sequence number — the caller's index into its trace.
    pub seq: u64,
    pub fingerprint: Fingerprint,
    pub priority: Priority,
    /// Tenant index of the requester (0 in the single-tenant world). The
    /// cluster layer attributes each flight's backlog slot to its leader's
    /// tenant when metering fair-share quotas.
    pub tenant: usize,
}

/// One unit of actual work: a leader plus the followers sharing its flight.
#[derive(Clone, Debug)]
pub struct Flight {
    pub fingerprint: Fingerprint,
    /// Arrival seq of the leader (first admitted request).
    pub leader_seq: u64,
    /// Arrival seqs of coalesced followers, in arrival order.
    pub follower_seqs: Vec<u64>,
    /// Most urgent priority across all members.
    pub priority: Priority,
    /// The *leader's* tenant — the flight's backlog slot is charged to
    /// whoever opened it, not to followers who coalesce onto it.
    pub tenant: usize,
}

impl Flight {
    pub fn members(&self) -> usize {
        1 + self.follower_seqs.len()
    }
}

/// Queue counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QueueStats {
    /// Requests admitted (leaders + followers).
    pub admitted: u64,
    /// Requests that coalesced onto an existing flight.
    pub coalesced: u64,
    /// Flights handed to the scheduler.
    pub dispatched: u64,
    /// Requests shed by admission control instead of being admitted.
    pub rejected: u64,
}

/// The pending-flight set. `BTreeMap` keyed by fingerprint keeps membership
/// checks O(log n) and every scan deterministic.
#[derive(Default)]
pub struct JobQueue {
    pending: BTreeMap<Fingerprint, Flight>,
    pub stats: QueueStats,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Whether a pending flight for `fp` exists — i.e. whether a push would
    /// coalesce instead of opening a new flight. Admission control only
    /// sheds requests that would *grow* the queue.
    pub fn contains(&self, fp: Fingerprint) -> bool {
        self.pending.contains_key(&fp)
    }

    /// Record a request shed by admission control (never admitted).
    pub fn reject(&mut self) {
        self.stats.rejected += 1;
    }

    /// Admit a request. Returns `true` when it opened a new flight, `false`
    /// when it coalesced onto an in-flight duplicate (single-flight dedup).
    pub fn push(&mut self, req: Request) -> bool {
        self.stats.admitted += 1;
        match self.pending.get_mut(&req.fingerprint) {
            Some(flight) => {
                flight.follower_seqs.push(req.seq);
                flight.priority = flight.priority.min(req.priority);
                self.stats.coalesced += 1;
                false
            }
            None => {
                self.pending.insert(
                    req.fingerprint,
                    Flight {
                        fingerprint: req.fingerprint,
                        leader_seq: req.seq,
                        follower_seqs: Vec::new(),
                        priority: req.priority,
                        tenant: req.tenant,
                    },
                );
                true
            }
        }
    }

    /// Take every pending flight, most urgent first (ties by arrival order).
    pub fn drain(&mut self) -> Vec<Flight> {
        let mut flights: Vec<Flight> = std::mem::take(&mut self.pending)
            .into_values()
            .collect();
        flights.sort_by_key(|f| (f.priority, f.leader_seq));
        self.stats.dispatched += flights.len() as u64;
        flights
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(seq: u64, fp: u64, p: Priority) -> Request {
        Request { seq, fingerprint: Fingerprint(fp), priority: p, tenant: 0 }
    }

    #[test]
    fn flight_keeps_the_leaders_tenant() {
        let mut q = JobQueue::new();
        q.push(Request { seq: 0, fingerprint: Fingerprint(1), priority: Priority::Batch, tenant: 2 });
        // A follower from another tenant coalesces but does not take over
        // the backlog attribution.
        q.push(Request { seq: 1, fingerprint: Fingerprint(1), priority: Priority::Batch, tenant: 0 });
        let flights = q.drain();
        assert_eq!(flights.len(), 1);
        assert_eq!(flights[0].tenant, 2);
        assert_eq!(flights[0].follower_seqs, vec![1]);
    }

    #[test]
    fn single_flight_dedups_identical_requests() {
        let mut q = JobQueue::new();
        assert!(q.push(req(0, 7, Priority::Standard)));
        assert!(q.contains(Fingerprint(7)));
        assert!(!q.contains(Fingerprint(9)));
        assert!(!q.push(req(1, 7, Priority::Standard)));
        assert!(!q.push(req(2, 7, Priority::Batch)));
        assert!(q.push(req(3, 9, Priority::Standard)));
        assert_eq!(q.len(), 2);
        assert_eq!(q.stats.admitted, 4);
        assert_eq!(q.stats.coalesced, 2);

        let flights = q.drain();
        assert_eq!(flights.len(), 2);
        let f7 = flights.iter().find(|f| f.fingerprint == Fingerprint(7)).unwrap();
        assert_eq!(f7.leader_seq, 0);
        assert_eq!(f7.follower_seqs, vec![1, 2]);
        assert_eq!(f7.members(), 3);
        assert_eq!(q.stats.dispatched, 2);
        assert!(q.is_empty());
    }

    #[test]
    fn followers_escalate_flight_priority() {
        let mut q = JobQueue::new();
        q.push(req(0, 1, Priority::Batch));
        q.push(req(1, 2, Priority::Standard));
        q.push(req(2, 1, Priority::Interactive)); // escalates flight 1
        let flights = q.drain();
        assert_eq!(flights[0].fingerprint, Fingerprint(1));
        assert_eq!(flights[0].priority, Priority::Interactive);
        assert_eq!(flights[1].fingerprint, Fingerprint(2));
    }

    #[test]
    fn drain_orders_by_priority_then_arrival() {
        let mut q = JobQueue::new();
        q.push(req(0, 10, Priority::Batch));
        q.push(req(1, 11, Priority::Interactive));
        q.push(req(2, 12, Priority::Standard));
        q.push(req(3, 13, Priority::Interactive));
        let order: Vec<u64> = q.drain().iter().map(|f| f.leader_seq).collect();
        assert_eq!(order, vec![1, 3, 2, 0]);
    }
}
