//! Per-tenant token-bucket rate limiting at the admission front door.
//!
//! The implementation splits three concerns so the single-node service and
//! the cluster share one limiter (see the policy/scope/decision shape of
//! production rate-limit interceptors):
//!
//! - **Policy** ([`RatePolicy`]): the refill rate and burst capacity every
//!   tenant gets. `None` — the default — disables limiting entirely and is
//!   bitwise identity with the pre-limiter replay.
//! - **Scope**: one [`Bucket`] per tenant id, grown lazily. Tenancy is the
//!   only scope the replays need; a different scope (per-GPU, per-key) would
//!   be a different index, not a different algorithm.
//! - **Decision** ([`RateDecision`]): admit (a token was consumed) or
//!   throttle (no token; carries the simulated instant the next token
//!   lands, so the shed event can say when a retry would succeed).
//!
//! # Determinism
//!
//! Refills land at *simulated* instants: a bucket refills one whole token
//! every `1/rate` seconds from its anchor. The arithmetic is evaluated
//! lazily at each decision instead of through the global event heap, which
//! is observably equivalent — between a refill landing and the next arrival
//! no other state can read the bucket — and keeps the limiter pure f64
//! arithmetic in arrival order. Arrivals are processed in seq order
//! regardless of the host thread count or window size, so decisions are
//! bit-identical across both, and a traced replay decides exactly like an
//! untraced one.

/// The per-tenant token-bucket parameters (every tenant gets the same
/// policy; weights differentiate tenants at *dispatch*, not at the door).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RatePolicy {
    /// Tokens refilled per simulated second (> 0).
    pub rate_per_s: f64,
    /// Bucket capacity: the largest burst admitted from a full bucket
    /// (>= 1).
    pub burst: f64,
}

impl RatePolicy {
    /// Build the optional policy from the CLI/config pair: `None` rate
    /// means no limiting; a missing burst defaults to one second's worth of
    /// tokens (at least one whole token).
    pub fn from_config(rate_per_s: Option<f64>, burst: Option<f64>) -> Option<RatePolicy> {
        let rate = rate_per_s?;
        assert!(rate.is_finite() && rate > 0.0, "tenant rate must be finite and > 0, got {rate}");
        let burst = burst.unwrap_or_else(|| rate.ceil().max(1.0));
        assert!(
            burst.is_finite() && burst >= 1.0,
            "tenant burst must be finite and >= 1, got {burst}"
        );
        Some(RatePolicy { rate_per_s: rate, burst })
    }
}

/// One tenant's bucket: the tokens held at `anchor_s`. Refills are whole
/// tokens, so `anchor_s` advances in exact `1/rate` steps and the token
/// count stays an integer-valued f64 — no drift across arrival patterns.
#[derive(Clone, Copy, Debug)]
struct Bucket {
    tokens: f64,
    anchor_s: f64,
}

/// The front-door verdict for one arrival.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateDecision {
    /// A token was consumed; the request proceeds to admission.
    Admit,
    /// No token at this instant. `tokens` is the (fractional-free) count
    /// the bucket held; `retry_at_s` is the simulated instant the next
    /// whole token lands.
    Throttle {
        /// Tokens in the bucket at the decision instant.
        tokens: f64,
        /// Simulated instant a retry would be admitted.
        retry_at_s: f64,
    },
}

/// The per-tenant limiter: one policy, one bucket per tenant id. With no
/// policy every decision is [`RateDecision::Admit`] and no state exists.
#[derive(Clone, Debug, Default)]
pub struct RateLimiter {
    policy: Option<RatePolicy>,
    buckets: Vec<Bucket>,
}

impl RateLimiter {
    /// A limiter enforcing `policy` (or admitting everything when `None`).
    pub fn new(policy: Option<RatePolicy>) -> RateLimiter {
        RateLimiter { policy, buckets: Vec::new() }
    }

    /// Whether any limiting is configured.
    pub fn enabled(&self) -> bool {
        self.policy.is_some()
    }

    /// Decide `tenant`'s arrival at simulated instant `now_s`. Consumes a
    /// token on admit; a throttle leaves the bucket untouched. Arrivals
    /// must be presented in nondecreasing `now_s` order per tenant (the
    /// replays' arrival order guarantees it).
    pub fn check(&mut self, tenant: usize, now_s: f64) -> RateDecision {
        let Some(policy) = self.policy else {
            return RateDecision::Admit;
        };
        if tenant >= self.buckets.len() {
            // New buckets start full, anchored at the epoch: the first
            // arrivals of a tenant ride the burst allowance.
            self.buckets
                .resize(tenant + 1, Bucket { tokens: policy.burst, anchor_s: 0.0 });
        }
        let b = &mut self.buckets[tenant];
        // Lazy whole-token refill: grant every token whose landing instant
        // is <= now, then advance the anchor by exactly the granted steps
        // (or snap to now when the bucket refills to capacity).
        let grants = ((now_s - b.anchor_s) * policy.rate_per_s).floor().max(0.0);
        if b.tokens + grants >= policy.burst {
            b.tokens = policy.burst;
            b.anchor_s = now_s;
        } else {
            b.tokens += grants;
            b.anchor_s += grants / policy.rate_per_s;
        }
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            RateDecision::Admit
        } else {
            RateDecision::Throttle {
                tokens: b.tokens,
                retry_at_s: b.anchor_s + (1.0 - b.tokens) / policy.rate_per_s,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_policy_admits_everything_statelessly() {
        let mut l = RateLimiter::new(None);
        assert!(!l.enabled());
        for i in 0..1000 {
            assert_eq!(l.check(i % 3, 0.0), RateDecision::Admit);
        }
        assert!(l.buckets.is_empty(), "no policy, no state");
    }

    #[test]
    fn burst_admits_then_throttles_with_a_retry_instant() {
        // 1 token/10s, burst 2: two immediate admits, then a throttle that
        // names the next landing.
        let mut l = RateLimiter::new(RatePolicy::from_config(Some(0.1), Some(2.0)));
        assert_eq!(l.check(0, 0.0), RateDecision::Admit);
        assert_eq!(l.check(0, 0.0), RateDecision::Admit);
        match l.check(0, 0.0) {
            RateDecision::Throttle { tokens, retry_at_s } => {
                assert_eq!(tokens, 0.0);
                assert!((retry_at_s - 10.0).abs() < 1e-12, "next token lands at t=10");
            }
            d => panic!("expected a throttle, got {d:?}"),
        }
        // At the named instant the retry is admitted.
        assert_eq!(l.check(0, 10.0), RateDecision::Admit);
        // ...and the very next arrival throttles again until t=20.
        match l.check(0, 10.0) {
            RateDecision::Throttle { retry_at_s, .. } => {
                assert!((retry_at_s - 20.0).abs() < 1e-12);
            }
            d => panic!("expected a throttle, got {d:?}"),
        }
    }

    #[test]
    fn refills_are_whole_tokens_at_exact_instants() {
        // 1 token/10s: at t=9.99 nothing landed; at t=10 one token did.
        let mut l = RateLimiter::new(RatePolicy::from_config(Some(0.1), Some(1.0)));
        assert_eq!(l.check(0, 0.0), RateDecision::Admit);
        assert!(matches!(l.check(0, 9.99), RateDecision::Throttle { .. }));
        assert_eq!(l.check(0, 10.0), RateDecision::Admit);
        // A long idle period refills to burst, never beyond: burst 1 admits
        // exactly one after any gap.
        assert_eq!(l.check(0, 1000.0), RateDecision::Admit);
        assert!(matches!(l.check(0, 1000.0), RateDecision::Throttle { .. }));
    }

    #[test]
    fn tenants_have_independent_buckets() {
        let mut l = RateLimiter::new(RatePolicy::from_config(Some(0.1), Some(1.0)));
        assert_eq!(l.check(0, 0.0), RateDecision::Admit);
        assert!(matches!(l.check(0, 0.0), RateDecision::Throttle { .. }));
        // Tenant 2's bucket is untouched by tenant 0's spend.
        assert_eq!(l.check(2, 0.0), RateDecision::Admit);
    }

    #[test]
    fn default_burst_is_one_second_of_tokens() {
        let p = RatePolicy::from_config(Some(2.5), None).unwrap();
        assert_eq!(p.burst, 3.0, "ceil(rate), at least 1");
        let p = RatePolicy::from_config(Some(0.01), None).unwrap();
        assert_eq!(p.burst, 1.0);
        assert_eq!(RatePolicy::from_config(None, Some(5.0)), None);
    }

    #[test]
    fn lazy_refill_matches_eventful_refill() {
        // The lazy arithmetic must agree with literally simulating refill
        // events: replay a fixed arrival pattern against a step-by-step
        // model that lands one token every 1/rate seconds.
        let rate = 0.25;
        let burst = 3.0;
        let arrivals: Vec<f64> =
            vec![0.0, 0.5, 1.0, 3.9, 4.0, 4.0, 8.0, 9.0, 30.0, 30.0, 30.0, 30.0, 31.0];
        let mut lazy = RateLimiter::new(Some(RatePolicy { rate_per_s: rate, burst }));

        // Eventful model: tokens + the instant of the next landing.
        let (mut tokens, mut next_land) = (burst, 1.0 / rate);
        let mut eventful = Vec::new();
        for &t in &arrivals {
            while next_land <= t {
                if tokens + 1.0 >= burst {
                    tokens = burst;
                    // A full bucket pauses refills; the next landing is one
                    // period after it next loses a token. Track lazily:
                    next_land = f64::INFINITY;
                } else {
                    tokens += 1.0;
                    next_land += 1.0 / rate;
                }
            }
            if tokens >= 1.0 {
                tokens -= 1.0;
                if next_land == f64::INFINITY {
                    next_land = t + 1.0 / rate;
                }
                eventful.push(true);
            } else {
                eventful.push(false);
            }
        }
        let lazy_decisions: Vec<bool> = arrivals
            .iter()
            .map(|&t| matches!(lazy.check(0, t), RateDecision::Admit))
            .collect();
        assert_eq!(lazy_decisions, eventful);
    }
}
