//! Reusable fixed-size worker pool over an indexed work list.
//!
//! Refactored out of `coordinator::run_suite`'s ad-hoc thread loop so the
//! batch suite runner and the service scheduler dispatch through one
//! mechanism. tokio is unavailable offline (DESIGN.md §2), so this is
//! std::thread with an atomic work counter: workers claim indices until the
//! list is exhausted, and results land in their slot regardless of which
//! worker ran them — output order, and therefore every downstream
//! aggregation, is independent of scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i` in `0..n` on up to `threads` workers, returning
/// the results in index order. Deterministic for deterministic `f` no matter
/// the worker count or interleaving.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        // Fast path: no thread spawn overhead for serial or tiny batches.
        return (0..n).map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = f(i);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = run_indexed(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn handles_empty_and_serial() {
        assert!(run_indexed(0, 4, |i| i).is_empty());
        assert_eq!(run_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
        // more threads than items
        assert_eq!(run_indexed(2, 64, |i| i), vec![0, 1]);
    }

    #[test]
    fn same_result_across_worker_counts() {
        let a = run_indexed(50, 1, |i| (i as u64).wrapping_mul(0x9E3779B9));
        let b = run_indexed(50, 7, |i| (i as u64).wrapping_mul(0x9E3779B9));
        assert_eq!(a, b);
    }
}
