//! Worker pools, real and simulated.
//!
//! Two fleets live here:
//!
//! - [`run_indexed`] — the reusable fixed-size *OS-thread* pool over an
//!   indexed work list, refactored out of `coordinator::run_suite`. It only
//!   affects how fast the host machine crunches workflow runs, never any
//!   reported number.
//! - [`FleetSim`] — the *simulated* GPU-worker fleet the service layer's
//!   discrete-event latency model schedules onto. `ServiceConfig::sim_workers`
//!   sizes this fleet; queue wait, completion times, and therefore every
//!   latency percentile in a `ServiceReport` come from it.
//!
//! tokio is unavailable offline (DESIGN.md §2), so `run_indexed` is
//! std::thread with an atomic work counter: workers claim indices until the
//! list is exhausted, and results land in their slot regardless of which
//! worker ran them — output order, and therefore every downstream
//! aggregation, is independent of scheduling.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::service::fingerprint::Fingerprint;
use crate::service::queue::Priority;

/// Run `f(i)` for every `i` in `0..n` on up to `threads` workers, returning
/// the results in index order. Deterministic for deterministic `f` no matter
/// the worker count or interleaving.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        // Fast path: no thread spawn overhead for serial or tiny batches.
        return (0..n).map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = f(i);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed slot"))
        .collect()
}

/// One unit of simulated work: a drained flight whose workflow result (and
/// therefore service time) is already known, waiting for a simulated worker.
#[derive(Clone, Debug)]
pub struct SimFlight {
    pub fingerprint: Fingerprint,
    /// Most urgent priority across members; late joiners can escalate it
    /// while the flight still waits.
    pub priority: Priority,
    /// Arrival seq of the leader — the tie-breaker within a priority class.
    pub leader_seq: u64,
    /// Leader's tenant: the cluster layer releases this tenant's backlog
    /// slot when the flight starts on a worker.
    pub tenant: usize,
    /// Simulated instant the flight exists from (its leader's arrival).
    pub arrival_s: f64,
    /// Seconds one simulated worker needs to serve it (the run's wall time).
    pub service_s: f64,
    /// `(seq, arrival_s)` of every member — leader first, then followers in
    /// join order. Each member's latency is `completion - its own arrival`.
    pub members: Vec<(u64, f64)>,
    /// Cold-counterfactual dollars each member credits (see `replay`).
    pub cold_ref: f64,
}

/// When a flight started and finished on the simulated fleet.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimCompletion {
    pub start_s: f64,
    pub completion_s: f64,
}

/// Discrete-event simulation of a finite GPU-worker fleet serving
/// per-priority queues, non-preemptively and without clairvoyance: whenever
/// a worker frees at time `f`, it takes the most urgent flight (ties by
/// leader arrival order) among those that have arrived by `max(f, earliest
/// waiting arrival)`. All state is `BTreeMap`/heap based and every scan is
/// in a total order, so a replay is bit-deterministic.
pub struct FleetSim {
    workers: usize,
    /// Next-free instant per worker. Min-heap over `f64::to_bits`, which
    /// orders like the values because simulated times are finite and >= 0.
    free_at: BinaryHeap<Reverse<u64>>,
    /// The per-priority queues: flights waiting for a worker, drained in
    /// (priority, leader arrival) order.
    waiting: BTreeMap<(Priority, u64), SimFlight>,
    /// fingerprint -> key in `waiting`, for single-flight joins.
    waiting_by_fp: BTreeMap<Fingerprint, (Priority, u64)>,
    /// `(arrival_s bits, leader_seq)` of every waiting flight — the first
    /// element is the earliest arrival, so the per-arrival `advance` probe
    /// is O(log n) instead of a scan over the whole backlog.
    arrivals: BTreeSet<(u64, u64)>,
    /// fingerprint -> (completion_s, cold_ref) of the most recently started
    /// flight, for joins onto work already on a worker.
    started: BTreeMap<Fingerprint, (f64, f64)>,
    queue_wait_s: f64,
    served: usize,
    busy_s: f64,
    makespan_s: f64,
}

impl FleetSim {
    /// `workers` is clamped to at least 1.
    pub fn new(workers: usize) -> FleetSim {
        let workers = workers.max(1);
        FleetSim {
            workers,
            free_at: (0..workers).map(|_| Reverse(0.0f64.to_bits())).collect(),
            waiting: BTreeMap::new(),
            waiting_by_fp: BTreeMap::new(),
            arrivals: BTreeSet::new(),
            started: BTreeMap::new(),
            queue_wait_s: 0.0,
            served: 0,
            busy_s: 0.0,
            makespan_s: 0.0,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Flights waiting for a worker (the admission-control depth signal).
    pub fn depth(&self) -> usize {
        self.waiting.len()
    }

    /// Enqueue a flight. Any previous flight for the same fingerprint must
    /// already have started (single-flight: a waiting duplicate would have
    /// been joined instead).
    pub fn submit(&mut self, flight: SimFlight) {
        let key = (flight.priority, flight.leader_seq);
        self.waiting_by_fp.insert(flight.fingerprint, key);
        self.arrivals.insert((flight.arrival_s.to_bits(), flight.leader_seq));
        self.waiting.insert(key, flight);
    }

    /// Join a *waiting* flight for `fp` as a follower, escalating its
    /// priority if the joiner is more urgent. Returns the flight's cold
    /// counterfactual when the join happened, `None` when nothing waits.
    pub fn join_waiting(
        &mut self,
        fp: Fingerprint,
        seq: u64,
        arrival_s: f64,
        priority: Priority,
    ) -> Option<f64> {
        let key = *self.waiting_by_fp.get(&fp)?;
        let mut flight = self.waiting.remove(&key).expect("waiting_by_fp tracks waiting");
        flight.members.push((seq, arrival_s));
        flight.priority = flight.priority.min(priority);
        let new_key = (flight.priority, flight.leader_seq);
        let cold_ref = flight.cold_ref;
        self.waiting_by_fp.insert(fp, new_key);
        self.waiting.insert(new_key, flight);
        Some(cold_ref)
    }

    /// `(completion_s, cold_ref)` of a flight for `fp` that is on a worker
    /// at `now` — started, not yet finished. A joiner's latency is the
    /// *remaining* time, `completion_s - now`.
    pub fn in_flight(&self, fp: Fingerprint, now: f64) -> Option<(f64, f64)> {
        self.started.get(&fp).copied().filter(|(done, _)| *done > now)
    }

    /// Process every service start due by `now`, invoking `on_served` per
    /// flight in start order. Call with `f64::INFINITY` to drain.
    pub fn advance(&mut self, now: f64, on_served: &mut dyn FnMut(&SimFlight, SimCompletion)) {
        while !self.waiting.is_empty() {
            let free = f64::from_bits(self.free_at.peek().expect("fleet has workers").0);
            let earliest_arrival = f64::from_bits(
                self.arrivals.first().expect("arrivals mirrors waiting").0,
            );
            // The next start: a worker is free and at least one flight has
            // arrived. Non-clairvoyant — the worker takes the best flight
            // available at that instant, not one still in the future.
            let start = free.max(earliest_arrival);
            if start > now {
                break;
            }
            // Worst-case O(waiting), but early-exits at the first eligible
            // key; under backlog (`free >= every arrival`) that is the head
            // of the map, so the common overload case selects in O(log n).
            let key = *self
                .waiting
                .iter()
                .find(|(_, f)| f.arrival_s <= start)
                .expect("a flight has arrived by the start instant")
                .0;
            let flight = self.waiting.remove(&key).expect("key taken from the map");
            self.waiting_by_fp.remove(&flight.fingerprint);
            self.arrivals.remove(&(flight.arrival_s.to_bits(), flight.leader_seq));
            self.free_at.pop();
            let completion = start + flight.service_s;
            self.free_at.push(Reverse(completion.to_bits()));
            self.started.insert(flight.fingerprint, (completion, flight.cold_ref));
            self.queue_wait_s += start - flight.arrival_s;
            self.busy_s += flight.service_s;
            self.served += 1;
            self.makespan_s = self.makespan_s.max(completion);
            on_served(&flight, SimCompletion { start_s: start, completion_s: completion });
        }
    }

    /// Total simulated worker-busy seconds across served flights.
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }

    /// Instant the last served flight completed (0 when nothing ran).
    pub fn makespan_s(&self) -> f64 {
        self.makespan_s
    }

    /// Mean seconds served flights spent waiting for a worker.
    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.queue_wait_s / self.served as f64
        }
    }

    /// Total simulated seconds served flights waited for a worker — the
    /// cluster layer sums this across node fleets before dividing, so the
    /// cluster-wide mean is flight-weighted, not node-weighted.
    pub fn total_queue_wait_s(&self) -> f64 {
        self.queue_wait_s
    }

    /// Flights this fleet has started serving.
    pub fn flights_served(&self) -> usize {
        self.served
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = run_indexed(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn handles_empty_and_serial() {
        assert!(run_indexed(0, 4, |i| i).is_empty());
        assert_eq!(run_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
        // more threads than items
        assert_eq!(run_indexed(2, 64, |i| i), vec![0, 1]);
    }

    #[test]
    fn same_result_across_worker_counts() {
        let a = run_indexed(50, 1, |i| (i as u64).wrapping_mul(0x9E3779B9));
        let b = run_indexed(50, 7, |i| (i as u64).wrapping_mul(0x9E3779B9));
        assert_eq!(a, b);
    }

    fn flight(fp: u64, seq: u64, arrival_s: f64, service_s: f64, p: Priority) -> SimFlight {
        SimFlight {
            fingerprint: Fingerprint(fp),
            priority: p,
            leader_seq: seq,
            tenant: 0,
            arrival_s,
            service_s,
            members: vec![(seq, arrival_s)],
            cold_ref: 0.30,
        }
    }

    fn drain_completions(sim: &mut FleetSim) -> Vec<(u64, SimCompletion)> {
        let mut out = Vec::new();
        sim.advance(f64::INFINITY, &mut |f, c| out.push((f.leader_seq, c)));
        out
    }

    #[test]
    fn one_worker_serializes_and_charges_queue_wait() {
        let mut sim = FleetSim::new(1);
        sim.submit(flight(1, 0, 0.0, 100.0, Priority::Standard));
        sim.submit(flight(2, 1, 10.0, 50.0, Priority::Standard));
        let done = drain_completions(&mut sim);
        assert_eq!(done[0], (0, SimCompletion { start_s: 0.0, completion_s: 100.0 }));
        // The second flight waited 90s for the worker, then ran 50s.
        assert_eq!(done[1].1.start_s, 100.0);
        assert_eq!(done[1].1.completion_s, 150.0);
        assert!((sim.mean_queue_wait_s() - 45.0).abs() < 1e-12);
        assert_eq!(sim.busy_s(), 150.0);
        assert_eq!(sim.makespan_s(), 150.0);
    }

    #[test]
    fn two_workers_run_in_parallel() {
        let mut sim = FleetSim::new(2);
        sim.submit(flight(1, 0, 0.0, 100.0, Priority::Standard));
        sim.submit(flight(2, 1, 10.0, 50.0, Priority::Standard));
        let done = drain_completions(&mut sim);
        assert_eq!(done[1].1.start_s, 10.0, "second worker picks it up at arrival");
        assert_eq!(sim.mean_queue_wait_s(), 0.0);
        assert_eq!(sim.makespan_s(), 100.0);
    }

    #[test]
    fn urgent_flights_jump_the_queue_but_never_preempt() {
        let mut sim = FleetSim::new(1);
        sim.submit(flight(1, 0, 0.0, 100.0, Priority::Batch));
        sim.submit(flight(2, 1, 5.0, 10.0, Priority::Batch));
        sim.submit(flight(3, 2, 6.0, 10.0, Priority::Interactive));
        let order: Vec<u64> = drain_completions(&mut sim).iter().map(|(s, _)| *s).collect();
        // Flight 0 was already running when 2 arrived (no preemption); the
        // interactive flight then overtakes the earlier batch flight.
        assert_eq!(order, vec![0, 2, 1]);
    }

    #[test]
    fn workers_do_not_serve_flights_from_the_future() {
        let mut sim = FleetSim::new(1);
        sim.submit(flight(1, 0, 50.0, 10.0, Priority::Batch));
        sim.submit(flight(2, 1, 80.0, 10.0, Priority::Interactive));
        let done = drain_completions(&mut sim);
        // The batch flight starts at its own arrival — the worker does not
        // idle until 80 just because something more urgent arrives later.
        assert_eq!(done[0], (0, SimCompletion { start_s: 50.0, completion_s: 60.0 }));
        assert_eq!(done[1].1.start_s, 80.0);
    }

    #[test]
    fn joins_escalate_priority_and_share_completion() {
        let mut sim = FleetSim::new(1);
        sim.submit(flight(1, 0, 0.0, 100.0, Priority::Standard));
        sim.submit(flight(2, 1, 1.0, 10.0, Priority::Batch));
        sim.submit(flight(3, 2, 2.0, 10.0, Priority::Standard));
        assert_eq!(sim.depth(), 3);
        // An interactive join on the batch flight escalates it past seq 2.
        assert_eq!(sim.join_waiting(Fingerprint(2), 3, 3.0, Priority::Interactive), Some(0.30));
        assert_eq!(sim.join_waiting(Fingerprint(99), 4, 3.0, Priority::Batch), None);
        assert_eq!(sim.depth(), 3, "a join adds no new flight");

        let mut members: Vec<Vec<u64>> = Vec::new();
        sim.advance(f64::INFINITY, &mut |f, _| {
            members.push(f.members.iter().map(|(s, _)| *s).collect())
        });
        assert_eq!(members[1], vec![1, 3], "follower rides the escalated flight");

        // Once started, the flight is joinable as in-flight work instead.
        let mut sim2 = FleetSim::new(1);
        sim2.submit(flight(7, 0, 0.0, 100.0, Priority::Standard));
        sim2.advance(0.0, &mut |_, _| {});
        assert_eq!(sim2.depth(), 0);
        assert_eq!(sim2.in_flight(Fingerprint(7), 40.0), Some((100.0, 0.30)));
        assert_eq!(sim2.in_flight(Fingerprint(7), 100.0), None, "finished by then");
        assert_eq!(sim2.join_waiting(Fingerprint(7), 1, 40.0, Priority::Standard), None);
    }
}
