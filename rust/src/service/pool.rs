//! Worker pools, real and simulated.
//!
//! Two fleets live here:
//!
//! - [`run_indexed`] — the reusable fixed-size *OS-thread* pool over an
//!   indexed work list, refactored out of `coordinator::run_suite`. It only
//!   affects how fast the host machine crunches workflow runs, never any
//!   reported number.
//! - [`FleetSim`] — the *simulated* GPU-worker fleet the service layer's
//!   discrete-event latency model schedules onto. `ServiceConfig::sim_workers`
//!   sizes this fleet; queue wait, completion times, and therefore every
//!   latency percentile in a `ServiceReport` come from it.
//!
//! The fleet is fully event-driven: a flight is submitted *without* a
//! service time, and the two [`FleetHooks`] callbacks fire at the flight's
//! simulated start (where the hook runs the workflow and returns the
//! service time) and at its simulated completion (where the hook applies
//! the flight's side effects — latency settlement, cache refill, cold-ref
//! recording). Completions are drained in timestamp order, interleaved with
//! starts, so a flight starting at instant `t` observes exactly the side
//! effects of flights whose completion is `<= t` — the dispatch-time
//! causality contract the service layer's warm starts and cache hits rely
//! on. Finished flights are pruned as their completion event fires, so the
//! in-flight index stays bounded by the number of workers, not the length
//! of the trace.
//!
//! # Hot-path storage
//!
//! Flight records live in a slab arena (`flights` + the parallel `started`
//! start-instant column; freed slots are recycled through `free_slots`), and
//! the ordered indexes (`waiting`, `running`, and the by-fingerprint probes)
//! hold `u32` slot ids instead of the records themselves. A flight is
//! written once at submission and never moved again: joins and priority
//! escalations mutate it in place, and settle reads it by id. Combined with
//! [`MemberList`]'s inline leader slot (a single-member flight — the vastly
//! common case — touches no heap at all), the submit → start → settle cycle
//! is allocation-free at steady state, which is what lets million-request
//! traces replay in seconds. Every mutation bumps [`FleetSim::version`]; the
//! cluster layer's global event heap uses the stamp to lazily invalidate
//! cached next-event entries instead of re-polling every node fleet per
//! event.
//!
//! tokio is unavailable offline (DESIGN.md §2), so `run_indexed` is
//! std::thread with an atomic work counter: workers claim indices until the
//! list is exhausted, and results land in their slot regardless of which
//! worker ran them — output order, and therefore every downstream
//! aggregation, is independent of scheduling.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::service::fingerprint::Fingerprint;
use crate::service::queue::Priority;

/// Run `f(i)` for every `i` in `0..n` on up to `threads` workers, returning
/// the results in index order. Deterministic for deterministic `f` no matter
/// the worker count or interleaving.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        // Fast path: no thread spawn overhead for serial or tiny batches.
        return (0..n).map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = f(i);
                *slots[i].lock().expect("no worker panicked holding the slot") = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("no worker panicked holding the slot")
                .expect("worker completed slot")
        })
        .collect()
}

/// The `(seq, arrival_s)` membership of a single-flight group: the leader
/// inline (every flight has one), followers in a spill vector that only
/// exists once someone actually joins. `Vec::new()` never allocates, so the
/// common single-member flight costs no heap at all — the allocation-budget
/// fence in `tests/alloc_budget.rs` leans on this.
#[derive(Clone, Debug)]
pub struct MemberList {
    first: (u64, f64),
    rest: Vec<(u64, f64)>,
}

impl MemberList {
    /// A fresh membership holding only the leader.
    pub fn one(seq: u64, arrival_s: f64) -> MemberList {
        MemberList { first: (seq, arrival_s), rest: Vec::new() }
    }

    /// Append a follower (join order is preserved after the leader).
    pub fn push(&mut self, seq: u64, arrival_s: f64) {
        self.rest.push((seq, arrival_s));
    }

    /// Members in this flight (leader + followers).
    pub fn len(&self) -> usize {
        1 + self.rest.len()
    }

    /// Never empty: a flight always carries its leader.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Iterate `(seq, arrival_s)` pairs, leader first, followers in join
    /// order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        std::iter::once(self.first).chain(self.rest.iter().copied())
    }
}

/// One unit of simulated work: a single-flight group (leader plus coalesced
/// followers) waiting for, or running on, a simulated GPU worker. The
/// flight's service time is unknown until it starts — the workflow runs at
/// the start event, not at submission.
#[derive(Clone, Debug)]
pub struct SimFlight {
    /// Content address of the work — the single-flight dedup key.
    pub fingerprint: Fingerprint,
    /// Most urgent priority across members; late joiners can escalate it
    /// while the flight still waits.
    pub priority: Priority,
    /// Arrival seq of the leader — the tie-breaker within a priority class.
    pub leader_seq: u64,
    /// Leader's tenant: the cluster layer releases this tenant's backlog
    /// slot when the flight starts on a worker.
    pub tenant: usize,
    /// Simulated instant the flight exists from (its leader's arrival).
    pub arrival_s: f64,
    /// Every member — leader first, then followers in join order (followers
    /// may join while the flight waits *or* while it runs). Each member's
    /// latency is `completion - its own arrival`, settled by the completion
    /// hook.
    pub members: MemberList,
}

/// When a flight started and finished on the simulated fleet.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimCompletion {
    /// Simulated instant the flight started on a worker.
    pub start_s: f64,
    /// Simulated instant the flight's service time elapsed.
    pub completion_s: f64,
}

/// The dispatch-fairness arithmetic at the instant a flight was picked:
/// the leader tenant's accumulated (weight-normalized) deficit, the
/// fleet-wide virtual time it is measured against, and the tenant's
/// weight. Passed to [`FleetHooks::on_start`] so the flight recorder can
/// narrate *why* this flight won (or waited for) the worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DispatchSnapshot {
    /// The tenant's normalized virtual service-seconds charged so far
    /// (before this flight's own service is charged).
    pub deficit_s: f64,
    /// The fleet's virtual clock: the smallest deficit among recently
    /// backlogged tenants. `deficit_s - vtime_s` is how far ahead of its
    /// entitlement the tenant is.
    pub vtime_s: f64,
    /// The tenant's configured weight (1.0 when unconfigured).
    pub weight: f64,
}

/// The fleet's two event callbacks. One trait rather than two closures so a
/// single mutable replay context (cache, cold-cost registry, counters) can
/// serve both without aliasing `&mut` borrows.
pub trait FleetHooks {
    /// A worker picked up `flight` at `start_s`: run (or look up) its
    /// workflow and return the service time in simulated seconds. Every
    /// completion with instant `<= start_s` has already been applied.
    /// `fair` carries the dispatch-fairness arithmetic that picked this
    /// flight (maintained, for observability, even with fair dispatch off).
    fn on_start(&mut self, flight: &SimFlight, start_s: f64, fair: DispatchSnapshot) -> f64;
    /// `flight`'s completion instant was reached: apply its side effects
    /// (settle member latencies, refill the cache, record the cold ref).
    fn on_complete(&mut self, flight: &SimFlight, done: SimCompletion);
}

/// The fleet's next internal event (used to interleave events in global
/// timestamp order, completions before starts at ties).
enum PendingEvent {
    /// Key into `running`: `(completion bits, leader_seq)`.
    Completion((u64, u64)),
    Start(f64),
}

/// Discrete-event simulation of a finite GPU-worker fleet serving
/// per-priority queues, non-preemptively and without clairvoyance: whenever
/// a worker frees at time `f`, it picks among the flights that have arrived
/// by `max(f, earliest waiting arrival)`. Priority classes strictly
/// dominate; *within* a class the default is a deficit-weighted-fair queue
/// keyed by tenant — the eligible flight whose leader tenant has the
/// smallest weight-normalized service deficit wins (ties by tenant index,
/// then leader arrival order), so an admitted hog backlog cannot monopolize
/// the workers. With a single tenant (or [`FleetSim::set_fair_dispatch`]
/// off) the pick degenerates to exactly the historical strict
/// `(priority, arrival)` order. Deficits are plain f64 sums updated in
/// event order, so the scheduler is as bit-deterministic as the rest of the
/// fleet. All state is `BTreeMap`/heap based and every scan is in a total
/// order, so a replay is bit-deterministic. Flight records live in the slab
/// arena (see the module docs) and the maps hold slot ids only.
pub struct FleetSim {
    workers: usize,
    /// Next-free instant per worker. Min-heap over `f64::to_bits`, which
    /// orders like the values because simulated times are finite and >= 0.
    free_at: BinaryHeap<Reverse<u64>>,
    /// The flight arena: records are written once at submission and mutated
    /// in place; slots are recycled through `free_slots` at completion, so
    /// the arena's length is bounded by peak concurrency, not trace length.
    flights: Vec<SimFlight>,
    /// Start instant per arena slot (the struct-of-arrays column the
    /// completion event reads; meaningful while the slot is running).
    started: Vec<f64>,
    /// Slot ids freed by completed flights, ready for reuse.
    free_slots: Vec<u32>,
    /// The per-priority queues: flights waiting for a worker, started in
    /// (priority, leader arrival) order. Values are arena slot ids.
    waiting: BTreeMap<(Priority, u64), u32>,
    /// fingerprint -> key in `waiting`, for single-flight joins.
    waiting_by_fp: BTreeMap<Fingerprint, (Priority, u64)>,
    /// `(arrival_s bits, leader_seq)` of every waiting flight — the first
    /// element is the earliest arrival, so the next-start probe is O(log n)
    /// instead of a scan over the whole backlog.
    arrivals: BTreeSet<(u64, u64)>,
    /// The completion-event queue: flights on a worker, keyed by
    /// `(completion bits, leader_seq)` so draining the map front replays
    /// completions in timestamp order. Values are arena slot ids; entries
    /// are removed as their completion fires — finished flights never
    /// accumulate.
    running: BTreeMap<(u64, u64), u32>,
    /// fingerprint -> key in `running`, for joins onto work already on a
    /// worker. Pruned with `running`, so the probe stays O(log workers).
    running_by_fp: BTreeMap<Fingerprint, (u64, u64)>,
    /// Bumped on every mutation that can change [`FleetSim::next_event`]
    /// (submit, joins, steps, multiplier changes). The cluster layer stamps
    /// its global event-heap entries with this and discards stale ones
    /// lazily instead of re-polling every fleet per event.
    version: u64,
    queue_wait_s: f64,
    served: usize,
    busy_s: f64,
    makespan_s: f64,
    /// Every service time the hooks return is scaled by this factor before
    /// the completion is scheduled — the "slow node" (straggler) knob. 1.0
    /// (the default) is bitwise identity for finite service times, so an
    /// unconfigured fleet behaves exactly as before the knob existed.
    service_multiplier: f64,
    /// Whether the within-class pick uses the deficit-weighted-fair queue
    /// (default) or the historical strict `(priority, arrival)` order. The
    /// deficit accounting below is maintained either way, so traces carry
    /// the fairness arithmetic even with the fair pick disabled.
    fair_dispatch: bool,
    /// Per-tenant weights; missing entries (and an empty vec) mean 1.0.
    tenant_weights: Vec<f64>,
    /// Per-tenant weight-normalized virtual service-seconds charged so far
    /// (grown lazily on submit). The fair pick takes the smallest.
    deficit: Vec<f64>,
    /// Waiting flights per leader tenant — the idle→backlogged transition
    /// detector for the deficit clamp below.
    waiting_by_tenant: Vec<u32>,
    /// The fleet's virtual clock: the largest pre-charge deficit any
    /// started flight has been measured at. A tenant going from idle to
    /// backlogged has its deficit clamped up to this, so a long-idle (or
    /// freshly bursting) tenant gets its fair share *from now on* rather
    /// than a make-up monopoly over the workers.
    vtime: f64,
}

impl FleetSim {
    /// `workers` is clamped to at least 1.
    pub fn new(workers: usize) -> FleetSim {
        let workers = workers.max(1);
        FleetSim {
            workers,
            free_at: (0..workers).map(|_| Reverse(0.0f64.to_bits())).collect(),
            flights: Vec::new(),
            started: Vec::new(),
            free_slots: Vec::new(),
            waiting: BTreeMap::new(),
            waiting_by_fp: BTreeMap::new(),
            arrivals: BTreeSet::new(),
            running: BTreeMap::new(),
            running_by_fp: BTreeMap::new(),
            version: 0,
            queue_wait_s: 0.0,
            served: 0,
            busy_s: 0.0,
            makespan_s: 0.0,
            service_multiplier: 1.0,
            fair_dispatch: true,
            tenant_weights: Vec::new(),
            deficit: Vec::new(),
            waiting_by_tenant: Vec::new(),
            vtime: 0.0,
        }
    }

    /// Simulated GPU workers in this fleet.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Scale every service time this fleet's workers take by `m` — a value
    /// above 1.0 models a straggler node (slow GPUs, thermal throttling, a
    /// noisy neighbour), below 1.0 a faster-than-baseline part. Non-finite
    /// or non-positive values are rejected (they would corrupt the event
    /// clock); the multiplier applies to everything the hooks charge to the
    /// flight, cross-node transfer fetches included — a slow node is slow
    /// at ingesting transfers too.
    pub fn set_service_multiplier(&mut self, m: f64) {
        assert!(m.is_finite() && m > 0.0, "service multiplier must be finite and > 0, got {m}");
        self.service_multiplier = m;
        self.version = self.version.wrapping_add(1);
    }

    /// The fleet's current service-time multiplier (1.0 unless configured).
    pub fn service_multiplier(&self) -> f64 {
        self.service_multiplier
    }

    /// Toggle the within-class deficit-weighted-fair pick. Off restores the
    /// historical strict `(priority, arrival)` dispatch order exactly; the
    /// deficit accounting keeps running either way so the flight recorder's
    /// fairness arithmetic stays comparable across the toggle.
    pub fn set_fair_dispatch(&mut self, on: bool) {
        self.fair_dispatch = on;
        self.version = self.version.wrapping_add(1);
    }

    /// Whether the fair pick is active (true unless configured off).
    pub fn fair_dispatch(&self) -> bool {
        self.fair_dispatch
    }

    /// Set per-tenant dispatch weights (indexed by tenant id; missing or
    /// non-positive/non-finite entries fall back to 1.0). An empty slice —
    /// the default — weighs every tenant equally, which with one tenant is
    /// bitwise-identical to the pre-fairness scheduler.
    pub fn set_tenant_weights(&mut self, weights: &[f64]) {
        self.tenant_weights = weights.to_vec();
        self.version = self.version.wrapping_add(1);
    }

    /// The dispatch weight of `tenant` (1.0 unless configured).
    fn weight(&self, tenant: usize) -> f64 {
        match self.tenant_weights.get(tenant) {
            Some(&w) if w.is_finite() && w > 0.0 => w,
            _ => 1.0,
        }
    }

    /// The weight-normalized virtual service-seconds charged to `tenant`
    /// so far (0.0 for a tenant the fleet has never seen).
    pub fn tenant_deficit_s(&self, tenant: usize) -> f64 {
        self.deficit.get(tenant).copied().unwrap_or(0.0)
    }

    /// Grow the per-tenant columns to cover `tenant`.
    fn ensure_tenant(&mut self, tenant: usize) {
        if tenant >= self.deficit.len() {
            self.deficit.resize(tenant + 1, 0.0);
            self.waiting_by_tenant.resize(tenant + 1, 0);
        }
    }

    /// Mutation stamp: changes whenever [`FleetSim::next_event`] may have
    /// changed. An event-heap entry recorded at version `v` is still valid
    /// iff the fleet's version is still `v`.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Flights waiting for a worker (the admission-control depth signal).
    pub fn depth(&self) -> usize {
        self.waiting.len()
    }

    /// Flights on a simulated worker right now (the flight recorder's
    /// occupancy gauge).
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Whether a flight for `fp` is waiting for a worker.
    pub fn is_waiting(&self, fp: Fingerprint) -> bool {
        self.waiting_by_fp.contains_key(&fp)
    }

    /// Whether a flight for `fp` is on a worker right now.
    pub fn is_running(&self, fp: Fingerprint) -> bool {
        self.running_by_fp.contains_key(&fp)
    }

    /// Completion instant of the running flight for `fp`, if one is on a
    /// worker (introspection/tests; joiners use [`FleetSim::join_running`]).
    pub fn in_flight(&self, fp: Fingerprint) -> Option<f64> {
        self.running_by_fp.get(&fp).map(|(bits, _)| f64::from_bits(*bits))
    }

    /// Enqueue a new flight. Single-flight: the caller must have tried
    /// [`FleetSim::join_waiting`] / [`FleetSim::join_running`] first, so no
    /// duplicate for the fingerprint exists.
    pub fn submit(&mut self, flight: SimFlight) {
        debug_assert!(
            !self.is_waiting(flight.fingerprint) && !self.is_running(flight.fingerprint),
            "single-flight: a duplicate would have been joined"
        );
        let key = (flight.priority, flight.leader_seq);
        self.ensure_tenant(flight.tenant);
        // Idle → backlogged: clamp the tenant's deficit up to the virtual
        // clock (start-time fairness, as in SFQ). Without this a tenant
        // that sat idle — or just showed up — would carry a tiny lifetime
        // deficit and monopolize the workers until it "caught up".
        if self.waiting_by_tenant[flight.tenant] == 0 {
            self.deficit[flight.tenant] = self.deficit[flight.tenant].max(self.vtime);
        }
        self.waiting_by_tenant[flight.tenant] += 1;
        self.waiting_by_fp.insert(flight.fingerprint, key);
        self.arrivals.insert((flight.arrival_s.to_bits(), flight.leader_seq));
        let idx = match self.free_slots.pop() {
            Some(i) => {
                self.flights[i as usize] = flight;
                i
            }
            None => {
                self.flights.push(flight);
                self.started.push(0.0);
                (self.flights.len() - 1) as u32
            }
        };
        self.waiting.insert(key, idx);
        self.version = self.version.wrapping_add(1);
    }

    /// Join a *waiting* flight for `fp` as a follower, escalating its
    /// priority if the joiner is more urgent. Returns whether a flight was
    /// waiting to join.
    pub fn join_waiting(
        &mut self,
        fp: Fingerprint,
        seq: u64,
        arrival_s: f64,
        priority: Priority,
    ) -> bool {
        let Some(key) = self.waiting_by_fp.get(&fp).copied() else {
            return false;
        };
        let idx = self.waiting.remove(&key).expect("waiting_by_fp tracks waiting");
        let flight = &mut self.flights[idx as usize];
        flight.members.push(seq, arrival_s);
        flight.priority = flight.priority.min(priority);
        let new_key = (flight.priority, flight.leader_seq);
        self.waiting_by_fp.insert(fp, new_key);
        self.waiting.insert(new_key, idx);
        self.version = self.version.wrapping_add(1);
        true
    }

    /// Join a *running* flight for `fp` as a follower: the joiner's answer
    /// is the leader's remaining time, settled with every other member at
    /// the completion event. Returns whether a flight was running to join.
    pub fn join_running(&mut self, fp: Fingerprint, seq: u64, arrival_s: f64) -> bool {
        let Some(key) = self.running_by_fp.get(&fp).copied() else {
            return false;
        };
        let idx = *self.running.get(&key).expect("running_by_fp tracks running");
        self.flights[idx as usize].members.push(seq, arrival_s);
        self.version = self.version.wrapping_add(1);
        true
    }

    /// The fleet's next event instant, if any: `(instant, is_completion)`.
    /// Completions order before starts at equal instants, so a flight
    /// starting at `t` sees everything that completed by `t`. The cluster
    /// layer uses this to interleave N node fleets in global event order.
    pub fn next_event(&self) -> Option<(f64, bool)> {
        self.peek_event().map(|e| match e {
            PendingEvent::Completion((bits, _)) => (f64::from_bits(bits), true),
            PendingEvent::Start(s) => (s, false),
        })
    }

    fn peek_event(&self) -> Option<PendingEvent> {
        let completion = self.running.keys().next().copied();
        let start = if self.waiting.is_empty() {
            None
        } else {
            let free = f64::from_bits(self.free_at.peek().expect("fleet has workers").0);
            let earliest = f64::from_bits(
                self.arrivals.first().expect("arrivals mirrors waiting").0,
            );
            // The next start: a worker is free and at least one flight has
            // arrived. Non-clairvoyant — the worker takes the best flight
            // available at that instant, not one still in the future.
            Some(free.max(earliest))
        };
        match (completion, start) {
            (None, None) => None,
            (None, Some(s)) => Some(PendingEvent::Start(s)),
            (Some(key), s) => {
                // Completions win ties: side effects at `t` are visible to a
                // flight starting at `t`.
                match s {
                    Some(start_s) if start_s < f64::from_bits(key.0) => {
                        Some(PendingEvent::Start(start_s))
                    }
                    _ => Some(PendingEvent::Completion(key)),
                }
            }
        }
    }

    /// The deficit-weighted-fair pick: among flights that have arrived by
    /// `start`, take the one minimizing `(priority, tenant deficit, tenant,
    /// leader_seq)`. Keys iterate in (priority, seq) order, so the scan
    /// early-breaks as soon as a later priority class is reached with a
    /// candidate already in hand — priority classes strictly dominate, the
    /// deficit only arbitrates *within* a class. With one tenant every
    /// candidate shares (deficit, tenant), so the strict `<` comparison
    /// keeps the first (lowest-seq) eligible entry — exactly the historical
    /// strict-order pick, bit for bit.
    fn fair_pick(&self, start: f64) -> (Priority, u64) {
        let mut best: Option<((Priority, u64), f64, usize)> = None;
        for (&key, &idx) in self.waiting.iter() {
            if let Some((bkey, _, _)) = best {
                if key.0 > bkey.0 {
                    break;
                }
            }
            let f = &self.flights[idx as usize];
            if f.arrival_s > start {
                continue;
            }
            let d = self.deficit.get(f.tenant).copied().unwrap_or(0.0);
            let better = match best {
                None => true,
                // Same priority class here (the break above guarantees it):
                // smallest deficit wins, ties by tenant index then seq.
                Some((bkey, bd, bt)) => (d, f.tenant, key.1) < (bd, bt, bkey.1),
            };
            if better {
                best = Some((key, d, f.tenant));
            }
        }
        best.expect("a flight has arrived by the start instant").0
    }

    /// Process the single next event if it is due by `now`. Returns whether
    /// one fired.
    pub fn step(&mut self, now: f64, hooks: &mut dyn FleetHooks) -> bool {
        match self.peek_event() {
            Some(PendingEvent::Completion(key)) if f64::from_bits(key.0) <= now => {
                let idx = self.running.remove(&key).expect("peeked key is resident") as usize;
                let fp = self.flights[idx].fingerprint;
                self.running_by_fp.remove(&fp);
                self.version = self.version.wrapping_add(1);
                hooks.on_complete(
                    &self.flights[idx],
                    SimCompletion {
                        start_s: self.started[idx],
                        completion_s: f64::from_bits(key.0),
                    },
                );
                // Settle done: recycle the slot (the record stays in place
                // until a later submission overwrites it — no deallocation).
                self.free_slots.push(idx as u32);
                true
            }
            Some(PendingEvent::Start(start)) if start <= now => {
                let key = if self.fair_dispatch {
                    self.fair_pick(start)
                } else {
                    // The historical strict (priority, arrival) scan.
                    // Worst-case O(waiting), but early-exits at the first
                    // eligible key; under backlog (`free >= every arrival`)
                    // that is the head of the map, so the common overload
                    // case selects in O(log n).
                    *self
                        .waiting
                        .iter()
                        .find(|(_, &idx)| self.flights[idx as usize].arrival_s <= start)
                        .expect("a flight has arrived by the start instant")
                        .0
                };
                let idx = self.waiting.remove(&key).expect("key taken from the map") as usize;
                let (fp, arrival_s, leader_seq, tenant) = {
                    let f = &self.flights[idx];
                    (f.fingerprint, f.arrival_s, f.leader_seq, f.tenant)
                };
                self.waiting_by_fp.remove(&fp);
                self.arrivals.remove(&(arrival_s.to_bits(), leader_seq));
                self.free_at.pop();
                self.ensure_tenant(tenant);
                self.waiting_by_tenant[tenant] =
                    self.waiting_by_tenant[tenant].saturating_sub(1);
                // The fairness arithmetic at pick time, surfaced to the
                // hooks (and so the flight recorder) before the charge.
                let weight = self.weight(tenant);
                let deficit_before = self.deficit[tenant];
                let fair = DispatchSnapshot {
                    deficit_s: deficit_before,
                    vtime_s: self.vtime.max(deficit_before),
                    weight,
                };
                let service_s =
                    hooks.on_start(&self.flights[idx], start, fair) * self.service_multiplier;
                debug_assert!(
                    service_s.is_finite() && service_s >= 0.0,
                    "service time must be finite and non-negative, got {service_s}"
                );
                // Advance the virtual clock to the picked tenant's
                // pre-charge deficit and charge the actual service,
                // normalized by weight — a weight-2 tenant accrues deficit
                // half as fast, so it wins the pick twice as often.
                self.vtime = self.vtime.max(deficit_before);
                self.deficit[tenant] = deficit_before + service_s / weight;
                let completion = start + service_s;
                self.free_at.push(Reverse(completion.to_bits()));
                self.queue_wait_s += start - arrival_s;
                self.busy_s += service_s;
                self.served += 1;
                self.makespan_s = self.makespan_s.max(completion);
                let run_key = (completion.to_bits(), leader_seq);
                self.running_by_fp.insert(fp, run_key);
                self.started[idx] = start;
                self.running.insert(run_key, idx as u32);
                self.version = self.version.wrapping_add(1);
                true
            }
            _ => false,
        }
    }

    /// Process every start and completion due by `now`, in timestamp order
    /// (completions before starts at ties). Call with `f64::INFINITY` to
    /// drain.
    pub fn advance(&mut self, now: f64, hooks: &mut dyn FleetHooks) {
        while self.step(now, hooks) {}
    }

    /// Total simulated worker-busy seconds across served flights.
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }

    /// Instant the last served flight completed (0 when nothing ran).
    pub fn makespan_s(&self) -> f64 {
        self.makespan_s
    }

    /// Mean seconds served flights spent waiting for a worker.
    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.queue_wait_s / self.served as f64
        }
    }

    /// Total simulated seconds served flights waited for a worker — the
    /// cluster layer sums this across node fleets before dividing, so the
    /// cluster-wide mean is flight-weighted, not node-weighted.
    pub fn total_queue_wait_s(&self) -> f64 {
        self.queue_wait_s
    }

    /// Flights this fleet has started serving.
    pub fn flights_served(&self) -> usize {
        self.served
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = run_indexed(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn handles_empty_and_serial() {
        assert!(run_indexed(0, 4, |i| i).is_empty());
        assert_eq!(run_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
        // more threads than items
        assert_eq!(run_indexed(2, 64, |i| i), vec![0, 1]);
    }

    #[test]
    fn same_result_across_worker_counts() {
        let a = run_indexed(50, 1, |i| (i as u64).wrapping_mul(0x9E3779B9));
        let b = run_indexed(50, 7, |i| (i as u64).wrapping_mul(0x9E3779B9));
        assert_eq!(a, b);
    }

    fn flight(fp: u64, seq: u64, arrival_s: f64, p: Priority) -> SimFlight {
        SimFlight {
            fingerprint: Fingerprint(fp),
            priority: p,
            leader_seq: seq,
            tenant: 0,
            arrival_s,
            members: MemberList::one(seq, arrival_s),
        }
    }

    /// Test hooks: a fixed service time per leader seq, with every start,
    /// completion, member list, and dispatch snapshot recorded in firing
    /// order.
    struct Script {
        service: BTreeMap<u64, f64>,
        starts: Vec<(u64, f64)>,
        snapshots: Vec<(u64, DispatchSnapshot)>,
        completions: Vec<(u64, SimCompletion)>,
        members: Vec<Vec<u64>>,
    }

    impl Script {
        fn new(service: &[(u64, f64)]) -> Script {
            Script {
                service: service.iter().copied().collect(),
                starts: Vec::new(),
                snapshots: Vec::new(),
                completions: Vec::new(),
                members: Vec::new(),
            }
        }
    }

    impl FleetHooks for Script {
        fn on_start(&mut self, f: &SimFlight, start_s: f64, fair: DispatchSnapshot) -> f64 {
            self.starts.push((f.leader_seq, start_s));
            self.snapshots.push((f.leader_seq, fair));
            self.service[&f.leader_seq]
        }
        fn on_complete(&mut self, f: &SimFlight, done: SimCompletion) {
            self.completions.push((f.leader_seq, done));
            self.members.push(f.members.iter().map(|(s, _)| s).collect());
        }
    }

    #[test]
    fn member_list_inlines_the_leader() {
        let mut m = MemberList::one(7, 1.5);
        assert_eq!(m.len(), 1);
        assert!(!m.is_empty());
        m.push(9, 2.5);
        assert_eq!(m.len(), 2);
        let all: Vec<(u64, f64)> = m.iter().collect();
        assert_eq!(all, vec![(7, 1.5), (9, 2.5)]);
    }

    #[test]
    fn one_worker_serializes_and_charges_queue_wait() {
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 100.0), (1, 50.0)]);
        sim.submit(flight(1, 0, 0.0, Priority::Standard));
        sim.submit(flight(2, 1, 10.0, Priority::Standard));
        sim.advance(f64::INFINITY, &mut hooks);
        assert_eq!(
            hooks.completions[0],
            (0, SimCompletion { start_s: 0.0, completion_s: 100.0 })
        );
        // The second flight waited 90s for the worker, then ran 50s.
        assert_eq!(hooks.completions[1].1.start_s, 100.0);
        assert_eq!(hooks.completions[1].1.completion_s, 150.0);
        assert!((sim.mean_queue_wait_s() - 45.0).abs() < 1e-12);
        assert_eq!(sim.busy_s(), 150.0);
        assert_eq!(sim.makespan_s(), 150.0);
    }

    #[test]
    fn two_workers_run_in_parallel() {
        let mut sim = FleetSim::new(2);
        let mut hooks = Script::new(&[(0, 100.0), (1, 50.0)]);
        sim.submit(flight(1, 0, 0.0, Priority::Standard));
        sim.submit(flight(2, 1, 10.0, Priority::Standard));
        sim.advance(f64::INFINITY, &mut hooks);
        assert_eq!(hooks.starts[1], (1, 10.0), "second worker picks it up at arrival");
        assert_eq!(sim.mean_queue_wait_s(), 0.0);
        assert_eq!(sim.makespan_s(), 100.0);
    }

    #[test]
    fn urgent_flights_jump_the_queue_but_never_preempt() {
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 100.0), (1, 10.0), (2, 10.0)]);
        sim.submit(flight(1, 0, 0.0, Priority::Batch));
        sim.submit(flight(2, 1, 5.0, Priority::Batch));
        sim.submit(flight(3, 2, 6.0, Priority::Interactive));
        sim.advance(f64::INFINITY, &mut hooks);
        let order: Vec<u64> = hooks.starts.iter().map(|(s, _)| *s).collect();
        // Flight 0 was already running when 2 arrived (no preemption); the
        // interactive flight then overtakes the earlier batch flight.
        assert_eq!(order, vec![0, 2, 1]);
    }

    #[test]
    fn workers_do_not_serve_flights_from_the_future() {
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 10.0), (1, 10.0)]);
        sim.submit(flight(1, 0, 50.0, Priority::Batch));
        sim.submit(flight(2, 1, 80.0, Priority::Interactive));
        sim.advance(f64::INFINITY, &mut hooks);
        // The batch flight starts at its own arrival — the worker does not
        // idle until 80 just because something more urgent arrives later.
        assert_eq!(
            hooks.completions[0],
            (0, SimCompletion { start_s: 50.0, completion_s: 60.0 })
        );
        assert_eq!(hooks.completions[1].1.start_s, 80.0);
    }

    #[test]
    fn completions_fire_before_starts_and_interleave_with_them() {
        // Worker frees at 100 (flight 0 completes); flight 1 arrived at 10.
        // Advancing to 120 must fire 0's completion, then 1's start at 100 —
        // in that order, so a start at `t` sees completions `<= t`.
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 100.0), (1, 5.0)]);
        sim.submit(flight(1, 0, 0.0, Priority::Standard));
        sim.submit(flight(2, 1, 10.0, Priority::Standard));
        sim.advance(120.0, &mut hooks);
        assert_eq!(hooks.completions.len(), 2, "105 <= 120: both completions fired");
        assert_eq!(hooks.starts.len(), 2);
        assert_eq!(hooks.starts[1], (1, 100.0));
        // Advance stops at `now`: nothing in the future fired.
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 100.0), (1, 5.0)]);
        sim.submit(flight(1, 0, 0.0, Priority::Standard));
        sim.submit(flight(2, 1, 10.0, Priority::Standard));
        sim.advance(99.0, &mut hooks);
        assert_eq!(hooks.starts.len(), 1, "flight 1's start at 100 is not due yet");
        assert!(hooks.completions.is_empty());
        assert_eq!(sim.next_event(), Some((100.0, true)), "completion wins the t=100 tie");
    }

    #[test]
    fn finished_flights_are_pruned_from_the_inflight_index() {
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 100.0)]);
        sim.submit(flight(7, 0, 0.0, Priority::Standard));
        sim.advance(0.0, &mut hooks);
        assert!(sim.is_running(Fingerprint(7)));
        assert_eq!(sim.in_flight(Fingerprint(7)), Some(100.0));
        // A long trace of probes after the completion must find nothing —
        // the old implementation kept every finished flight forever.
        sim.advance(100.0, &mut hooks);
        assert!(!sim.is_running(Fingerprint(7)), "pruned at its completion event");
        assert_eq!(sim.in_flight(Fingerprint(7)), None);
        assert_eq!(hooks.completions.len(), 1);
    }

    #[test]
    fn arena_slots_are_recycled_across_flights() {
        // Serve many more flights than the worker count: the arena must stay
        // bounded by peak concurrency (waiting + running), not trace length.
        // Service shorter than the interarrival gap, so the fleet keeps up
        // and peak concurrency stays at a couple of flights.
        let mut sim = FleetSim::new(2);
        let service: Vec<(u64, f64)> = (0..64).map(|i| (i, 0.5)).collect();
        let mut hooks = Script::new(&service);
        for i in 0..64u64 {
            sim.submit(flight(100 + i, i, i as f64, Priority::Standard));
            sim.advance(i as f64, &mut hooks);
        }
        sim.advance(f64::INFINITY, &mut hooks);
        assert_eq!(hooks.completions.len(), 64);
        assert_eq!(sim.flights_served(), 64);
        assert!(
            sim.flights.len() < 16,
            "arena grew to {} slots for 64 sequential flights",
            sim.flights.len()
        );
    }

    #[test]
    fn version_stamp_tracks_every_mutation() {
        let mut sim = FleetSim::new(1);
        let v0 = sim.version();
        sim.submit(flight(1, 0, 0.0, Priority::Standard));
        let v1 = sim.version();
        assert_ne!(v0, v1, "submit changes the next event");
        assert!(sim.join_waiting(Fingerprint(1), 1, 0.5, Priority::Interactive));
        let v2 = sim.version();
        assert_ne!(v1, v2, "a join can escalate priority / change membership");
        let mut hooks = Script::new(&[(0, 10.0)]);
        sim.advance(0.0, &mut hooks);
        assert_ne!(v2, sim.version(), "a fired start changes the next event");
        let v3 = sim.version();
        assert!(sim.join_running(Fingerprint(1), 2, 1.0));
        assert_ne!(v3, sim.version());
    }

    #[test]
    fn joins_escalate_priority_and_share_completion() {
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 100.0), (1, 10.0), (2, 10.0)]);
        sim.submit(flight(1, 0, 0.0, Priority::Standard));
        sim.advance(0.5, &mut hooks); // flight 0 starts; 1 and 2 arrive later
        sim.submit(flight(2, 1, 1.0, Priority::Batch));
        sim.submit(flight(3, 2, 2.0, Priority::Standard));
        assert_eq!(sim.depth(), 2);
        // An interactive join on the batch flight escalates it past seq 2.
        assert!(sim.join_waiting(Fingerprint(2), 3, 3.0, Priority::Interactive));
        assert!(!sim.join_waiting(Fingerprint(99), 4, 3.0, Priority::Batch));
        assert_eq!(sim.depth(), 2, "a join adds no new flight");

        sim.advance(f64::INFINITY, &mut hooks);
        assert_eq!(hooks.members[1], vec![1, 3], "follower rides the escalated flight");
        let order: Vec<u64> = hooks.starts.iter().map(|(s, _)| *s).collect();
        assert_eq!(order, vec![0, 1, 2], "escalated flight starts before seq 2");
    }

    fn tflight(fp: u64, seq: u64, tenant: usize, arrival_s: f64, p: Priority) -> SimFlight {
        SimFlight { tenant, ..flight(fp, seq, arrival_s, p) }
    }

    #[test]
    fn fair_dispatch_interleaves_tenants_within_a_class() {
        // Tenant 0 dumps four flights and tenant 1 two, all at t=0, equal
        // weights, one worker. Strict order would drain the hog first; the
        // deficit pick alternates until the light tenant's queue is empty.
        let service: Vec<(u64, f64)> = (0..6).map(|s| (s, 10.0)).collect();
        let submit_all = |sim: &mut FleetSim| {
            for seq in 0..4u64 {
                sim.submit(tflight(1 + seq, seq, 0, 0.0, Priority::Standard));
            }
            sim.submit(tflight(10, 4, 1, 0.0, Priority::Standard));
            sim.submit(tflight(11, 5, 1, 0.0, Priority::Standard));
        };
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&service);
        submit_all(&mut sim);
        sim.advance(f64::INFINITY, &mut hooks);
        let order: Vec<u64> = hooks.starts.iter().map(|(s, _)| *s).collect();
        assert_eq!(order, vec![0, 4, 1, 5, 2, 3], "tenants alternate under equal weights");
        // The snapshots carry the deficit arithmetic: tenant 1's first pick
        // won on a zero deficit while tenant 0 already owed 10s.
        assert_eq!(hooks.snapshots[1].0, 4);
        assert_eq!(hooks.snapshots[1].1.deficit_s, 0.0);
        assert_eq!(hooks.snapshots[1].1.weight, 1.0);

        // Fair dispatch off: the historical strict (priority, seq) order.
        let mut sim = FleetSim::new(1);
        sim.set_fair_dispatch(false);
        let mut hooks = Script::new(&service);
        submit_all(&mut sim);
        sim.advance(f64::INFINITY, &mut hooks);
        let order: Vec<u64> = hooks.starts.iter().map(|(s, _)| *s).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5], "strict order drains the hog first");
    }

    #[test]
    fn weights_bias_the_fair_share() {
        // Weight 3 vs 1: the heavy tenant accrues deficit a third as fast,
        // so it wins three starts for each of the light tenant's.
        let service: Vec<(u64, f64)> = (0..6).map(|s| (s, 10.0)).collect();
        let mut sim = FleetSim::new(1);
        sim.set_tenant_weights(&[3.0, 1.0]);
        let mut hooks = Script::new(&service);
        for seq in 0..3u64 {
            sim.submit(tflight(1 + seq, seq, 0, 0.0, Priority::Standard));
        }
        for seq in 3..6u64 {
            sim.submit(tflight(10 + seq, seq, 1, 0.0, Priority::Standard));
        }
        sim.advance(f64::INFINITY, &mut hooks);
        let order: Vec<u64> = hooks.starts.iter().map(|(s, _)| *s).collect();
        assert_eq!(order, vec![0, 3, 1, 2, 4, 5]);
        assert_eq!(hooks.snapshots[0].1.weight, 3.0);
        // Deficit is normalized: tenant 0's second start owed 10/3 seconds.
        let (seq, snap) = hooks.snapshots[2];
        assert_eq!(seq, 1);
        assert!((snap.deficit_s - 10.0 / 3.0).abs() < 1e-12, "{snap:?}");
    }

    #[test]
    fn priority_still_dominates_fair_dispatch() {
        // The hog tenant owes plenty of deficit, but its *interactive*
        // flight still beats the light tenant's standard one: the deficit
        // only arbitrates within a priority class.
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 50.0), (1, 10.0), (2, 10.0)]);
        sim.submit(tflight(1, 0, 0, 0.0, Priority::Standard));
        sim.advance(0.0, &mut hooks); // hog starts; deficit 50 charged
        sim.submit(tflight(2, 1, 0, 10.0, Priority::Interactive));
        sim.submit(tflight(3, 2, 1, 10.0, Priority::Standard));
        sim.advance(f64::INFINITY, &mut hooks);
        let order: Vec<u64> = hooks.starts.iter().map(|(s, _)| *s).collect();
        assert_eq!(order, vec![0, 1, 2], "interactive wins regardless of deficit");
    }

    #[test]
    fn idle_tenant_rejoins_at_the_virtual_clock_not_zero() {
        // Tenant 0 runs alone, advancing the virtual clock to 20s. When
        // tenant 1 shows up late its deficit clamps up to the clock — it
        // gets a fair share from now on, not a make-up monopoly.
        let service: Vec<(u64, f64)> = (0..7).map(|s| (s, 10.0)).collect();
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&service);
        for seq in 0..3u64 {
            sim.submit(tflight(1 + seq, seq, 0, 0.0, Priority::Standard));
        }
        sim.advance(f64::INFINITY, &mut hooks);
        assert_eq!(sim.tenant_deficit_s(0), 30.0);
        sim.submit(tflight(10, 3, 1, 100.0, Priority::Standard));
        sim.submit(tflight(11, 4, 1, 100.0, Priority::Standard));
        sim.submit(tflight(12, 5, 0, 100.0, Priority::Standard));
        sim.submit(tflight(13, 6, 0, 100.0, Priority::Standard));
        sim.advance(f64::INFINITY, &mut hooks);
        // Tenant 1's first pick was measured at the clamped deficit (the
        // virtual clock had reached 20), not at zero.
        let (seq, snap) = hooks.snapshots[3];
        assert_eq!(seq, 3);
        assert_eq!(snap.deficit_s, 20.0, "clamped to vtime, not the lifetime sum");
        // After its clamped start (20 → 30) it ties tenant 0's 30: the
        // lower tenant index breaks the tie, then they alternate.
        let tail: Vec<u64> = hooks.starts[3..].iter().map(|(s, _)| *s).collect();
        assert_eq!(tail, vec![3, 5, 4, 6]);
    }

    #[test]
    fn running_joins_ride_the_flight_to_its_completion() {
        let mut sim = FleetSim::new(1);
        let mut hooks = Script::new(&[(0, 100.0)]);
        sim.submit(flight(7, 0, 0.0, Priority::Standard));
        sim.advance(40.0, &mut hooks);
        assert!(sim.join_running(Fingerprint(7), 1, 40.0));
        assert!(!sim.join_running(Fingerprint(9), 2, 40.0));
        sim.advance(f64::INFINITY, &mut hooks);
        assert_eq!(hooks.members[0], vec![0, 1]);
        // Once completed, the fingerprint is joinable no more.
        assert!(!sim.join_running(Fingerprint(7), 3, 200.0));
    }
}
