//! Synthetic traffic traces: Zipf-distributed task popularity over the
//! KernelBench-sim suite, a skewed GPU mix, and a priority mix.
//!
//! Production kernel-optimization traffic is heavy-tailed — a few operators
//! (attention, GEMM epilogues, softmax variants) dominate while a long tail
//! trickles — which is exactly the regime where a result cache pays for
//! itself. The trace is fully determined by its seed.

use crate::gpu::{self, GpuSpec};
use crate::service::queue::{Priority, ALL_PRIORITIES};
use crate::util::rng::Rng;

/// Trace shape parameters.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    pub requests: usize,
    /// Zipf exponent s (popularity of the k-th task ∝ k^-s).
    pub zipf_s: f64,
    pub seed: u64,
    /// `(gpu key, weight)` — most traffic targets the default part, a
    /// minority targets others (the cross-GPU warm-start opportunity).
    pub gpu_mix: Vec<(&'static str, f64)>,
    /// Weights for [interactive, standard, batch].
    pub priority_mix: [f64; 3],
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            requests: 2000,
            zipf_s: 1.1,
            seed: 7,
            gpu_mix: vec![
                ("rtx6000", 0.85),
                ("a100", 0.05),
                ("rtx4090", 0.05),
                ("h100", 0.05),
            ],
            priority_mix: [0.2, 0.6, 0.2],
        }
    }
}

/// One arriving request: an index into the caller's task set, a target GPU,
/// and an urgency class.
#[derive(Clone, Copy, Debug)]
pub struct TrafficRequest {
    pub task_index: usize,
    pub gpu: &'static GpuSpec,
    pub priority: Priority,
}

/// Generate a trace over a task set of `n_tasks`. Popularity rank is mapped
/// onto task indices through a seeded shuffle, so *which* tasks are hot
/// varies with the seed while the rank-frequency law does not.
pub fn generate(n_tasks: usize, cfg: &TrafficConfig) -> Vec<TrafficRequest> {
    assert!(n_tasks > 0, "traffic needs a task set");
    let mut rng = Rng::new(cfg.seed ^ 0x7261_6666_6963_u64);

    // rank -> task index
    let mut perm: Vec<usize> = (0..n_tasks).collect();
    rng.shuffle(&mut perm);
    let zipf_weights: Vec<f64> =
        (1..=n_tasks).map(|k| (k as f64).powf(-cfg.zipf_s)).collect();

    let gpus: Vec<&'static GpuSpec> = cfg
        .gpu_mix
        .iter()
        .map(|(key, _)| gpu::by_key(key).unwrap_or_else(|| panic!("unknown gpu {key}")))
        .collect();
    let gpu_weights: Vec<f64> = cfg.gpu_mix.iter().map(|(_, w)| *w).collect();

    (0..cfg.requests)
        .map(|_| {
            let rank = rng.weighted_choice(&zipf_weights);
            let g = rng.weighted_choice(&gpu_weights);
            let p = rng.weighted_choice(&cfg.priority_mix);
            TrafficRequest {
                task_index: perm[rank],
                gpu: gpus[g],
                priority: ALL_PRIORITIES[p],
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = TrafficConfig { requests: 200, ..TrafficConfig::default() };
        let a = generate(250, &cfg);
        let b = generate(250, &cfg);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.task_index, y.task_index);
            assert_eq!(x.gpu.key, y.gpu.key);
            assert_eq!(x.priority, y.priority);
        }
        let c = generate(250, &TrafficConfig { seed: 8, ..cfg });
        assert!(a.iter().zip(&c).any(|(x, y)| x.task_index != y.task_index));
    }

    #[test]
    fn zipf_trace_is_heavy_tailed() {
        let cfg = TrafficConfig { requests: 2000, ..TrafficConfig::default() };
        let trace = generate(250, &cfg);
        let mut counts = vec![0usize; 250];
        for r in &trace {
            counts[r.task_index] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // The hottest task should dwarf the median task.
        assert!(counts[0] > 100, "head count {}", counts[0]);
        assert!(counts[0] > counts[125].max(1) * 10);
        // And repeats dominate: far fewer distinct tasks than requests.
        let distinct = counts.iter().filter(|c| **c > 0).count();
        assert!(distinct < 250, "some tail tasks never arrive");
    }

    #[test]
    fn gpu_mix_respected() {
        let cfg = TrafficConfig { requests: 2000, ..TrafficConfig::default() };
        let trace = generate(250, &cfg);
        let default_share = trace.iter().filter(|r| r.gpu.key == "rtx6000").count() as f64
            / trace.len() as f64;
        assert!((0.8..0.9).contains(&default_share), "share {default_share}");
        assert!(trace.iter().any(|r| r.gpu.key != "rtx6000"));
    }
}
