//! Synthetic traffic traces: Zipf-distributed task popularity over the
//! KernelBench-sim suite, a skewed GPU mix, a priority mix, a tenant mix,
//! and Poisson arrival times.
//!
//! Production kernel-optimization traffic is heavy-tailed — a few operators
//! (attention, GEMM epilogues, softmax variants) dominate while a long tail
//! trickles — which is exactly the regime where a result cache pays for
//! itself. Each request also carries a simulated arrival instant (exponential
//! interarrival gaps, i.e. a Poisson process), which is what lets the service
//! layer's discrete-event simulator charge queueing delay instead of bare
//! service time, and a tenant index, which is what lets the cluster layer
//! enforce per-tenant quotas. The trace is fully determined by its seed.
//!
//! Tenant draws come from a *separate* RNG stream derived from the seed, so
//! adding or reshaping `tenant_mix` never perturbs which tasks, GPUs, or
//! priorities a given seed produces — single-node replays stay byte-stable
//! under multi-tenant reconfiguration.

use anyhow::{bail, Result};

use crate::gpu::{self, GpuSpec};
use crate::service::queue::{Priority, ALL_PRIORITIES};
use crate::util::rng::Rng;

/// Trace shape parameters.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    /// Requests to generate.
    pub requests: usize,
    /// Zipf exponent s (popularity of the k-th task ∝ k^-s).
    pub zipf_s: f64,
    /// RNG seed — the trace is fully determined by it.
    pub seed: u64,
    /// Mean gap between consecutive arrivals, in simulated seconds
    /// (exponentially distributed). 0 models a single burst at t = 0.
    pub mean_interarrival_s: f64,
    /// `(gpu key, weight)` — most traffic targets the default part, a
    /// minority targets others (the cross-GPU warm-start opportunity).
    pub gpu_mix: Vec<(&'static str, f64)>,
    /// Weights for [interactive, standard, batch].
    pub priority_mix: [f64; 3],
    /// `(tenant name, weight)` — who is asking. Index `i` of this list is
    /// the `TrafficRequest::tenant` it produces; the cluster layer maps the
    /// same indices onto its `TenantSpec` list. A single-entry mix models
    /// the pre-cluster single-tenant world.
    pub tenant_mix: Vec<(String, f64)>,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            requests: 2000,
            zipf_s: 1.1,
            seed: 7,
            mean_interarrival_s: 90.0,
            gpu_mix: vec![
                ("rtx6000", 0.85),
                ("a100", 0.05),
                ("rtx4090", 0.05),
                ("h100", 0.05),
            ],
            priority_mix: [0.2, 0.6, 0.2],
            tenant_mix: vec![("default".to_string(), 1.0)],
        }
    }
}

impl TrafficConfig {
    /// Reject shapes the weighted samplers cannot draw from: negative or
    /// non-finite weights, and mixes whose weights sum to zero.
    pub fn validate(&self) -> Result<()> {
        if !self.zipf_s.is_finite() {
            bail!("traffic config: zipf_s must be finite, got {}", self.zipf_s);
        }
        if !(self.mean_interarrival_s.is_finite() && self.mean_interarrival_s >= 0.0) {
            bail!(
                "traffic config: mean_interarrival_s must be finite and >= 0, got {}",
                self.mean_interarrival_s
            );
        }
        if self.gpu_mix.is_empty() {
            bail!("traffic config: gpu_mix must name at least one GPU");
        }
        for (key, w) in &self.gpu_mix {
            if !(w.is_finite() && *w >= 0.0) {
                bail!("traffic config: gpu_mix weight for '{key}' must be finite and >= 0, got {w}");
            }
        }
        if self.gpu_mix.iter().map(|(_, w)| *w).sum::<f64>() <= 0.0 {
            bail!("traffic config: gpu_mix weights sum to zero — no GPU can be drawn");
        }
        for (p, w) in ALL_PRIORITIES.iter().zip(&self.priority_mix) {
            if !(w.is_finite() && *w >= 0.0) {
                bail!(
                    "traffic config: priority_mix weight for '{}' must be finite and >= 0, got {w}",
                    p.name()
                );
            }
        }
        if self.priority_mix.iter().sum::<f64>() <= 0.0 {
            bail!("traffic config: priority_mix weights sum to zero — no class can be drawn");
        }
        if self.tenant_mix.is_empty() {
            bail!("traffic config: tenant_mix must name at least one tenant");
        }
        for (name, w) in &self.tenant_mix {
            if !(w.is_finite() && *w >= 0.0) {
                bail!(
                    "traffic config: tenant_mix weight for '{name}' must be finite and >= 0, got {w}"
                );
            }
        }
        if self.tenant_mix.iter().map(|(_, w)| *w).sum::<f64>() <= 0.0 {
            bail!("traffic config: tenant_mix weights sum to zero — no tenant can be drawn");
        }
        Ok(())
    }
}

/// One arriving request: an index into the caller's task set, a target GPU,
/// an urgency class, a tenant, and the simulated instant it arrives.
#[derive(Clone, Copy, Debug)]
pub struct TrafficRequest {
    /// Index into the caller's task set.
    pub task_index: usize,
    /// Target GPU the kernel must be optimized for.
    pub gpu: &'static GpuSpec,
    /// Urgency class (admission and SLO scoring key off it).
    pub priority: Priority,
    /// Index into the trace's `tenant_mix` (and the cluster's tenant list).
    /// Single-node replays ignore it; the cluster layer meters quotas by it.
    pub tenant: usize,
    /// Simulated arrival time in seconds from trace start (nondecreasing).
    pub arrival_s: f64,
}

/// Generate a trace over a task set of `n_tasks`, or explain why the config
/// cannot produce one. Popularity rank is mapped onto task indices through a
/// seeded shuffle, so *which* tasks are hot varies with the seed while the
/// rank-frequency law does not.
pub fn try_generate(n_tasks: usize, cfg: &TrafficConfig) -> Result<Vec<TrafficRequest>> {
    if n_tasks == 0 {
        bail!("traffic needs a task set");
    }
    cfg.validate()?;
    let mut rng = Rng::new(cfg.seed ^ 0x7261_6666_6963_u64);
    // Tenants draw from their own stream: reshaping the tenant mix must not
    // shift the task/GPU/priority/arrival draws of an existing seed.
    let mut tenant_rng = Rng::new(cfg.seed ^ 0x7465_6e61_6e74_u64);

    // rank -> task index
    let mut perm: Vec<usize> = (0..n_tasks).collect();
    rng.shuffle(&mut perm);
    let zipf_weights: Vec<f64> =
        (1..=n_tasks).map(|k| (k as f64).powf(-cfg.zipf_s)).collect();
    // A strongly negative exponent overflows k^-s to +inf, which would
    // silently degenerate the weighted sampler instead of erroring.
    if !zipf_weights.iter().all(|w| w.is_finite()) {
        bail!(
            "traffic config: zipf_s = {} overflows the rank weights for {n_tasks} tasks",
            cfg.zipf_s
        );
    }

    let mut gpus: Vec<&'static GpuSpec> = Vec::with_capacity(cfg.gpu_mix.len());
    for (key, _) in &cfg.gpu_mix {
        match gpu::by_key(key) {
            Some(g) => gpus.push(g),
            None => bail!("traffic config: unknown gpu '{key}' in gpu_mix"),
        }
    }
    let gpu_weights: Vec<f64> = cfg.gpu_mix.iter().map(|(_, w)| *w).collect();
    let tenant_weights: Vec<f64> = cfg.tenant_mix.iter().map(|(_, w)| *w).collect();

    let mut clock_s = 0.0f64;
    Ok((0..cfg.requests)
        .map(|_| {
            let rank = rng.weighted_choice(&zipf_weights);
            let g = rng.weighted_choice(&gpu_weights);
            let p = rng.weighted_choice(&cfg.priority_mix);
            let t = tenant_rng.weighted_choice(&tenant_weights);
            // Exponential interarrival gap (Poisson arrivals). `1 - f64()` is
            // in (0, 1], so the log is finite.
            clock_s += -cfg.mean_interarrival_s * (1.0 - rng.f64()).ln();
            TrafficRequest {
                task_index: perm[rank],
                gpu: gpus[g],
                priority: ALL_PRIORITIES[p],
                tenant: t,
                arrival_s: clock_s,
            }
        })
        .collect())
}

/// Generate a trace, panicking on an invalid config (tests and examples; the
/// CLI goes through [`try_generate`] for a clean exit).
pub fn generate(n_tasks: usize, cfg: &TrafficConfig) -> Vec<TrafficRequest> {
    try_generate(n_tasks, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = TrafficConfig { requests: 200, ..TrafficConfig::default() };
        let a = generate(250, &cfg);
        let b = generate(250, &cfg);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.task_index, y.task_index);
            assert_eq!(x.gpu.key, y.gpu.key);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
        }
        let c = generate(250, &TrafficConfig { seed: 8, ..cfg });
        assert!(a.iter().zip(&c).any(|(x, y)| x.task_index != y.task_index));
    }

    #[test]
    fn zipf_trace_is_heavy_tailed() {
        let cfg = TrafficConfig { requests: 2000, ..TrafficConfig::default() };
        let trace = generate(250, &cfg);
        let mut counts = vec![0usize; 250];
        for r in &trace {
            counts[r.task_index] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // The hottest task should dwarf the median task.
        assert!(counts[0] > 100, "head count {}", counts[0]);
        assert!(counts[0] > counts[125].max(1) * 10);
        // And repeats dominate: far fewer distinct tasks than requests.
        let distinct = counts.iter().filter(|c| **c > 0).count();
        assert!(distinct < 250, "some tail tasks never arrive");
    }

    #[test]
    fn gpu_mix_respected() {
        let cfg = TrafficConfig { requests: 2000, ..TrafficConfig::default() };
        let trace = generate(250, &cfg);
        let default_share = trace.iter().filter(|r| r.gpu.key == "rtx6000").count() as f64
            / trace.len() as f64;
        assert!((0.8..0.9).contains(&default_share), "share {default_share}");
        assert!(trace.iter().any(|r| r.gpu.key != "rtx6000"));
    }

    #[test]
    fn arrivals_are_nondecreasing_with_the_configured_mean() {
        let cfg = TrafficConfig { requests: 2000, ..TrafficConfig::default() };
        let trace = generate(250, &cfg);
        for pair in trace.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
        let span = trace.last().unwrap().arrival_s;
        let mean_gap = span / trace.len() as f64;
        assert!(
            (mean_gap - cfg.mean_interarrival_s).abs() < cfg.mean_interarrival_s * 0.1,
            "mean gap {mean_gap} vs configured {}",
            cfg.mean_interarrival_s
        );
        // A zero mean models one burst at t = 0.
        let burst = generate(
            250,
            &TrafficConfig { mean_interarrival_s: 0.0, requests: 50, ..TrafficConfig::default() },
        );
        assert!(burst.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn tenant_mix_is_respected_and_does_not_perturb_other_draws() {
        let single = TrafficConfig { requests: 1000, ..TrafficConfig::default() };
        let base = generate(250, &single);
        assert!(base.iter().all(|r| r.tenant == 0), "default mix is one tenant");

        let multi = TrafficConfig {
            requests: 1000,
            tenant_mix: vec![
                ("alpha".to_string(), 3.0),
                ("beta".to_string(), 1.0),
            ],
            ..TrafficConfig::default()
        };
        let trace = generate(250, &multi);
        let alpha = trace.iter().filter(|r| r.tenant == 0).count() as f64
            / trace.len() as f64;
        assert!((0.68..0.82).contains(&alpha), "alpha share {alpha}");
        assert!(trace.iter().any(|r| r.tenant == 1));
        // The tenant stream is independent: every non-tenant draw of the
        // seed is byte-identical to the single-tenant trace.
        for (x, y) in base.iter().zip(&trace) {
            assert_eq!(x.task_index, y.task_index);
            assert_eq!(x.gpu.key, y.gpu.key);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
        }
    }

    #[test]
    fn invalid_mixes_are_rejected_with_clear_errors() {
        let negative = TrafficConfig {
            gpu_mix: vec![("rtx6000", -1.0)],
            ..TrafficConfig::default()
        };
        let err = try_generate(10, &negative).unwrap_err().to_string();
        assert!(err.contains("gpu_mix") && err.contains("rtx6000"), "{err}");

        let zero_sum = TrafficConfig {
            gpu_mix: vec![("rtx6000", 0.0), ("a100", 0.0)],
            ..TrafficConfig::default()
        };
        let err = try_generate(10, &zero_sum).unwrap_err().to_string();
        assert!(err.contains("sum to zero"), "{err}");

        let bad_priority = TrafficConfig {
            priority_mix: [0.0, 0.0, 0.0],
            ..TrafficConfig::default()
        };
        let err = try_generate(10, &bad_priority).unwrap_err().to_string();
        assert!(err.contains("priority_mix"), "{err}");

        let nan_priority = TrafficConfig {
            priority_mix: [f64::NAN, 1.0, 1.0],
            ..TrafficConfig::default()
        };
        let err = try_generate(10, &nan_priority).unwrap_err().to_string();
        assert!(err.contains("interactive"), "{err}");

        let unknown_gpu = TrafficConfig {
            gpu_mix: vec![("tpu9000", 1.0)],
            ..TrafficConfig::default()
        };
        let err = try_generate(10, &unknown_gpu).unwrap_err().to_string();
        assert!(err.contains("tpu9000"), "{err}");

        let zero_tenants = TrafficConfig { tenant_mix: vec![], ..TrafficConfig::default() };
        let err = try_generate(10, &zero_tenants).unwrap_err().to_string();
        assert!(err.contains("tenant_mix"), "{err}");

        let bad_tenant = TrafficConfig {
            tenant_mix: vec![("alpha".to_string(), -2.0)],
            ..TrafficConfig::default()
        };
        let err = try_generate(10, &bad_tenant).unwrap_err().to_string();
        assert!(err.contains("tenant_mix") && err.contains("alpha"), "{err}");

        let nan_zipf = TrafficConfig { zipf_s: f64::NAN, ..TrafficConfig::default() };
        let err = try_generate(10, &nan_zipf).unwrap_err().to_string();
        assert!(err.contains("zipf_s"), "{err}");

        // 250^130 > f64::MAX: the rank weights would be +inf.
        let inv_zipf = TrafficConfig { zipf_s: -130.0, ..TrafficConfig::default() };
        let err = try_generate(250, &inv_zipf).unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");

        assert!(try_generate(0, &TrafficConfig::default()).is_err());
    }
}
