//! Content-addressed result cache with LRU eviction and JSONL persistence.
//!
//! Maps a request `Fingerprint` to the best kernel a workflow run found for
//! it, plus the cost ledger of that run — enough to (i) answer a repeat
//! request without touching the agents, (ii) price what the hit *saved*, and
//! (iii) seed a warm start for the same task on a different GPU.
//!
//! Internals are `BTreeMap`-based on purpose: every scan (warm-candidate
//! lookup, snapshotting) iterates in a total order, so service replays are
//! bit-deterministic regardless of insertion history or hash seeds. Recency
//! is a monotonic tick plus a tick->fingerprint index, so the admission-path
//! operations (get / insert / evict) are all O(log n), never O(capacity).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::kernel::KernelConfig;
use crate::service::fingerprint::Fingerprint;
use crate::util::json::Json;
use crate::workflow::TaskResult;

/// Snapshot wire-format version, written as the first JSONL line and
/// required by `restore`. Fingerprints are stored literally, so this must
/// be bumped whenever the `fingerprint` hashing scheme changes — a restore
/// against an incompatible scheme then fails loudly instead of silently
/// never hitting. v2: length-prefixed `FieldHasher` fields.
pub const SNAPSHOT_VERSION: u32 = 2;

/// One cached optimization result.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheEntry {
    /// Content address of the request this entry answers.
    pub fingerprint: Fingerprint,
    /// Task identifier (e.g. `L1-95`) — the warm-candidate scan matches it.
    pub task_id: String,
    /// GPU the producing run tuned on.
    pub gpu_key: String,
    /// Strategy name of the producing run.
    pub strategy: String,
    /// Coder model name of the producing run.
    pub coder: String,
    /// Judge model name of the producing run.
    pub judge: String,
    /// Best speedup the producing run measured.
    pub best_speedup: f64,
    /// The best kernel configuration found — what a warm start seeds from.
    pub best_config: KernelConfig,
    /// API dollars the producing run actually spent (a warm-started run
    /// spends less than a cold one).
    pub api_usd: f64,
    /// What a *cold* run of this fingerprint costs — the counterfactual a
    /// hit avoids. For cold runs this equals `api_usd`; warm-started runs
    /// inherit it from their warm-start source.
    pub cold_api_usd: f64,
    /// Wall seconds the producing run took — what a hit avoids re-waiting.
    pub wall_s: f64,
    /// Round at which the producing run first measured its best kernel.
    pub rounds_to_best: usize,
}

impl CacheEntry {
    /// Assemble the entry a flight's completed run refills the cache with —
    /// `None` when the run produced nothing cacheable (never correct, or no
    /// best config survived). Shared by the single-node and cluster replay
    /// loops via `service::settle_flight_completion`, so both layers cache
    /// byte-identical entries for the same run.
    #[allow(clippy::too_many_arguments)]
    pub fn from_run(
        fingerprint: Fingerprint,
        task_id: String,
        gpu_key: &str,
        strategy: &str,
        coder: &str,
        judge: &str,
        result: &TaskResult,
        cold_api_usd: f64,
    ) -> Option<CacheEntry> {
        if !result.correct {
            return None;
        }
        let best_config = result.best_config.clone()?;
        Some(CacheEntry {
            fingerprint,
            task_id,
            gpu_key: gpu_key.to_string(),
            strategy: strategy.to_string(),
            coder: coder.to_string(),
            judge: judge.to_string(),
            best_speedup: result.best_speedup,
            best_config,
            api_usd: result.ledger.api_usd,
            cold_api_usd,
            wall_s: result.ledger.wall_s,
            rounds_to_best: result.rounds_to_best().unwrap_or(0),
        })
    }

    /// Serialize as one snapshot JSONL line.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fingerprint", Json::str(self.fingerprint.to_string())),
            ("task_id", Json::str(self.task_id.clone())),
            ("gpu_key", Json::str(self.gpu_key.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("coder", Json::str(self.coder.clone())),
            ("judge", Json::str(self.judge.clone())),
            ("best_speedup", Json::num(self.best_speedup)),
            ("best_config", self.best_config.to_json()),
            ("api_usd", Json::num(self.api_usd)),
            ("cold_api_usd", Json::num(self.cold_api_usd)),
            ("wall_s", Json::num(self.wall_s)),
            ("rounds_to_best", Json::num(self.rounds_to_best as f64)),
        ])
    }

    /// Parse a snapshot JSONL line (`None` when fields are missing or
    /// malformed).
    pub fn from_json(v: &Json) -> Option<CacheEntry> {
        Some(CacheEntry {
            fingerprint: Fingerprint::parse(v.get("fingerprint")?.as_str()?)?,
            task_id: v.get("task_id")?.as_str()?.to_string(),
            gpu_key: v.get("gpu_key")?.as_str()?.to_string(),
            strategy: v.get("strategy")?.as_str()?.to_string(),
            coder: v.get("coder")?.as_str()?.to_string(),
            judge: v.get("judge")?.as_str()?.to_string(),
            best_speedup: v.get("best_speedup")?.as_f64()?,
            best_config: KernelConfig::from_json(v.get("best_config")?)?,
            api_usd: v.get("api_usd")?.as_f64()?,
            cold_api_usd: v.get("cold_api_usd")?.as_f64()?,
            wall_s: v.get("wall_s")?.as_f64()?,
            rounds_to_best: v.get("rounds_to_best")?.as_usize()?,
        })
    }
}

/// Hit/miss/eviction counters (monotonic over the cache's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups that found a resident entry.
    pub hits: u64,
    /// Lookups that found nothing.
    pub misses: u64,
    /// Entries inserted (including refreshes of resident keys).
    pub inserts: u64,
    /// Entries dropped by LRU capacity pressure (migrations via
    /// [`ResultCache::remove`] do not count).
    pub evictions: u64,
}

struct Slot {
    entry: CacheEntry,
    tick: u64,
}

/// Re-tick a resident slot to most-recently-used. Free function over the
/// disjoint fields so `get`/`insert` can call it while holding the map's
/// `&mut Slot` — the recency index and the slot must move together or LRU
/// eviction order corrupts.
fn retick(
    tick: &mut u64,
    recency: &mut BTreeMap<u64, Fingerprint>,
    slot: &mut Slot,
    fp: Fingerprint,
) {
    *tick += 1;
    recency.remove(&slot.tick);
    slot.tick = *tick;
    recency.insert(*tick, fp);
}

/// Bounded content-addressed cache, least-recently-used eviction.
pub struct ResultCache {
    capacity: usize,
    map: BTreeMap<Fingerprint, Slot>,
    /// tick -> fingerprint; ticks are unique, so the first key is the LRU.
    recency: BTreeMap<u64, Fingerprint>,
    tick: u64,
    /// Lifetime hit/miss/insert/eviction counters. Replay loops report
    /// *deltas* against a snapshot of this taken at replay start.
    pub stats: CacheStats,
}

impl ResultCache {
    /// `capacity` is clamped to at least 1.
    pub fn new(capacity: usize) -> ResultCache {
        ResultCache {
            capacity: capacity.max(1),
            map: BTreeMap::new(),
            recency: BTreeMap::new(),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The entry budget evictions enforce.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lookup, counting a hit or miss and refreshing recency on hit. One
    /// map probe: the slot found by `get_mut` is re-ticked in place.
    pub fn get(&mut self, fp: Fingerprint) -> Option<&CacheEntry> {
        match self.map.get_mut(&fp) {
            Some(slot) => {
                self.stats.hits += 1;
                retick(&mut self.tick, &mut self.recency, slot, fp);
                Some(&slot.entry)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Lookup without touching recency or counters (introspection).
    pub fn peek(&self, fp: Fingerprint) -> Option<&CacheEntry> {
        self.map.get(&fp).map(|s| &s.entry)
    }

    /// Insert (or refresh) an entry, evicting the LRU entry when full.
    /// Returns the evicted fingerprint, if the insert displaced one —
    /// the flight recorder names evictions with it.
    pub fn insert(&mut self, entry: CacheEntry) -> Option<Fingerprint> {
        let fp = entry.fingerprint;
        self.stats.inserts += 1;
        if let Some(slot) = self.map.get_mut(&fp) {
            slot.entry = entry;
            retick(&mut self.tick, &mut self.recency, slot, fp);
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.capacity {
            if let Some((_, cold)) = self.recency.pop_first() {
                self.map.remove(&cold);
                self.stats.evictions += 1;
                evicted = Some(cold);
            }
        }
        self.tick += 1;
        self.recency.insert(self.tick, fp);
        self.map.insert(fp, Slot { entry, tick: self.tick });
        evicted
    }

    /// Remove and return the entry for `fp`, if resident. This is a
    /// *migration*, not an eviction — the cluster layer's planned rebalance
    /// moves an entry to the shard that now owns its key — so the eviction
    /// counter is untouched and recency bookkeeping is simply dropped with
    /// the slot.
    pub fn remove(&mut self, fp: Fingerprint) -> Option<CacheEntry> {
        let slot = self.map.remove(&fp)?;
        self.recency.remove(&slot.tick);
        Some(slot.entry)
    }

    /// Best cross-GPU transfer candidate: a cached correct kernel for the
    /// same task / strategy / models, tuned on a *different* GPU. Ties break
    /// on (speedup, fingerprint) so the scan is order-independent.
    pub fn warm_candidate(
        &self,
        task_id: &str,
        gpu_key: &str,
        strategy: &str,
        coder: &str,
        judge: &str,
    ) -> Option<&CacheEntry> {
        self.map
            .values()
            .map(|s| &s.entry)
            .filter(|e| {
                e.task_id == task_id
                    && e.gpu_key != gpu_key
                    && e.strategy == strategy
                    && e.coder == coder
                    && e.judge == judge
                    && e.best_speedup > 0.0
            })
            .max_by(|a, b| {
                // total_cmp: a NaN speedup (already excluded by the filter,
                // but snapshots are external input) must never panic a scan.
                a.best_speedup
                    .total_cmp(&b.best_speedup)
                    .then_with(|| a.fingerprint.cmp(&b.fingerprint))
            })
    }

    /// Entries coldest-first (the order `snapshot` writes and `restore`
    /// replays, so recency survives a round trip).
    pub fn entries_coldest_first(&self) -> impl Iterator<Item = &CacheEntry> {
        self.recency
            .values()
            .filter_map(|fp| self.map.get(fp).map(|s| &s.entry))
    }

    /// Write the cache as JSONL: a version header, then one entry per line,
    /// coldest first.
    pub fn snapshot(&self, path: impl AsRef<Path>) -> Result<()> {
        self.snapshot_with_header(path, Vec::new())
    }

    /// [`ResultCache::snapshot`], with extra fields merged into the header
    /// line next to `snapshot_version`. The cluster layer stamps each shard
    /// file with its rendezvous epoch, shard index, and node count so a
    /// restore can cross-check the manifest against the files it names;
    /// [`ResultCache::restore`] itself ignores unknown header fields.
    pub fn snapshot_with_header(
        &self,
        path: impl AsRef<Path>,
        extra: Vec<(&str, Json)>,
    ) -> Result<()> {
        let mut header = vec![("snapshot_version", Json::num(SNAPSHOT_VERSION as f64))];
        header.extend(extra);
        let mut out = Json::obj(header).to_string();
        out.push('\n');
        for e in self.entries_coldest_first() {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        std::fs::write(path.as_ref(), out)
            .with_context(|| format!("writing snapshot {}", path.as_ref().display()))
    }

    /// Rebuild a cache from a JSONL snapshot. The first line must carry a
    /// matching [`SNAPSHOT_VERSION`]; entry lines are inserted in file
    /// order, so the snapshot's recency (and its eviction decisions, if the
    /// new capacity is smaller) is reproduced; evictions forced by a smaller
    /// capacity stay on the counter — they are real capacity decisions —
    /// while the hit/miss/insert churn of the rebuild is reset. Malformed
    /// lines are an error: a warm restart from a corrupt snapshot should
    /// fail loudly, not serve half a cache.
    pub fn restore(path: impl AsRef<Path>, capacity: usize) -> Result<ResultCache> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading snapshot {}", path.as_ref().display()))?;
        Self::restore_from_str(&text, capacity, path.as_ref())
    }

    /// [`ResultCache::restore`] over snapshot text already in memory —
    /// `origin` names the source file in errors. The cluster loader uses
    /// this to rebuild each shard from the one read its manifest
    /// cross-checks already made.
    pub fn restore_from_str(text: &str, capacity: usize, origin: &Path) -> Result<ResultCache> {
        let path = origin;
        let mut cache = ResultCache::new(capacity);
        let mut saw_header = false;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line).map_err(|e| {
                anyhow!("snapshot {} line {}: {e}", path.display(), i + 1)
            })?;
            if !saw_header {
                // The first line must declare a compatible fingerprint
                // scheme; a version-less snapshot was written by a build
                // whose fingerprints no longer match anything. Both
                // diagnoses carry the offending path so the operator knows
                // *which* file to delete.
                match v.get("snapshot_version").and_then(|x| x.as_f64()) {
                    Some(x) if x == SNAPSHOT_VERSION as f64 => {
                        saw_header = true;
                        continue;
                    }
                    Some(x) => bail!(
                        "snapshot {} has version {x} unsupported by this build \
                         (which reads {SNAPSHOT_VERSION}) — delete the snapshot \
                         and re-warm",
                        path.display()
                    ),
                    None => bail!(
                        "snapshot {} has no version header (written before the \
                         v{SNAPSHOT_VERSION} fingerprint scheme) — delete the \
                         snapshot and re-warm",
                        path.display()
                    ),
                }
            }
            let entry = CacheEntry::from_json(&v).ok_or_else(|| {
                anyhow!(
                    "snapshot {} line {}: missing fields",
                    path.display(),
                    i + 1
                )
            })?;
            cache.insert(entry);
        }
        if !saw_header {
            bail!(
                "snapshot {} is empty or missing its version header",
                path.display()
            );
        }
        // Restoring is not traffic: don't let the rebuild pollute the
        // hit/miss/insert counters. Evictions survive — a snapshot squeezed
        // into a smaller cache really did drop entries.
        cache.stats = CacheStats { evictions: cache.stats.evictions, ..CacheStats::default() };
        Ok(cache)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn entry(fp: u64, task: &str, gpu: &str, speedup: f64) -> CacheEntry {
        CacheEntry {
            fingerprint: Fingerprint(fp),
            task_id: task.to_string(),
            gpu_key: gpu.to_string(),
            strategy: "CudaForge".to_string(),
            coder: "OpenAI-o3".to_string(),
            judge: "OpenAI-o3".to_string(),
            best_speedup: speedup,
            best_config: KernelConfig::naive(),
            api_usd: 0.30,
            cold_api_usd: 0.30,
            wall_s: 1590.0,
            rounds_to_best: 6,
        }
    }

    #[test]
    fn hit_miss_counters() {
        let mut c = ResultCache::new(4);
        assert!(c.get(Fingerprint(1)).is_none());
        c.insert(entry(1, "L1-1", "rtx6000", 1.5));
        assert!(c.get(Fingerprint(1)).is_some());
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.inserts, 1);
    }

    #[test]
    fn lru_evicts_coldest_and_get_refreshes() {
        let mut c = ResultCache::new(2);
        c.insert(entry(1, "L1-1", "rtx6000", 1.0));
        c.insert(entry(2, "L1-2", "rtx6000", 1.0));
        // touch 1 so 2 becomes coldest
        assert!(c.get(Fingerprint(1)).is_some());
        c.insert(entry(3, "L1-3", "rtx6000", 1.0));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats.evictions, 1);
        assert!(c.peek(Fingerprint(2)).is_none(), "2 was LRU");
        assert!(c.peek(Fingerprint(1)).is_some());
        assert!(c.peek(Fingerprint(3)).is_some());
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c = ResultCache::new(2);
        c.insert(entry(1, "L1-1", "rtx6000", 1.0));
        c.insert(entry(2, "L1-2", "rtx6000", 1.0));
        c.insert(entry(1, "L1-1", "rtx6000", 2.0)); // refresh, not a new key
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats.evictions, 0);
        assert_eq!(c.peek(Fingerprint(1)).unwrap().best_speedup, 2.0);
        // now 2 is coldest
        c.insert(entry(3, "L1-3", "rtx6000", 1.0));
        assert!(c.peek(Fingerprint(2)).is_none());
    }

    #[test]
    fn remove_is_a_migration_not_an_eviction() {
        let mut c = ResultCache::new(2);
        c.insert(entry(1, "L1-1", "rtx6000", 1.0));
        c.insert(entry(2, "L1-2", "rtx6000", 1.0));
        let taken = c.remove(Fingerprint(1)).expect("resident");
        assert_eq!(taken.fingerprint, Fingerprint(1));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats.evictions, 0, "migration must not count as eviction");
        assert!(c.remove(Fingerprint(1)).is_none(), "already gone");
        // The freed slot is genuinely free: two inserts fit without evicting
        // (the removed entry's recency bookkeeping left with it).
        c.insert(entry(3, "L1-3", "rtx6000", 1.0));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats.evictions, 0);
        c.insert(entry(4, "L1-4", "rtx6000", 1.0));
        assert_eq!(c.stats.evictions, 1, "capacity pressure still evicts LRU");
        assert!(c.peek(Fingerprint(2)).is_none(), "2 was coldest");
    }

    #[test]
    fn header_extras_round_trip_and_are_ignored_by_restore() {
        let dir = std::env::temp_dir().join("cudaforge_cache_header_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stamped.jsonl");
        let mut c = ResultCache::new(4);
        c.insert(entry(1, "L1-1", "rtx6000", 1.1));
        c.snapshot_with_header(
            &path,
            vec![("epoch", Json::num(3.0)), ("shard", Json::num(1.0))],
        )
        .unwrap();
        // The stamped fields are on the header line…
        let text = std::fs::read_to_string(&path).unwrap();
        let header = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(header.get("epoch").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(header.get("shard").and_then(|v| v.as_f64()), Some(1.0));
        // …and a plain restore still succeeds, ignoring them.
        let r = ResultCache::restore(&path, 4).unwrap();
        assert_eq!(r.len(), 1);
        assert!(r.peek(Fingerprint(1)).is_some());
    }

    #[test]
    fn warm_candidate_prefers_fastest_other_gpu() {
        let mut c = ResultCache::new(8);
        c.insert(entry(1, "L1-95", "rtx6000", 1.4));
        c.insert(entry(2, "L1-95", "a100", 2.0));
        c.insert(entry(3, "L1-95", "h100", 1.7));
        c.insert(entry(4, "L1-1", "a100", 9.0)); // different task
        let w = c
            .warm_candidate("L1-95", "rtx6000", "CudaForge", "OpenAI-o3", "OpenAI-o3")
            .unwrap();
        assert_eq!(w.gpu_key, "a100");
        assert_eq!(w.best_speedup, 2.0);
        assert!(
            c.warm_candidate("L1-95", "rtx6000", "one-shot", "OpenAI-o3", "OpenAI-o3")
                .is_none(),
            "strategy must match"
        );
    }

    #[test]
    fn warm_candidate_survives_nan_speedups() {
        let mut c = ResultCache::new(8);
        let mut poisoned = entry(1, "L1-95", "a100", 1.0);
        poisoned.best_speedup = f64::NAN; // e.g. a hand-edited snapshot
        c.insert(poisoned);
        c.insert(entry(2, "L1-95", "h100", 1.3));
        c.insert(entry(3, "L1-95", "rtx4090", 1.3)); // tie -> fingerprint order
        let w = c
            .warm_candidate("L1-95", "rtx6000", "CudaForge", "OpenAI-o3", "OpenAI-o3")
            .unwrap();
        assert_eq!(w.fingerprint, Fingerprint(3), "NaN skipped, tie broken by fingerprint");

        let mut all_nan = ResultCache::new(4);
        let mut e = entry(4, "L1-95", "a100", 1.0);
        e.best_speedup = f64::NAN;
        all_nan.insert(e);
        assert!(all_nan
            .warm_candidate("L1-95", "rtx6000", "CudaForge", "OpenAI-o3", "OpenAI-o3")
            .is_none());
    }

    #[test]
    fn restore_into_smaller_capacity_records_evictions() {
        let dir = std::env::temp_dir().join("cudaforge_cache_shrink_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.jsonl");

        let mut c = ResultCache::new(8);
        for i in 1..=6u64 {
            c.insert(entry(i, &format!("L1-{i}"), "rtx6000", 1.0));
        }
        c.snapshot(&path).unwrap();

        let r = ResultCache::restore(&path, 2).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.stats.evictions, 4, "squeezing 6 entries into 2 drops 4");
        assert_eq!(r.stats.inserts, 0, "rebuild churn is not traffic");
        assert_eq!(r.stats.hits, 0);
        // The hottest (last-written) entries survive, coldest go first.
        assert!(r.peek(Fingerprint(5)).is_some());
        assert!(r.peek(Fingerprint(6)).is_some());
        assert!(r.peek(Fingerprint(1)).is_none());
    }

    #[test]
    fn snapshot_restore_round_trips_entries_and_recency() {
        let dir = std::env::temp_dir().join("cudaforge_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.jsonl");

        let mut c = ResultCache::new(4);
        c.insert(entry(1, "L1-1", "rtx6000", 1.1));
        c.insert(entry(2, "L1-2", "a100", 1.2));
        c.get(Fingerprint(1)); // 2 is now coldest
        c.snapshot(&path).unwrap();

        let mut r = ResultCache::restore(&path, 4).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.stats, CacheStats::default());
        assert_eq!(r.peek(Fingerprint(2)), c.peek(Fingerprint(2)));
        // recency survived: inserting fresh keys evicts 2 first, not 1
        r.insert(entry(3, "L1-3", "rtx6000", 1.0));
        r.insert(entry(4, "L1-4", "rtx6000", 1.0));
        r.insert(entry(5, "L1-5", "rtx6000", 1.0));
        assert!(r.peek(Fingerprint(2)).is_none());
        assert!(r.peek(Fingerprint(1)).is_some());

        assert!(ResultCache::restore(dir.join("absent.jsonl"), 4).is_err());
        std::fs::write(dir.join("bad.jsonl"), "{not json}\n").unwrap();
        assert!(ResultCache::restore(dir.join("bad.jsonl"), 4).is_err());

        // Version gate: fingerprints are stored literally, so a snapshot
        // from another scheme must fail loudly, not restore-and-never-hit.
        let entry_line = entry(9, "L1-9", "rtx6000", 1.0).to_json().to_string();
        std::fs::write(dir.join("headerless.jsonl"), format!("{entry_line}\n")).unwrap();
        let err = ResultCache::restore(dir.join("headerless.jsonl"), 4)
            .unwrap_err()
            .to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::write(
            dir.join("old.jsonl"),
            format!("{{\"snapshot_version\":1}}\n{entry_line}\n"),
        )
        .unwrap();
        let err = ResultCache::restore(dir.join("old.jsonl"), 4).unwrap_err().to_string();
        assert!(err.contains("unsupported"), "{err}");
        std::fs::write(dir.join("empty.jsonl"), "").unwrap();
        assert!(ResultCache::restore(dir.join("empty.jsonl"), 4).is_err());
    }
}
