//! Content-addressed request fingerprints.
//!
//! A fingerprint is a stable 64-bit digest of everything that determines a
//! request's answer: the task's physical workload descriptor, the target GPU,
//! the agent models, the strategy and the round budget. Two requests with the
//! same fingerprint are the same piece of work — the cache and the
//! single-flight queue key on it.
//!
//! Stability matters more than speed here: the digest is computed over a
//! *canonical* field list (sorted by field name), so the order in which
//! callers add fields — or the order struct fields happen to be declared
//! in — can never change the hash. The seed is deliberately excluded:
//! re-rolling the RNG does not change what the user asked for.
//!
//! Cache snapshots store fingerprints literally, so any change to this
//! hashing scheme orphans every existing JSONL snapshot (restore succeeds
//! but nothing ever hits) — treat the byte layout in `finish` as a wire
//! format.

use std::fmt;

use crate::agents::ModelProfile;
use crate::gpu::GpuSpec;
use crate::tasks::TaskSpec;
use crate::workflow::Strategy;

/// 64-bit content address of one optimization request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(
    /// The digest value (FNV-1a over the canonical field list).
    pub u64,
);

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

impl Fingerprint {
    /// Parse the hex form written by `Display` (cache snapshots).
    pub fn parse(s: &str) -> Option<Fingerprint> {
        u64::from_str_radix(s, 16).ok().map(Fingerprint)
    }
}

pub(crate) const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over `bytes`, continuing from `h`. Shared with the cluster
/// router's rendezvous scores so both sides key off the same digest family.
pub(crate) fn fnv_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Order-insensitive field hasher: add `(name, value)` pairs in any order,
/// `finish` canonicalizes (sorts by name) before digesting.
#[derive(Default)]
pub struct FieldHasher {
    fields: Vec<(String, String)>,
}

impl FieldHasher {
    /// An empty hasher.
    pub fn new() -> FieldHasher {
        FieldHasher::default()
    }

    /// Add one `(name, value)` pair (order does not matter).
    pub fn field(mut self, name: &str, value: impl fmt::Display) -> FieldHasher {
        self.fields.push((name.to_string(), value.to_string()));
        self
    }

    /// Canonicalize (sort by name) and digest the field list.
    pub fn finish(mut self) -> Fingerprint {
        self.fields.sort();
        let mut h = FNV_OFFSET;
        for (name, value) in &self.fields {
            // Length-prefix both halves: unlike a sentinel separator, no
            // byte a name or value might itself contain (task names are
            // caller-provided) can shift the name/value or field/field
            // boundary and alias another field list.
            h = fnv_extend(h, &(name.len() as u64).to_le_bytes());
            h = fnv_extend(h, name.as_bytes());
            h = fnv_extend(h, &(value.len() as u64).to_le_bytes());
            h = fnv_extend(h, value.as_bytes());
        }
        Fingerprint(h)
    }
}

/// Fingerprint one optimization request. Content-addressed: every TaskSpec
/// field that feeds the simulator participates, so a task whose workload
/// descriptor changes (new suite revision) misses the old cache entries.
pub fn of_request(
    task: &TaskSpec,
    gpu: &GpuSpec,
    coder: &ModelProfile,
    judge: &ModelProfile,
    strategy: Strategy,
    rounds: usize,
) -> Fingerprint {
    FieldHasher::new()
        .field("task.level", task.level)
        .field("task.index", task.index)
        .field("task.name", &task.name)
        .field("task.op_class", task.op_class.name())
        .field("task.flops", task.flops)
        .field("task.ideal_bytes", task.ideal_bytes)
        .field("task.out_elems", task.out_elems)
        .field("task.intermediate_bytes", task.intermediate_bytes)
        .field("task.stages", task.stages)
        .field("task.tc_eligible", task.tc_eligible)
        .field("task.difficulty", task.difficulty)
        .field("task.baseline_quality", task.baseline_quality)
        .field("task.baseline_waste", task.baseline_waste)
        .field("task.binding", task.binding.unwrap_or("-"))
        .field("gpu.key", gpu.key)
        .field("coder", coder.name)
        .field("judge", judge.name)
        .field("strategy", strategy.name())
        .field("rounds", rounds)
        .finish()
}

/// Fold a static-analysis gate into a request fingerprint. Linted and
/// unlinted runs of the same request can produce different kernels (the
/// gate spends repair rounds before the first compile), so they must not
/// share cache entries. Only called when the gate is on: lint-off services
/// keep their historical fingerprints, and every cache snapshot written
/// before the analyzer existed stays valid.
pub fn with_lint(base: Fingerprint, repair_confidence: f64, max_repairs: u32) -> Fingerprint {
    let mut h = fnv_extend(base.0, b"lint");
    h = fnv_extend(h, &repair_confidence.to_bits().to_le_bytes());
    h = fnv_extend(h, &max_repairs.to_le_bytes());
    Fingerprint(h)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::agents::profiles::{GPT5, O3};
    use crate::gpu::{A100, RTX6000_ADA};
    use crate::tasks::by_id;

    #[test]
    fn stable_across_field_insertion_order() {
        let a = FieldHasher::new()
            .field("gpu", "rtx6000")
            .field("task", "L1-95")
            .field("rounds", 10)
            .finish();
        let b = FieldHasher::new()
            .field("rounds", 10)
            .field("task", "L1-95")
            .field("gpu", "rtx6000")
            .finish();
        assert_eq!(a, b);
    }

    #[test]
    fn field_boundaries_do_not_collide() {
        let a = FieldHasher::new().field("ab", "c").finish();
        let b = FieldHasher::new().field("a", "bc").finish();
        assert_ne!(a, b);
        // a delimiter-looking value must not shift the name/value boundary
        let c = FieldHasher::new().field("a", "b=c").finish();
        let d = FieldHasher::new().field("a=b", "c").finish();
        assert_ne!(c, d);
        // ...nor may an embedded separator byte: these alias under any
        // sentinel-delimited scheme.
        let e = FieldHasher::new().field("a", "b\x1fc").finish();
        let f = FieldHasher::new().field("a\x1fb", "c").finish();
        assert_ne!(e, f);
        let g = FieldHasher::new().field("a", "b").field("c", "d").finish();
        let h = FieldHasher::new().field("a", "b\x1fc\x1fd").finish();
        assert_ne!(g, h);
    }

    #[test]
    fn request_fingerprint_discriminates_every_axis() {
        let t95 = by_id("L1-95").unwrap();
        let t1 = by_id("L1-1").unwrap();
        let base = of_request(&t95, &RTX6000_ADA, &O3, &O3, Strategy::CudaForge, 10);
        assert_eq!(
            base,
            of_request(&t95, &RTX6000_ADA, &O3, &O3, Strategy::CudaForge, 10),
            "same request, same address"
        );
        for other in [
            of_request(&t1, &RTX6000_ADA, &O3, &O3, Strategy::CudaForge, 10),
            of_request(&t95, &A100, &O3, &O3, Strategy::CudaForge, 10),
            of_request(&t95, &RTX6000_ADA, &GPT5, &O3, Strategy::CudaForge, 10),
            of_request(&t95, &RTX6000_ADA, &O3, &GPT5, Strategy::CudaForge, 10),
            of_request(&t95, &RTX6000_ADA, &O3, &O3, Strategy::OneShot, 10),
            of_request(&t95, &RTX6000_ADA, &O3, &O3, Strategy::CudaForge, 30),
        ] {
            assert_ne!(base, other);
        }
    }

    #[test]
    fn hex_round_trips() {
        let fp = Fingerprint(0x0123_4567_89ab_cdef);
        assert_eq!(Fingerprint::parse(&fp.to_string()), Some(fp));
        assert!(Fingerprint::parse("not-hex").is_none());
    }
}
