//! The offline NCU metric-selection pipeline — the paper's Algorithms 1–2
//! (§2.3): kernel sampling on representative tasks, per-task Top-20 Pearson
//! ranking (after alias/collinearity removal), and cross-task consolidation
//! at the 75th percentile, yielding the ~24-metric key subset the Judge uses.

use crate::agents::profiles::O3;
use crate::agents::Coder;
use crate::gpu::GpuSpec;
use crate::kernel::{KernelConfig, OPT_CATALOG};
use crate::sim::{ncu, simulate, SimParams};
use crate::tasks::{by_id, TaskSpec};
use crate::util::rng::Rng;
use crate::util::stats::{mean, pearson, percentile};

/// The representative tasks of Algorithm 1 ("e.g., Conv2D, MatMul").
pub const REPRESENTATIVE_TASKS: [&str; 8] =
    ["L1-54", "L1-1", "L1-62", "L1-24", "L1-47", "L1-40", "L1-95", "L2-51"];

/// One sampled kernel: its runtime and its profiled metric vector.
#[derive(Clone, Debug)]
pub struct SampledKernel {
    pub runtime_us: f64,
    pub metrics: Vec<f64>,
}

/// Per-task output of the Top-20 stage (Tables 6–7).
#[derive(Clone, Debug)]
pub struct TaskTop20 {
    pub task_id: String,
    pub task_name: String,
    /// (metric name, signed Pearson r), ranked by |r| descending.
    pub ranked: Vec<(String, f64)>,
}

/// Final pipeline output (Table 8).
#[derive(Clone, Debug)]
pub struct Selection {
    pub per_task: Vec<TaskTop20>,
    /// Selected metric names with their global correlation scores S_m.
    pub selected: Vec<(String, f64)>,
}

/// Algorithm 1: sample kernels by self-refinement on one task, keep the 10
/// with the largest speed disparity (5 fastest + 5 slowest correct kernels).
pub fn sample_kernels(
    gpu: &GpuSpec,
    task: &TaskSpec,
    params: &SimParams,
    iterations: usize,
    rng: &mut Rng,
) -> Vec<SampledKernel> {
    let coder = Coder::new(O3);
    let mut correct: Vec<(f64, KernelConfig)> = Vec::new();
    for i in 0..iterations {
        let mut krng = rng.fork(i as u64);
        let (mut cfg, _) = coder.initial(task, gpu, &mut krng);
        // A short self-refine walk: random applicable moves (the
        // generate -> execute/profile -> repair/optimize cycle of Alg. 1).
        for _ in 0..krng.range_usize(0, 6) {
            let o = OPT_CATALOG[krng.below(OPT_CATALOG.len())];
            if o.applicable(task, &cfg) {
                o.apply(&mut cfg, task, gpu);
            }
        }
        cfg.bugs.clear(); // only correct kernels enter the metric study
        cfg.legalize(gpu);
        let out = simulate(gpu, task, &cfg, params, 1.0);
        correct.push((out.internals.kernel_time_us, cfg));
    }
    correct.sort_by(|a, b| a.0.total_cmp(&b.0));
    // Largest disparity: the 5 fastest and the 5 slowest.
    let n = correct.len();
    let mut picked: Vec<&(f64, KernelConfig)> = Vec::with_capacity(10);
    picked.extend(correct.iter().take(5));
    picked.extend(correct.iter().skip(n.saturating_sub(5)));
    picked
        .into_iter()
        .map(|(rt, cfg)| {
            let out = simulate(gpu, task, cfg, params, 1.0);
            let metrics = ncu::profile(gpu, task, cfg, &out, rng);
            SampledKernel { runtime_us: *rt, metrics }
        })
        .collect()
}

/// Alias/collinearity removal: cluster metrics whose pairwise |r| across the
/// sampled kernels exceeds 0.999 and keep (only true duplicate views collapse — the paper itself retains alias families like the three DRAM-throughput variants in Table 8) one canonical representative per
/// cluster (lowest catalog index — which prefers the canonical NCU names).
/// Returns the surviving metric indices.
pub fn remove_aliases(kernels: &[SampledKernel]) -> Vec<usize> {
    let n = ncu::N_METRICS;
    let cols: Vec<Vec<f64>> = (0..n)
        .map(|m| kernels.iter().map(|k| k.metrics[m]).collect())
        .collect();
    let mut keep = vec![true; n];
    for i in 0..n {
        if !keep[i] {
            continue;
        }
        for j in (i + 1)..n {
            if keep[j] && pearson(&cols[i], &cols[j]).abs() > 0.999 {
                keep[j] = false;
            }
        }
    }
    (0..n).filter(|&i| keep[i]).collect()
}

/// Algorithm 2 per-task stage: Pearson of every surviving metric against
/// runtime, ranked, truncated to the Top-20 by |r|.
pub fn top20(task: &TaskSpec, kernels: &[SampledKernel]) -> TaskTop20 {
    let runtimes: Vec<f64> = kernels.iter().map(|k| k.runtime_us).collect();
    let survivors = remove_aliases(kernels);
    let mut ranked: Vec<(String, f64)> = survivors
        .into_iter()
        .map(|m| {
            let col: Vec<f64> = kernels.iter().map(|k| k.metrics[m]).collect();
            (ncu::CATALOG[m].to_string(), pearson(&col, &runtimes))
        })
        .filter(|(_, r)| r.abs() > 1e-6)
        .collect();
    ranked.sort_by(|a, b| b.1.abs().total_cmp(&a.1.abs()));
    ranked.truncate(20);
    TaskTop20 {
        task_id: task.id(),
        task_name: task.name.clone(),
        ranked,
    }
}

/// The full pipeline (Algorithms 1–2) over the representative tasks.
pub fn select_metrics(
    gpu: &GpuSpec,
    params: &SimParams,
    iterations: usize,
    seed: u64,
) -> Selection {
    let mut rng = Rng::new(seed);
    let mut per_task = Vec::new();
    for id in REPRESENTATIVE_TASKS {
        let task = by_id(id).expect("representative task exists");
        let kernels = sample_kernels(gpu, &task, params, iterations, &mut rng);
        per_task.push(top20(&task, &kernels));
    }

    // Step 3: consolidate across tasks.
    #[derive(Default)]
    struct Acc {
        rs: Vec<f64>,
    }
    let mut by_name: std::collections::BTreeMap<String, Acc> = Default::default();
    for t in &per_task {
        for (name, r) in &t.ranked {
            by_name.entry(name.clone()).or_default().rs.push(*r);
        }
    }
    // Keep: appears in multiple tasks AND sign-consistent; score = mean |r|.
    let mut candidates: Vec<(String, f64)> = by_name
        .iter()
        .filter(|(_, acc)| {
            acc.rs.len() >= 2
                && (acc.rs.iter().all(|r| *r > 0.0) || acc.rs.iter().all(|r| *r < 0.0))
        })
        .map(|(name, acc)| {
            let s: Vec<f64> = acc.rs.iter().map(|r| r.abs()).collect();
            (name.clone(), mean(&s))
        })
        .collect();
    let scores: Vec<f64> = candidates.iter().map(|(_, s)| *s).collect();
    let p75 = percentile(&scores, 75.0);
    // "select metrics whose global scores exceed the 75th percentile" — the
    // paper applies P75 over *all* candidates (pre-filter); with our catalog
    // the sign+recurrence filter plus P75-of-filtered lands in the paper's
    // ~24-metric regime.
    candidates.retain(|(_, s)| *s >= p75 * 0.72);
    candidates.sort_by(|a, b| b.1.total_cmp(&a.1));
    Selection { per_task, selected: candidates }
}

impl Selection {
    /// Overlap with the paper's Table-8 subset (names).
    pub fn overlap_with_paper(&self) -> usize {
        self.selected
            .iter()
            .filter(|(n, _)| ncu::KEY_SUBSET.contains(&n.as_str()))
            .count()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu::RTX6000_ADA;

    fn quick_selection() -> Selection {
        select_metrics(&RTX6000_ADA, &SimParams::default(), 40, 2025)
    }

    #[test]
    fn sampling_returns_ten_disparate_kernels() {
        let task = by_id("L1-1").unwrap();
        let mut rng = Rng::new(1);
        let ks = sample_kernels(&RTX6000_ADA, &task, &SimParams::default(), 50, &mut rng);
        assert_eq!(ks.len(), 10);
        let rts: Vec<f64> = ks.iter().map(|k| k.runtime_us).collect();
        let spread = rts.iter().cloned().fold(f64::MIN, f64::max)
            / rts.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.2, "disparity {spread}");
    }

    #[test]
    fn alias_removal_drops_collinear_duplicates() {
        let task = by_id("L1-24").unwrap();
        let mut rng = Rng::new(2);
        let ks = sample_kernels(&RTX6000_ADA, &task, &SimParams::default(), 50, &mut rng);
        let kept = remove_aliases(&ks);
        assert!(kept.len() < ncu::N_METRICS, "nothing removed");
        assert!(kept.len() > 20, "too much removed: {}", kept.len());
    }

    #[test]
    fn top20_is_ranked_by_abs_r() {
        let task = by_id("L1-47").unwrap();
        let mut rng = Rng::new(3);
        let ks = sample_kernels(&RTX6000_ADA, &task, &SimParams::default(), 60, &mut rng);
        let t = top20(&task, &ks);
        assert!(t.ranked.len() <= 20 && t.ranked.len() >= 10);
        for w in t.ranked.windows(2) {
            assert!(w[0].1.abs() >= w[1].1.abs() - 1e-12);
        }
        // cycles-active should be a near-perfect runtime correlate (Table 6
        // shows 1.000000).
        let top_names: Vec<&str> = t.ranked.iter().take(4).map(|x| x.0.as_str()).collect();
        assert!(
            top_names.iter().any(|n| n.contains("cycles")),
            "top metrics {top_names:?}"
        );
    }

    #[test]
    fn pipeline_recovers_key_subset_scale() {
        let sel = quick_selection();
        let n = sel.selected.len();
        assert!(
            (16..=34).contains(&n),
            "selected {n} metrics: {:?}",
            sel.selected.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
        );
        let overlap = sel.overlap_with_paper();
        assert!(
            overlap >= 12,
            "only {overlap} of the paper's 24 recovered; selected: {:?}",
            sel.selected.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn selection_is_deterministic() {
        let a = quick_selection();
        let b = quick_selection();
        assert_eq!(
            a.selected.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>(),
            b.selected.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>()
        );
    }
}
