//! Sharded multi-tenant cluster simulation over the kernel-optimization
//! service.
//!
//! `service::KernelService` prices one node: one result cache, one
//! single-flight queue, one simulated GPU fleet. The ROADMAP's target —
//! serving millions of users — is a *cluster* of such nodes, and the
//! questions that matter at that scale are cluster questions: how evenly do
//! fingerprints shard, what does a node failure cost, which tenant starves
//! under overload, and when is it worth fetching a warm-start seed from
//! another node's shard. This module answers them with the same
//! discrete-event discipline as the single-node layer:
//!
//! - [`router`] — rendezvous (highest-random-weight) hashing routes each
//!   fingerprint to one alive node; a node's death moves only its own keys.
//! - Each simulated node owns its **own** `ResultCache` shard, `JobQueue`,
//!   and `FleetSim` worker slice — there is no shared cache, so a request
//!   hitting the "wrong" node's shard is impossible by construction.
//! - **Tenancy.** Every trace request carries a tenant index. Under
//!   overload (a node's flight backlog at `queue_depth`), weighted
//!   fair-share quotas meter who may open *new* flights: tenant `i` may
//!   hold at most `queue_depth * weight_i / total_weight` backlog slots.
//!   Quota sheds are counted per tenant — the old global batch-shed is no
//!   longer the only admission knob (it still applies first).
//! - **Failure/rebalance.** A configured node drops mid-replay: its cache
//!   shard is lost (entries counted), accepted work drains gracefully, and
//!   subsequent requests for its keys rehash to surviving nodes where they
//!   re-miss — the re-run flights and their API dollars are accounted in
//!   [`RebalanceReport`].
//! - **Cross-node warm starts.** A miss on node A may seed from the best
//!   hit-adjacent entry owned by node B, paying a configurable transfer
//!   latency on top of the run's service time.
//!
//! # Determinism
//!
//! Everything reported is simulated-time or request-count arithmetic
//! accumulated in (arrival, node, flight) order; OS `threads` only changes
//! how fast the host crunches workflow runs. A [`ClusterReport`] is
//! bit-identical across thread counts, and a 1-node single-tenant cluster
//! replay is bit-identical to [`KernelService::replay`]'s `ServiceReport` —
//! both invariants are asserted by `tests/integration_cluster.rs`.
//!
//! [`KernelService::replay`]: crate::service::KernelService::replay

pub mod router;

use std::collections::{BTreeMap, BTreeSet};

use crate::service::cache::{CacheEntry, ResultCache};
use crate::service::fingerprint::Fingerprint;
use crate::service::pool::{self, FleetSim, SimFlight};
use crate::service::queue::{Flight, JobQueue, Priority, Request, ALL_PRIORITIES};
use crate::service::traffic::TrafficRequest;
use crate::service::{PriorityClassReport, ServiceConfig, ServiceReport};
use crate::tasks::TaskSpec;
use crate::util::stats::{mean, percentile};
use crate::workflow::{run_task, CorrectnessOracle, TaskResult, WorkflowConfig};

pub use router::Router;

/// One tenant of the cluster: a name for reporting and a fair-share weight.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Relative share of each node's flight backlog this tenant may hold
    /// under overload (see [`fair_share_quotas`]). Non-positive weights get
    /// the minimum quota of one slot.
    pub weight: f64,
}

impl TenantSpec {
    pub fn new(name: impl Into<String>, weight: f64) -> TenantSpec {
        TenantSpec { name: name.into(), weight }
    }
}

/// Cluster deployment parameters. `service` holds the *per-node* knobs:
/// `capacity` is each shard's entry budget, `sim_workers` each node's
/// simulated GPU slice, `queue_depth` each node's admission bound;
/// `window` and `threads` stay cluster-global.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub service: ServiceConfig,
    /// Simulated nodes (clamped to at least 1).
    pub nodes: usize,
    /// The tenant population. `TrafficRequest::tenant` indexes this list
    /// (out-of-range indices clamp to the last tenant).
    pub tenants: Vec<TenantSpec>,
    /// Enforce weighted fair-share quotas under overload. Off by default so
    /// a 1-node, 1-tenant cluster reproduces the single-node service's
    /// admission behaviour exactly (only batch work is shed at the bound).
    pub tenant_quotas: bool,
    /// Simulated seconds to fetch a warm-start seed kernel from another
    /// node's shard, added to the run's service time.
    pub transfer_latency_s: f64,
    /// Fail node `.0` the first time simulated time reaches `.1` seconds:
    /// its cache shard is lost and later requests for its keys rehash.
    pub fail_node_at: Option<(usize, f64)>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            service: ServiceConfig::default(),
            nodes: 4,
            tenants: vec![TenantSpec::new("default", 1.0)],
            tenant_quotas: false,
            transfer_latency_s: 30.0,
            fail_node_at: None,
        }
    }
}

/// Per-node backlog quota for each tenant: its weight-share of
/// `queue_depth`, floored, but never below one slot (every tenant can make
/// progress). An unbounded queue disables quotas entirely.
pub fn fair_share_quotas(queue_depth: usize, tenants: &[TenantSpec]) -> Vec<usize> {
    let total: f64 = tenants.iter().map(|t| t.weight.max(0.0)).sum();
    tenants
        .iter()
        .map(|t| {
            if queue_depth == usize::MAX || total <= 0.0 {
                usize::MAX
            } else {
                let share = queue_depth as f64 * t.weight.max(0.0) / total;
                (share.floor() as usize).max(1)
            }
        })
        .collect()
}

/// One node's serving-state slice, with its cache-effectiveness and
/// utilization aggregates for the replay.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeReport {
    pub node: usize,
    /// False once the failure event killed this node.
    pub alive: bool,
    /// Requests routed to this node (hits + joins + flights + sheds).
    pub requests: usize,
    pub cache_hits: u64,
    pub shared: u64,
    pub flights_run: usize,
    pub rejected: u64,
    pub evictions: u64,
    pub hit_rate: f64,
    /// Busy time / (node workers × node makespan).
    pub utilization: f64,
    pub peak_queue_depth: usize,
    /// Entries resident in this node's shard after the replay.
    pub cache_entries: usize,
}

/// One tenant's outcome: traffic volume, shed counts, and latency/SLO
/// aggregates (each served request scored against its own priority class's
/// target).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantReport {
    pub tenant: String,
    pub weight: f64,
    pub requests: usize,
    /// Requests that got an answer (requests − rejected).
    pub served: usize,
    /// All sheds of this tenant's traffic (batch overload + quota).
    pub rejected: u64,
    /// The subset of `rejected` shed specifically by this tenant exceeding
    /// its fair-share quota.
    pub quota_shed: u64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    /// Fraction of served requests within their priority class's SLO
    /// target (1.0 when nothing was served — a vacuous SLO holds).
    pub slo_attainment: f64,
}

/// What the configured node failure cost.
#[derive(Clone, Debug, PartialEq)]
pub struct RebalanceReport {
    pub failed_node: usize,
    pub failed_at_s: f64,
    /// Cache entries the dead node's shard held — all lost.
    pub cache_entries_lost: usize,
    /// Post-failure requests whose rendezvous owner *would have been* the
    /// dead node — the traffic that rehashed to survivors.
    pub rehashed_requests: usize,
    /// Lost keys that had to re-run a full workflow on a surviving node.
    pub remissed_flights: usize,
    /// API dollars those re-runs spent — work the cluster had already paid
    /// for once.
    pub remiss_api_usd: f64,
}

/// Everything a cluster replay reports. `overall` is shaped exactly like
/// the single-node report (and *is* that report, bit for bit, for a 1-node
/// single-tenant cluster); the per-node / per-tenant / rebalance views are
/// what the sharded deployment adds.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterReport {
    pub overall: ServiceReport,
    pub nodes: usize,
    pub per_node: Vec<NodeReport>,
    pub per_tenant: Vec<TenantReport>,
    /// Executed misses that warm-started from an entry owned by a
    /// *different* node (each paid `transfer_latency_s`).
    pub cross_node_warm: usize,
    /// Total quota-exceeded sheds across tenants.
    pub quota_shed: u64,
    /// Present when `fail_node_at` fired during the replay.
    pub rebalance: Option<RebalanceReport>,
}

/// Per-replay mutable state of one simulated node (caches live on the
/// service so they survive across replays, like the single-node layer).
struct NodeState {
    queue: JobQueue,
    fleet: FleetSim,
    /// Flights opened but not yet started, per tenant — the fair-share
    /// quota meter.
    backlog_by_tenant: Vec<usize>,
    requests: usize,
    hits: u64,
    shared: u64,
    flights_run: usize,
    rejected: u64,
    peak_depth: usize,
    /// This node's cache eviction counter at replay start (delta basis).
    evictions0: u64,
    /// Evictions accumulated before the cache shard was dropped by the
    /// failure event (the replacement cache restarts its counter).
    evictions_carry: u64,
}

/// The long-lived cluster: a router plus N cache shards and the
/// cluster-wide cold-cost registry (counterfactual pricing is a property of
/// fingerprints, not of which shard served them).
pub struct ClusterService {
    pub config: ClusterConfig,
    router: Router,
    caches: Vec<ResultCache>,
    cold_cost: BTreeMap<Fingerprint, f64>,
}

impl ClusterService {
    pub fn new(mut config: ClusterConfig) -> ClusterService {
        config.nodes = config.nodes.max(1);
        if config.tenants.is_empty() {
            config.tenants.push(TenantSpec::new("default", 1.0));
        }
        let caches = (0..config.nodes)
            .map(|_| ResultCache::new(config.service.capacity))
            .collect();
        let router = Router::new(config.nodes);
        ClusterService { config, router, caches, cold_cost: BTreeMap::new() }
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Node `n`'s cache shard (introspection/tests).
    pub fn cache(&self, n: usize) -> &ResultCache {
        &self.caches[n]
    }

    /// Best warm-start candidate across every *alive* shard, with its
    /// owning node (a dead node's entries are unreachable, not warm-start
    /// donors). Ties break on (speedup, fingerprint, node) so the scan
    /// order can never change the pick.
    fn warm_candidate_across(
        &self,
        task_id: &str,
        gpu_key: &str,
        alive: &[bool],
    ) -> Option<(usize, &CacheEntry)> {
        let c = &self.config.service;
        let mut best: Option<(usize, &CacheEntry)> = None;
        for (node, cache) in self.caches.iter().enumerate() {
            if !alive.get(node).copied().unwrap_or(false) {
                continue;
            }
            let cand = cache.warm_candidate(
                task_id,
                gpu_key,
                c.strategy.name(),
                c.coder.name,
                c.judge.name,
            );
            if let Some(e) = cand {
                let better = match best {
                    None => true,
                    Some((bn, b)) => e
                        .best_speedup
                        .total_cmp(&b.best_speedup)
                        .then_with(|| e.fingerprint.cmp(&b.fingerprint))
                        .then_with(|| node.cmp(&bn))
                        .is_gt(),
                };
                if better {
                    best = Some((node, e));
                }
            }
        }
        best
    }

    /// Replay a traffic trace through the cluster. Mirrors
    /// [`crate::service::KernelService::replay`] per node: windowed
    /// admission, single-flight joins, per-node discrete-event fleets —
    /// plus routing, tenancy, failure, and cross-node warm starts.
    /// Deterministic per (config, trace); OS `threads` changes wall-clock
    /// only.
    pub fn replay(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
    ) -> ClusterReport {
        let nodes = self.config.nodes;
        let n_tenants = self.config.tenants.len();
        let window = self.config.service.window.max(1);
        let sim_workers = self.config.service.sim_workers.max(1);
        let queue_depth = self.config.service.queue_depth;
        let hit_latency_s = self.config.service.hit_latency_s;
        let quotas_on = self.config.tenant_quotas;
        let quotas = fair_share_quotas(queue_depth, &self.config.tenants);
        debug_assert!(
            trace.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
            "trace must be sorted by arrival time"
        );

        let mut states: Vec<NodeState> = (0..nodes)
            .map(|i| NodeState {
                queue: JobQueue::new(),
                fleet: FleetSim::new(sim_workers),
                backlog_by_tenant: vec![0; n_tenants],
                requests: 0,
                hits: 0,
                shared: 0,
                flights_run: 0,
                rejected: 0,
                peak_depth: 0,
                evictions0: self.caches[i].stats.evictions,
                evictions_carry: 0,
            })
            .collect();
        let mut alive = vec![true; nodes];

        let mut latencies: Vec<Option<f64>> = vec![None; trace.len()];
        let mut api_spent = 0.0;
        let mut api_cold = 0.0;
        let mut flights_run = 0usize;
        let mut warm_started = 0usize;
        let mut warm_correct = 0usize;
        let mut shared = 0u64;
        let mut rejected = 0u64;
        let mut rejected_by_class = [0u64; 3];
        let mut cold_rounds: Vec<f64> = Vec::new();
        let mut warm_rounds: Vec<f64> = Vec::new();
        let mut cross_node_warm = 0usize;
        let mut tenant_requests = vec![0usize; n_tenants];
        let mut tenant_rejected = vec![0u64; n_tenants];
        let mut tenant_quota_shed = vec![0u64; n_tenants];
        let mut rebalance: Option<RebalanceReport> = None;
        let mut lost_keys: BTreeSet<Fingerprint> = BTreeSet::new();

        for (w0, win) in trace.chunks(window).enumerate().map(|(i, w)| (i * window, w)) {
            // ---- admission: route each arrival to its shard --------------
            for (off, req) in win.iter().enumerate() {
                let seq = (w0 + off) as u64;
                let now = req.arrival_s;
                let t = req.tenant.min(n_tenants - 1);
                for st in states.iter_mut() {
                    let NodeState { fleet, backlog_by_tenant, .. } = st;
                    fleet.advance(now, &mut |f, done| {
                        for (s, arr) in &f.members {
                            latencies[*s as usize] =
                                Some((done.completion_s - arr).max(hit_latency_s));
                        }
                        backlog_by_tenant[f.tenant] =
                            backlog_by_tenant[f.tenant].saturating_sub(1);
                    });
                }
                // The failure event: drop the node's shard, remember its
                // keys, keep serving its accepted work (graceful drain).
                if let Some((fnode, ftime)) = self.config.fail_node_at {
                    if fnode < nodes && alive[fnode] && now >= ftime {
                        alive[fnode] = false;
                        let capacity = self.config.service.capacity;
                        let cache = &mut self.caches[fnode];
                        lost_keys.extend(cache.entries_coldest_first().map(|e| e.fingerprint));
                        let carry = cache.stats.evictions;
                        *cache = ResultCache::new(capacity);
                        let st_f = &mut states[fnode];
                        st_f.evictions_carry = carry - st_f.evictions0;
                        st_f.evictions0 = 0;
                        rebalance = Some(RebalanceReport {
                            failed_node: fnode,
                            failed_at_s: ftime,
                            cache_entries_lost: lost_keys.len(),
                            rehashed_requests: 0,
                            remissed_flights: 0,
                            remiss_api_usd: 0.0,
                        });
                    }
                }
                let fp = self.config.service.fingerprint_of(&tasks[req.task_index], req.gpu);
                if let Some(rb) = rebalance.as_mut() {
                    if self.router.route_any(fp) == rb.failed_node {
                        rb.rehashed_requests += 1;
                    }
                }
                // Every arrival is this tenant's traffic, even one the
                // cluster cannot route (served + rejected == requests must
                // hold per tenant).
                tenant_requests[t] += 1;
                let ni = match self.router.route(fp, &alive) {
                    Some(n) => n,
                    None => {
                        // Every node is dead: shed unconditionally.
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        continue;
                    }
                };
                let st = &mut states[ni];
                st.requests += 1;
                if let Some(cold_ref) = st.fleet.join_waiting(fp, seq, now, req.priority) {
                    shared += 1;
                    st.shared += 1;
                    api_cold += cold_ref;
                    continue;
                }
                if let Some((completion_s, cold_ref)) = st.fleet.in_flight(fp, now) {
                    latencies[seq as usize] = Some((completion_s - now).max(hit_latency_s));
                    shared += 1;
                    st.shared += 1;
                    api_cold += cold_ref;
                    continue;
                }
                if let Some(entry) = self.caches[ni].get(fp) {
                    latencies[seq as usize] = Some(hit_latency_s);
                    st.hits += 1;
                    api_cold += entry.cold_api_usd;
                    continue;
                }
                // Miss: admission control. The global batch-shed applies
                // first (as on a single node), then the tenant's fair-share
                // quota — both only against requests opening a *new*
                // flight; joins are always free.
                let depth = st.fleet.depth() + st.queue.len();
                if depth >= queue_depth && !st.queue.contains(fp) {
                    if req.priority == Priority::Batch {
                        st.queue.reject();
                        st.rejected += 1;
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        continue;
                    }
                    if quotas_on && st.backlog_by_tenant[t] >= quotas[t] {
                        st.queue.reject();
                        st.rejected += 1;
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        tenant_quota_shed[t] += 1;
                        continue;
                    }
                }
                let opened = st.queue.push(Request {
                    seq,
                    fingerprint: fp,
                    priority: req.priority,
                    tenant: t,
                });
                if opened {
                    st.backlog_by_tenant[t] += 1;
                }
                st.peak_depth = st.peak_depth.max(st.fleet.depth() + st.queue.len());
            }

            // ---- dispatch: drain every shard, crunch on OS threads -------
            let mut flights: Vec<(usize, Flight)> = Vec::new();
            for (ni, st) in states.iter_mut().enumerate() {
                for f in st.queue.drain() {
                    flights.push((ni, f));
                }
            }
            let c = &self.config.service;
            let prepared: Vec<(WorkflowConfig, usize, bool)> = flights
                .iter()
                .map(|(ni, f)| {
                    let req = &trace[f.leader_seq as usize];
                    let task = &tasks[req.task_index];
                    let wf = c.base_workflow(req.gpu);
                    match self.warm_candidate_across(&task.id(), req.gpu.key, &alive) {
                        Some((owner, entry)) => {
                            (c.warm_start_from(wf, entry), req.task_index, owner != *ni)
                        }
                        None => (wf, req.task_index, false),
                    }
                })
                .collect();
            let results: Vec<TaskResult> = pool::run_indexed(
                prepared.len(),
                c.threads,
                |i| run_task(&prepared[i].0, &tasks[prepared[i].1], oracle),
            );

            // ---- accounting + shard refill + fleet submission ------------
            for (((ni, flight), (wf, task_index, cross)), result) in
                flights.iter().zip(&prepared).zip(&results)
            {
                let st = &mut states[*ni];
                flights_run += 1;
                st.flights_run += 1;
                api_spent += result.ledger.api_usd;
                let warm = wf.warm_start.is_some();
                if *cross {
                    cross_node_warm += 1;
                }
                let cold_ref = if warm {
                    self.cold_cost
                        .get(&flight.fingerprint)
                        .copied()
                        .unwrap_or(result.ledger.api_usd)
                } else {
                    self.cold_cost
                        .entry(flight.fingerprint)
                        .or_insert(result.ledger.api_usd);
                    result.ledger.api_usd
                };
                api_cold += cold_ref * flight.members() as f64;
                shared += flight.follower_seqs.len() as u64;
                st.shared += flight.follower_seqs.len() as u64;
                if let Some(rb) = rebalance.as_mut() {
                    // A lost key's first re-run is the failure's re-miss
                    // cost: work the dead shard had already paid for.
                    if lost_keys.remove(&flight.fingerprint) {
                        rb.remissed_flights += 1;
                        rb.remiss_api_usd += result.ledger.api_usd;
                    }
                }
                if warm {
                    warm_started += 1;
                    if result.correct {
                        warm_correct += 1;
                    }
                }
                if let Some(r2b) = result.rounds_to_best() {
                    if warm {
                        warm_rounds.push(r2b as f64);
                    } else {
                        cold_rounds.push(r2b as f64);
                    }
                }
                // A dead node's draining flights still answer their members,
                // but their results must not repopulate the unreachable
                // shard (the router will never send a request there again).
                if result.correct && alive[*ni] {
                    if let Some(best_config) = result.best_config.clone() {
                        let task = &tasks[*task_index];
                        self.caches[*ni].insert(CacheEntry {
                            fingerprint: flight.fingerprint,
                            task_id: task.id(),
                            gpu_key: wf.gpu.key.to_string(),
                            strategy: c.strategy.name().to_string(),
                            coder: c.coder.name.to_string(),
                            judge: c.judge.name.to_string(),
                            best_speedup: result.best_speedup,
                            best_config,
                            api_usd: result.ledger.api_usd,
                            cold_api_usd: cold_ref,
                            wall_s: result.ledger.wall_s,
                            rounds_to_best: result.rounds_to_best().unwrap_or(0),
                        });
                    }
                }
                let leader_arrival = trace[flight.leader_seq as usize].arrival_s;
                let mut members = Vec::with_capacity(flight.members());
                members.push((flight.leader_seq, leader_arrival));
                members.extend(
                    flight
                        .follower_seqs
                        .iter()
                        .map(|s| (*s, trace[*s as usize].arrival_s)),
                );
                // A cross-node seed is fetched before the run starts: the
                // transfer rides on the flight's service time.
                let service_s = result.ledger.wall_s
                    + if *cross { self.config.transfer_latency_s } else { 0.0 };
                st.fleet.submit(SimFlight {
                    fingerprint: flight.fingerprint,
                    priority: flight.priority,
                    leader_seq: flight.leader_seq,
                    tenant: flight.tenant,
                    arrival_s: leader_arrival,
                    service_s,
                    members,
                    cold_ref,
                });
            }
        }
        // Drain: serve everything still queued at end of trace.
        for st in states.iter_mut() {
            let NodeState { fleet, backlog_by_tenant, .. } = st;
            fleet.advance(f64::INFINITY, &mut |f, done| {
                for (s, arr) in &f.members {
                    latencies[*s as usize] =
                        Some((done.completion_s - arr).max(hit_latency_s));
                }
                backlog_by_tenant[f.tenant] =
                    backlog_by_tenant[f.tenant].saturating_sub(1);
            });
        }

        let served: Vec<f64> = latencies.iter().filter_map(|l| *l).collect();
        debug_assert_eq!(
            served.len() + rejected as usize,
            trace.len(),
            "every request is served or rejected"
        );
        let slo = self.config.service.slo;
        let per_priority: Vec<PriorityClassReport> = ALL_PRIORITIES
            .iter()
            .map(|p| {
                let class: Vec<f64> = trace
                    .iter()
                    .zip(&latencies)
                    .filter(|(r, _)| r.priority == *p)
                    .filter_map(|(_, l)| *l)
                    .collect();
                let target = slo.target_s(*p);
                let attainment = if class.is_empty() {
                    1.0
                } else {
                    class.iter().filter(|l| **l <= target).count() as f64 / class.len() as f64
                };
                PriorityClassReport {
                    priority: *p,
                    requests: trace.iter().filter(|r| r.priority == *p).count(),
                    rejected: rejected_by_class[*p as usize],
                    p50_latency_s: percentile(&class, 50.0),
                    p95_latency_s: percentile(&class, 95.0),
                    p99_latency_s: percentile(&class, 99.0),
                    slo_target_s: target,
                    slo_attainment: attainment,
                }
            })
            .collect();

        let hits: u64 = states.iter().map(|s| s.hits).sum();
        let evictions: u64 = states
            .iter()
            .enumerate()
            .map(|(i, s)| s.evictions_carry + self.caches[i].stats.evictions - s.evictions0)
            .sum();
        let busy_s: f64 = states.iter().map(|s| s.fleet.busy_s()).sum();
        let makespan = states
            .iter()
            .map(|s| s.fleet.makespan_s())
            .fold(0.0f64, f64::max);
        let wait_s: f64 = states.iter().map(|s| s.fleet.total_queue_wait_s()).sum();
        let served_flights: usize = states.iter().map(|s| s.fleet.flights_served()).sum();
        let total_workers = nodes * sim_workers;
        let gpu_hours = busy_s / 3600.0;

        let per_node: Vec<NodeReport> = states
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let node_makespan = s.fleet.makespan_s();
                NodeReport {
                    node: i,
                    alive: alive[i],
                    requests: s.requests,
                    cache_hits: s.hits,
                    shared: s.shared,
                    flights_run: s.flights_run,
                    rejected: s.rejected,
                    evictions: s.evictions_carry + self.caches[i].stats.evictions
                        - s.evictions0,
                    hit_rate: if s.requests == 0 {
                        0.0
                    } else {
                        (s.hits + s.shared) as f64 / s.requests as f64
                    },
                    utilization: if node_makespan > 0.0 {
                        s.fleet.busy_s() / (sim_workers as f64 * node_makespan)
                    } else {
                        0.0
                    },
                    peak_queue_depth: s.peak_depth,
                    cache_entries: self.caches[i].len(),
                }
            })
            .collect();

        let per_tenant: Vec<TenantReport> = self
            .config
            .tenants
            .iter()
            .enumerate()
            .map(|(t, spec)| {
                let lat: Vec<f64> = trace
                    .iter()
                    .zip(&latencies)
                    .filter(|(r, _)| r.tenant.min(n_tenants - 1) == t)
                    .filter_map(|(_, l)| *l)
                    .collect();
                let within = trace
                    .iter()
                    .zip(&latencies)
                    .filter(|(r, _)| r.tenant.min(n_tenants - 1) == t)
                    .filter_map(|(r, l)| l.map(|v| (r.priority, v)))
                    .filter(|(p, v)| *v <= slo.target_s(*p))
                    .count();
                TenantReport {
                    tenant: spec.name.clone(),
                    weight: spec.weight,
                    requests: tenant_requests[t],
                    served: lat.len(),
                    rejected: tenant_rejected[t],
                    quota_shed: tenant_quota_shed[t],
                    p50_latency_s: percentile(&lat, 50.0),
                    p95_latency_s: percentile(&lat, 95.0),
                    p99_latency_s: percentile(&lat, 99.0),
                    slo_attainment: if lat.is_empty() {
                        1.0
                    } else {
                        within as f64 / lat.len() as f64
                    },
                }
            })
            .collect();

        let overall = ServiceReport {
            requests: trace.len(),
            flights_run,
            cache_hits: hits,
            shared,
            evictions,
            rejected,
            warm_started,
            warm_correct,
            hit_rate: if trace.is_empty() {
                0.0
            } else {
                (hits + shared) as f64 / trace.len() as f64
            },
            p50_latency_s: percentile(&served, 50.0),
            p95_latency_s: percentile(&served, 95.0),
            p99_latency_s: percentile(&served, 99.0),
            mean_latency_s: mean(&served),
            mean_queue_wait_s: if served_flights == 0 {
                0.0
            } else {
                wait_s / served_flights as f64
            },
            peak_queue_depth: states.iter().map(|s| s.peak_depth).max().unwrap_or(0),
            utilization: if makespan > 0.0 {
                busy_s / (total_workers as f64 * makespan)
            } else {
                0.0
            },
            per_priority,
            api_usd_spent: api_spent,
            api_usd_saved: api_cold - api_spent,
            api_usd_cold: api_cold,
            mean_rounds_to_best_cold: mean(&cold_rounds),
            mean_rounds_to_best_warm: mean(&warm_rounds),
            gpu_hours,
            requests_per_gpu_hour: if gpu_hours > 0.0 {
                trace.len() as f64 / gpu_hours
            } else {
                0.0
            },
        };

        ClusterReport {
            overall,
            nodes,
            per_node,
            per_tenant,
            cross_node_warm,
            quota_shed: tenant_quota_shed.iter().sum(),
            rebalance,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu;
    use crate::service::traffic::{generate, TrafficConfig};
    use crate::tasks;
    use crate::workflow::NoOracle;

    #[test]
    fn fair_shares_follow_weights_with_a_floor() {
        let tenants = vec![TenantSpec::new("a", 3.0), TenantSpec::new("b", 1.0)];
        assert_eq!(fair_share_quotas(8, &tenants), vec![6, 2]);
        // Tiny weights still get one slot; unbounded depth disables quotas.
        let skew = vec![TenantSpec::new("big", 100.0), TenantSpec::new("tiny", 0.0001)];
        assert_eq!(fair_share_quotas(4, &skew), vec![3, 1]);
        assert_eq!(
            fair_share_quotas(usize::MAX, &tenants),
            vec![usize::MAX, usize::MAX]
        );
        // Degenerate weights fall back to "no quota" rather than panicking.
        let zeros = vec![TenantSpec::new("z", 0.0)];
        assert_eq!(fair_share_quotas(8, &zeros), vec![usize::MAX]);
    }

    #[test]
    fn requests_partition_across_nodes_and_tenants() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig {
                requests: 300,
                tenant_mix: vec![("a".to_string(), 1.0), ("b".to_string(), 1.0)],
                ..TrafficConfig::default()
            },
        );
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 3,
            tenants: vec![TenantSpec::new("a", 1.0), TenantSpec::new("b", 1.0)],
            service: ServiceConfig {
                threads: 2,
                window: 16,
                ..ServiceConfig::default()
            },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.nodes, 3);
        assert_eq!(r.per_node.len(), 3);
        assert_eq!(r.per_tenant.len(), 2);
        assert_eq!(
            r.per_node.iter().map(|n| n.requests).sum::<usize>(),
            r.overall.requests,
            "routing partitions the trace across shards"
        );
        assert!(
            r.per_node.iter().filter(|n| n.requests > 0).count() >= 2,
            "rendezvous hashing spreads this trace over multiple nodes"
        );
        assert_eq!(
            r.per_tenant.iter().map(|t| t.requests).sum::<usize>(),
            r.overall.requests
        );
        for t in &r.per_tenant {
            assert_eq!(t.served as u64 + t.rejected, t.requests as u64);
            assert!((0.0..=1.0).contains(&t.slo_attainment));
        }
        assert_eq!(
            r.overall.cache_hits + r.overall.shared + r.overall.flights_run as u64
                + r.overall.rejected,
            r.overall.requests as u64,
            "every request is a hit, a follower, a flight, or shed"
        );
        assert!(r.rebalance.is_none());
        assert_eq!(r.quota_shed, 0, "quotas are off by default");
    }

    #[test]
    fn all_nodes_dead_sheds_everything() {
        let suite = tasks::kernelbench();
        let trace = vec![TrafficRequest {
            task_index: 0,
            gpu: gpu::by_key("rtx6000").unwrap(),
            priority: Priority::Standard,
            tenant: 0,
            arrival_s: 10.0,
        }];
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 1,
            fail_node_at: Some((0, 0.0)),
            service: ServiceConfig { threads: 1, ..ServiceConfig::default() },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.overall.rejected, 1, "an unroutable request is shed");
        assert_eq!(r.overall.flights_run, 0);
        assert!(!r.per_node[0].alive);
        // The unroutable shed still counts as the tenant's traffic.
        assert_eq!(r.per_tenant[0].requests, 1);
        assert_eq!(r.per_tenant[0].rejected, 1);
        assert_eq!(r.per_tenant[0].served, 0);
    }
}
