//! Sharded multi-tenant cluster simulation over the kernel-optimization
//! service.
//!
//! `service::KernelService` prices one node: one result cache, one
//! simulated GPU fleet. The ROADMAP's target — serving millions of users —
//! is a *cluster* of such nodes, and the questions that matter at that
//! scale are cluster questions: how evenly do fingerprints shard, what does
//! a node failure cost, what does bringing a node (back) *in* cost, which
//! tenant starves under overload, and when is it worth fetching a
//! warm-start seed from another node's shard. This module answers them with
//! the same discrete-event discipline as the single-node layer:
//!
//! - [`router`] — rendezvous (highest-random-weight) hashing routes each
//!   fingerprint to one alive node; a node's death moves only its own keys,
//!   and a node's join moves exactly those keys back. [`Membership`] tracks
//!   the alive set plus a monotonically increasing **epoch** counting
//!   membership changes.
//! - Each simulated node owns its **own** `ResultCache` shard and
//!   `FleetSim` worker slice — there is no shared cache, so a request
//!   hitting the "wrong" node's shard is impossible by construction.
//! - **Tenancy.** Every trace request carries a tenant index. Under
//!   overload (a node's flight backlog at `queue_depth`), weighted
//!   fair-share quotas meter who may open *new* flights: tenant `i` may
//!   hold at most `queue_depth * weight_i / total_weight` backlog slots.
//!   Quota sheds are counted per tenant — the old global batch-shed is no
//!   longer the only admission knob (it still applies first).
//! - **Membership events.** [`ClusterConfig::events`] schedules failures
//!   *and* joins at simulated instants. A failure drops the node's shard
//!   (entries counted lost; later requests for its keys rehash to
//!   survivors and re-miss). A join is the inverse movement as a *planned
//!   rebalance*: the joining node returns empty, and every surviving-shard
//!   entry whose key the newcomer now owns is moved to it, landing one
//!   [`ClusterConfig::transfer_latency_s`] after the join instant — the
//!   movement and its transfer spend are itemized in [`RebalanceReport`],
//!   and requests that slip into the transfer gap re-miss (also itemized).
//!   A node whose *first* scheduled event is a join starts outside the
//!   cluster (the "new capacity arrives mid-trace" scenario); fail-then-
//!   join models recovery. Event streams are **validated at construction**:
//!   failing a node that is already dead at the event's instant, or joining
//!   one already alive, is a [`MembershipEventError`] naming the node and
//!   instant (see [`validate_events`]) — not a silent no-op.
//! - **Closed-loop autoscaling.** [`autoscale`] adds the policy layer that
//!   *emits* membership events instead of scripting them: an
//!   [`autoscale::AutoscalePolicy`] observes per-node rolling signals at
//!   simulated decision ticks and schedules fails (immediate) and joins
//!   (after a provisioning delay) through this same event machinery, so
//!   every decision is priced by the rebalance accounting below.
//!   [`scenario`] supplies the deterministic traffic/fleet scenarios
//!   (diurnal, flash crowd, mass interruption, straggler) policies are
//!   compared on, and [`crate::report::frontier_table`] renders the
//!   comparison.
//! - **Cross-node warm starts, locality-aware.** A miss on node A may seed
//!   from a hit-adjacent entry owned by node B, paying
//!   `transfer_latency_s` on top of the run's service time — but only when
//!   the remote seed beats the best own-shard seed by more than
//!   [`ClusterConfig::warm_locality_margin`] (relative speedup). Otherwise
//!   the own-shard candidate wins and the transfer is not paid.
//! - **Shard-aware snapshots.** [`ClusterService::snapshot`] persists every
//!   shard, the cluster-wide cold-cost registry, and a manifest declaring
//!   the rendezvous epoch and node count (see [`snapshot`]);
//!   [`ClusterService::restore`] rebuilds a warm cluster from it, rehashing
//!   keys through the router — and accounting the movement in a
//!   [`RebalanceReport`] — when the node count changed since the save.
//!
//! # Determinism and causality
//!
//! The replay drives every node fleet through one *global* event loop:
//! starts, completions, and rebalance refill landings fire in cluster-wide
//! timestamp order (refill landings first at an instant, then completions,
//! then starts, then node index), interleaved with arrivals; membership
//! events apply after everything due by their instant has fired. A flight
//! starting on any node therefore observes exactly the cache entries —
//! its own shard's and other shards' warm-start donors — whose producing
//! flights completed (or whose rebalance transfers landed) by its start
//! instant, never a result still being computed or still in transit.
//! Everything reported is simulated-time or request-count arithmetic
//! accumulated in that event order; OS `threads` and the `window`
//! speculation batch size only change how fast the host crunches workflow
//! runs. A [`ClusterReport`] is bit-identical across thread counts, and a
//! 1-node single-tenant cluster replay is bit-identical to
//! [`KernelService::replay`]'s `ServiceReport` — both invariants are
//! asserted by `tests/integration_cluster.rs`, and the per-flight
//! accounting itself is one shared helper
//! (`service::settle_flight_completion`), not parallel code.
//!
//! [`KernelService::replay`]: crate::service::KernelService::replay

pub mod autoscale;
pub mod router;
pub mod scenario;
pub mod snapshot;

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::path::Path;

use anyhow::Result;

use crate::service::cache::{CacheEntry, ResultCache};
use crate::service::fingerprint::Fingerprint;
use crate::service::pool::{
    DispatchSnapshot, FleetHooks, FleetSim, MemberList, SimCompletion, SimFlight,
};
use crate::service::queue::Priority;
use crate::service::ratelimit::{RateDecision, RateLimiter, RatePolicy};
use crate::service::traffic::TrafficRequest;
use crate::service::{
    admit_event, flight_complete_event, intern_fingerprints, per_priority_report,
    settle_flight_completion, speculate_window, PendingRun, ReplayStats, RunMemo, ServiceConfig,
    ServiceReport,
};
use crate::tasks::TaskSpec;
use crate::trace::profile::Stage;
use crate::trace::{NullSink, Observer, TraceEvent};
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::workflow::{run_task, CorrectnessOracle};

pub use autoscale::AutoscaleRun;
pub use router::{Membership, Router};
pub use scenario::Scenario;

/// One tenant of the cluster: a name for reporting and a fair-share weight.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Display name (reports and the `--tenants` CLI syntax).
    pub name: String,
    /// Relative share of each node's flight backlog this tenant may hold
    /// under overload (see [`fair_share_quotas`]). Non-positive weights get
    /// the minimum quota of one slot.
    pub weight: f64,
}

impl TenantSpec {
    /// A tenant with the given name and fair-share weight.
    pub fn new(name: impl Into<String>, weight: f64) -> TenantSpec {
        TenantSpec { name: name.into(), weight }
    }
}

/// What a scheduled membership event does to its node slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MembershipChange {
    /// The node drops out: its cache shard is lost and its keys rehash to
    /// survivors.
    Fail,
    /// The node (re)enters empty: the keys it owns move back from the
    /// surviving shards as a planned rebalance.
    Join,
}

/// One scheduled membership change, applied the first time simulated time
/// reaches `at_s` (at an arrival, or during the final drain if the instant
/// falls after the last arrival). Events whose node index is out of range
/// are filtered out before the replay consumes the stream; events that
/// would not change their node's state (failing a node already dead at the
/// instant, joining one already alive) are rejected at service
/// construction with a [`MembershipEventError`] naming the node and
/// instant — see [`validate_events`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MembershipEvent {
    /// The node slot the event concerns.
    pub node: usize,
    /// Simulated instant the change applies (clamped to `>= 0` when the
    /// replay consumes it; NaN clamps to 0 rather than never firing).
    pub at_s: f64,
    /// Whether the node fails or joins.
    pub change: MembershipChange,
}

impl MembershipEvent {
    /// Fail `node` at `at_s`.
    pub fn fail(node: usize, at_s: f64) -> MembershipEvent {
        MembershipEvent { node, at_s, change: MembershipChange::Fail }
    }

    /// Join `node` (empty) at `at_s`. When this is the node's first
    /// scheduled event, the node starts outside the cluster.
    pub fn join(node: usize, at_s: f64) -> MembershipEvent {
        MembershipEvent { node, at_s, change: MembershipChange::Join }
    }
}

/// Cluster deployment parameters. `service` holds the *per-node* knobs:
/// `capacity` is each shard's entry budget, `sim_workers` each node's
/// simulated GPU slice, `queue_depth` each node's admission bound;
/// `window` and `threads` stay cluster-global (both are host-speed knobs
/// with no effect on reported numbers).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// The per-node service parameter block.
    pub service: ServiceConfig,
    /// Simulated node slots (clamped to at least 1).
    pub nodes: usize,
    /// The tenant population. `TrafficRequest::tenant` indexes this list
    /// (out-of-range indices clamp to the last tenant).
    pub tenants: Vec<TenantSpec>,
    /// Enforce weighted fair-share quotas under overload. Off by default so
    /// a 1-node, 1-tenant cluster reproduces the single-node service's
    /// admission behaviour exactly (only batch work is shed at the bound).
    pub tenant_quotas: bool,
    /// Simulated seconds to move a kernel between nodes — paid by each
    /// cross-node warm-start seed fetch (on the flight's service time) and
    /// by each entry a join's planned rebalance refills (the refill lands
    /// this long after the join instant).
    pub transfer_latency_s: f64,
    /// Relative speedup margin a *remote* warm-start seed must beat the
    /// best own-shard seed by before the transfer is worth paying: remote
    /// wins only when `remote_speedup > own_speedup * (1 + margin)`.
    /// 0 (the default) prefers the own shard on anything but a strictly
    /// faster remote; negative values are clamped to 0.
    pub warm_locality_margin: f64,
    /// Scheduled membership changes, applied at their simulated instants
    /// in `(at_s, node, change)` order.
    pub events: Vec<MembershipEvent>,
    /// Node slots that start *outside* the cluster (dead) even without a
    /// scheduled join — the autoscaler's headroom: slots a policy may
    /// bring in later. Out-of-range indices are ignored. Empty by default,
    /// so existing configs are unaffected.
    pub initial_dead: Vec<usize>,
    /// Per-node service-time multipliers (the straggler knob): node `i`'s
    /// flights take `node_service_multipliers[i]` times their computed
    /// service time. Missing, non-finite, or non-positive entries mean
    /// `1.0`. Empty by default — and `x * 1.0` is bitwise identity for
    /// finite times, so an empty vector changes nothing.
    pub node_service_multipliers: Vec<f64>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            service: ServiceConfig::default(),
            nodes: 4,
            tenants: vec![TenantSpec::new("default", 1.0)],
            tenant_quotas: false,
            transfer_latency_s: 30.0,
            warm_locality_margin: 0.0,
            events: Vec::new(),
            initial_dead: Vec::new(),
            node_service_multipliers: Vec::new(),
        }
    }
}

impl ClusterConfig {
    /// Node `i`'s effective service-time multiplier: the configured entry
    /// when it is finite and positive, `1.0` otherwise (including when the
    /// vector is shorter than the fleet).
    pub fn node_multiplier(&self, node: usize) -> f64 {
        match self.node_service_multipliers.get(node) {
            Some(&m) if m.is_finite() && m > 0.0 => m,
            _ => 1.0,
        }
    }
}

/// Per-node backlog quota for each tenant: its weight-share of
/// `queue_depth`, floored, but never below one slot (every tenant can make
/// progress). An unbounded queue disables quotas entirely.
pub fn fair_share_quotas(queue_depth: usize, tenants: &[TenantSpec]) -> Vec<usize> {
    let total: f64 = tenants.iter().map(|t| t.weight.max(0.0)).sum();
    tenants
        .iter()
        .map(|t| {
            if queue_depth == usize::MAX || total <= 0.0 {
                usize::MAX
            } else {
                let share = queue_depth as f64 * t.weight.max(0.0) / total;
                (share.floor() as usize).max(1)
            }
        })
        .collect()
}

/// One node's serving-state slice, with its cache-effectiveness and
/// utilization aggregates for the replay.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeReport {
    /// Node slot index.
    pub node: usize,
    /// Whether the node is alive at the end of the replay.
    pub alive: bool,
    /// Requests routed to this node (hits + joins + flights + sheds).
    pub requests: usize,
    /// Requests this shard answered from cache.
    pub cache_hits: u64,
    /// Requests served by joining one of this node's in-flight duplicates.
    pub shared: u64,
    /// Workflow runs this node executed.
    pub flights_run: usize,
    /// Requests this node's admission control shed.
    pub rejected: u64,
    /// Entries this shard evicted under capacity pressure.
    pub evictions: u64,
    /// `(cache_hits + shared) / requests` for this node.
    pub hit_rate: f64,
    /// Busy time / (node workers × node makespan).
    pub utilization: f64,
    /// Deepest flight backlog observed at this node's admission decisions.
    pub peak_queue_depth: usize,
    /// Entries resident in this node's shard after the replay.
    pub cache_entries: usize,
}

/// One tenant's outcome: traffic volume, shed counts, and latency/SLO
/// aggregates (each served request scored against its own priority class's
/// target).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantReport {
    /// Tenant name (from [`ClusterConfig::tenants`]).
    pub tenant: String,
    /// The tenant's fair-share weight.
    pub weight: f64,
    /// Requests this tenant sent.
    pub requests: usize,
    /// Requests that got an answer (requests − rejected).
    pub served: usize,
    /// All sheds of this tenant's traffic (batch overload + quota).
    pub rejected: u64,
    /// The subset of `rejected` shed specifically by this tenant exceeding
    /// its fair-share quota.
    pub quota_shed: u64,
    /// The subset of `rejected` throttled by the front-door token bucket
    /// (shed reason `rate`; 0 with the limiter off).
    pub throttled: u64,
    /// Deepest flight backlog this tenant held on any single node (max over
    /// nodes of the per-node per-tenant peak, so `max over tenants <=` the
    /// cluster's `peak_queue_depth` `<= sum over tenants`).
    pub peak_queue_depth: usize,
    /// Median latency over this tenant's served requests, seconds.
    pub p50_latency_s: f64,
    /// 95th-percentile latency over this tenant's served requests, seconds.
    pub p95_latency_s: f64,
    /// 99th-percentile latency over this tenant's served requests, seconds.
    pub p99_latency_s: f64,
    /// Fraction of served requests within their priority class's SLO
    /// target (1.0 when nothing was served — a vacuous SLO holds).
    pub slo_attainment: f64,
}

/// Why keys moved between shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RebalanceKind {
    /// A node dropped out mid-replay: its shard was lost.
    NodeFailure,
    /// A node joined (empty) mid-replay: its keys moved back to it as a
    /// planned rebalance.
    NodeJoin,
    /// A snapshot was restored under a membership its manifest did not
    /// describe (different node count, or entries mis-placed relative to
    /// the initial membership), so keys rehashed at restore time.
    SnapshotRestore,
}

/// What one rebalance — a failure, a join, or a snapshot restore under
/// changed membership — cost.
#[derive(Clone, Debug, PartialEq)]
pub struct RebalanceReport {
    /// What triggered the movement.
    pub kind: RebalanceKind,
    /// The failed/joined node; for [`RebalanceKind::SnapshotRestore`], the
    /// node count the snapshot was laid out for.
    pub node: usize,
    /// Simulated instant the event applied (0 for a restore, which happens
    /// before the replay's clock starts).
    pub at_s: f64,
    /// Cache entries lost outright — a failure loses its whole shard plus
    /// any refills still in transit to it; a restore loses entries when no
    /// alive node can own them, or when the rehash overflows a target
    /// shard's capacity.
    pub cache_entries_lost: usize,
    /// Entries moved between shards (a join's planned refill, or a
    /// restore's rehash) rather than lost.
    pub entries_moved: usize,
    /// Total simulated transfer seconds those moves spent
    /// (`entries_moved × transfer_latency_s`).
    pub transfer_s: f64,
    /// Requests displaced by this event: traffic the dead node would have
    /// owned (failure), or traffic the joined node now owns (join).
    pub rehashed_requests: usize,
    /// Flights opened to re-run work this event made unreachable — a lost
    /// key coming back cold, or a moved key requested inside its transfer
    /// gap.
    pub remissed_flights: usize,
    /// API dollars those re-runs spent — work the cluster had already paid
    /// for once.
    pub remiss_api_usd: f64,
}

/// Everything a cluster replay reports. `overall` is shaped exactly like
/// the single-node report (and *is* that report, bit for bit, for a 1-node
/// single-tenant cluster); the per-node / per-tenant / rebalance views are
/// what the sharded deployment adds.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterReport {
    /// The cluster-wide aggregates, shaped like a single-node report.
    pub overall: ServiceReport,
    /// Node slots in the deployment.
    pub nodes: usize,
    /// Rendezvous epoch after the replay: membership changes applied over
    /// the cluster's lifetime, including history a snapshot restore
    /// resumed.
    pub epoch: u64,
    /// Per-node serving/caching breakdown.
    pub per_node: Vec<NodeReport>,
    /// Per-tenant traffic/SLO/shedding breakdown.
    pub per_tenant: Vec<TenantReport>,
    /// Executed misses that warm-started from an entry owned by a
    /// *different* node (each paid `transfer_latency_s`).
    pub cross_node_warm: usize,
    /// Alive-node-hours integrated over the replay's simulated span (from
    /// t = 0 to the fleet makespan, membership changes applied at their
    /// instants) — the fleet-sizing cost axis of the autoscaling frontier.
    /// A 4-node cluster alive for a 2-hour replay spends 8 node-hours
    /// whether or not its workers were busy.
    pub node_hours: f64,
    /// Total quota-exceeded sheds across tenants.
    pub quota_shed: u64,
    /// One entry per rebalance, in event order. The first replay after a
    /// [`ClusterService::restore`] that moved keys leads with that
    /// restore's movement; membership events applied during the replay
    /// follow.
    pub rebalances: Vec<RebalanceReport>,
}

/// The locality decision [`warm_choice_across`] made, with the numbers the
/// margin comparison ran on — exactly what the flight recorder's
/// `warm.lookup` event narrates.
struct WarmChoice<'c> {
    /// The winning candidate and its owning node (`None`: run cold).
    pick: Option<(usize, &'c CacheEntry)>,
    /// Best own-shard candidate's speedup, when the own shard had one.
    own_speedup: Option<f64>,
    /// Best remote candidate `(node, speedup)`, when any alive remote
    /// shard had one.
    remote: Option<(usize, f64)>,
}

/// Locality-aware warm-start pick across every *alive* shard, with the
/// owning node (a dead node's entries are unreachable, not warm-start
/// donors). The best candidate on the requester's own shard (`own`) wins
/// unless the best remote candidate beats it by more than
/// `locality_margin` (relative speedup) — fetching a marginally better
/// seed is not worth the transfer. Remote ties break on
/// (speedup, fingerprint, node) so the scan order can never change the
/// pick.
fn warm_choice_across<'c>(
    caches: &'c [ResultCache],
    c: &ServiceConfig,
    task_id: &str,
    gpu_key: &str,
    alive: &[bool],
    own: usize,
    locality_margin: f64,
) -> WarmChoice<'c> {
    let probe = |cache: &'c ResultCache| {
        cache.warm_candidate(task_id, gpu_key, c.strategy.name(), c.coder.name, c.judge.name)
    };
    let own_best = if alive.get(own).copied().unwrap_or(false) {
        probe(&caches[own])
    } else {
        None
    };
    let mut remote: Option<(usize, &CacheEntry)> = None;
    for (node, cache) in caches.iter().enumerate() {
        if node == own || !alive.get(node).copied().unwrap_or(false) {
            continue;
        }
        if let Some(e) = probe(cache) {
            let better = match remote {
                None => true,
                Some((bn, b)) => e
                    .best_speedup
                    .total_cmp(&b.best_speedup)
                    .then_with(|| e.fingerprint.cmp(&b.fingerprint))
                    .then_with(|| node.cmp(&bn))
                    .is_gt(),
            };
            if better {
                remote = Some((node, e));
            }
        }
    }
    let own_speedup = own_best.map(|e| e.best_speedup);
    let remote_info = remote.map(|(n, e)| (n, e.best_speedup));
    let pick = match (own_best, remote) {
        (None, None) => None,
        (Some(o), None) => Some((own, o)),
        (None, Some(r)) => Some(r),
        (Some(o), Some((rn, r))) => {
            if r.best_speedup > o.best_speedup * (1.0 + locality_margin.max(0.0)) {
                Some((rn, r))
            } else {
                Some((own, o))
            }
        }
    };
    WarmChoice { pick, own_speedup, remote: remote_info }
}

/// [`warm_choice_across`] reduced to the winning candidate — what the
/// speculation predictor (which never emits events) needs.
fn warm_candidate_across<'c>(
    caches: &'c [ResultCache],
    c: &ServiceConfig,
    task_id: &str,
    gpu_key: &str,
    alive: &[bool],
    own: usize,
    locality_margin: f64,
) -> Option<(usize, &'c CacheEntry)> {
    warm_choice_across(caches, c, task_id, gpu_key, alive, own, locality_margin).pick
}

/// Per-node admission/serving counters for one replay.
struct NodeCounters {
    requests: usize,
    hits: u64,
    shared: u64,
    flights_run: usize,
    rejected: u64,
    peak_depth: usize,
    /// Flights opened but not yet started, per tenant — the fair-share
    /// quota meter (the slot is released when the flight starts on a
    /// worker).
    backlog_by_tenant: Vec<usize>,
    /// Deepest per-tenant backlog observed at this node (sampled at each
    /// submit, right after the slot is taken) — the per-tenant split of
    /// `peak_depth`, so tenant report rows reconcile with node rows.
    peak_backlog_by_tenant: Vec<usize>,
    /// This node's cache eviction counter at replay start (delta basis).
    evictions0: u64,
    /// Evictions accumulated before the cache shard was dropped by a
    /// failure event (the replacement cache restarts its counter).
    evictions_carry: u64,
}

/// A rebalance being accounted during the replay: its report plus the keys
/// it made temporarily unreachable (lost by a failure, or in transit during
/// a join's refill). A new flight opened for a tracked key is that
/// rebalance's re-miss; a refill landing un-tracks its key.
struct ActiveRebalance {
    report: RebalanceReport,
    tracked: BTreeSet<Fingerprint>,
}

/// The cluster replay context. Implements [`FleetHooks`] for whichever node
/// fleet is currently stepping (`node` is set by the global event loop):
/// start events pick the warm seed across alive shards at event-time state,
/// completion events apply side effects via the accounting helper shared
/// with the single-node replay.
struct ClusterHooks<'a, 'o> {
    config: &'a ClusterConfig,
    trace: &'a [TrafficRequest],
    tasks: &'a [TaskSpec],
    oracle: &'a dyn CorrectnessOracle,
    router: Router,
    caches: &'a mut Vec<ResultCache>,
    cold_cost: &'a mut BTreeMap<Fingerprint, f64>,
    stats: ReplayStats,
    memo: RunMemo,
    pending: BTreeMap<u64, PendingRun>,
    /// Causality audit: the completion (or refill-landing) instant of each
    /// fingerprint's producing event *this replay* (absent = resident
    /// before it started).
    visible_at: BTreeMap<Fingerprint, f64>,
    per_node: Vec<NodeCounters>,
    membership: Membership,
    /// The node whose fleet is currently stepping.
    node: usize,
    cross_node_warm: usize,
    rebalances: Vec<ActiveRebalance>,
    /// Tracked keys whose re-run flight is open: fingerprint → index into
    /// `rebalances`, settled (remiss counted, spend added) at completion.
    remiss_open: BTreeMap<Fingerprint, usize>,
    /// Planned-rebalance refills in transit: `(landing bits, seq)` →
    /// `(destination node, source node, entry)`. Fired by the global event
    /// loop in timestamp order, before fleet events at the same instant.
    pending_refills: BTreeMap<(u64, u64), (usize, usize, CacheEntry)>,
    refill_seq: u64,
    /// The global event heap over node fleets: min-heap entries
    /// `(t bits, kind, node, version)` with kind 1 = completion, 2 = start —
    /// the same `(t, kind, node)` total order the per-node linear scan used,
    /// minus the O(nodes) scan per event. Entries are validated lazily: one
    /// is current iff its version stamp still equals its fleet's mutation
    /// counter ([`FleetSim::version`]); stale entries (the fleet mutated
    /// since the push) are popped and the fleet re-armed on sight. Every
    /// fleet mutation site pushes a fresh entry, so the current next event
    /// of every non-idle fleet is always represented.
    event_heap: BinaryHeap<Reverse<(u64, u8, u32, u64)>>,
    /// Alive-node-seconds accrued so far (piecewise-constant integral of
    /// the alive count over simulated time, advanced at each membership
    /// change and closed out at the fleet makespan).
    node_seconds: f64,
    /// The instant `node_seconds` is accrued up to.
    node_seconds_at: f64,
    /// The flight recorder. Every emission below happens on the
    /// deterministic event-loop path, at a simulated instant — never from
    /// the speculative OS-thread pool.
    obs: &'a mut Observer<'o>,
}

impl ClusterHooks<'_, '_> {
    /// Advance the alive-node-seconds integral to `now` at the *current*
    /// alive count. Called with each membership event's instant before the
    /// change applies (the interval up to the event bills at the old fleet
    /// size) and with the fleet makespan at the end of the replay.
    fn accrue_node_seconds(&mut self, now: f64) {
        let dt = (now - self.node_seconds_at).max(0.0);
        self.node_seconds += self.membership.alive_count() as f64 * dt;
        self.node_seconds_at = self.node_seconds_at.max(now);
    }

    /// Push node `ni`'s current next event onto the global heap, stamped
    /// with the fleet's mutation counter. Must be called after every fleet
    /// mutation (submit, join, fired step) so the heap always holds a
    /// current entry for each non-idle fleet; duplicate pushes at the same
    /// version are identical tuples and harmless.
    fn arm_fleet(&mut self, fleets: &[FleetSim], ni: usize) {
        if let Some((t, is_completion)) = fleets[ni].next_event() {
            let kind = if is_completion { 1 } else { 2 };
            self.event_heap
                .push(Reverse((t.to_bits(), kind, ni as u32, fleets[ni].version())));
        }
    }
}

impl ClusterHooks<'_, '_> {
    /// Count this arrival against every rebalance that displaced it: a
    /// failure displaces requests its dead node would own were it alive; a
    /// join displaces requests its node now owns (pre-join they routed to a
    /// survivor). Restores count nothing (their movement is fully planned,
    /// before traffic).
    fn count_rehashed(&mut self, fp: Fingerprint) {
        let membership = &self.membership;
        let router = self.router;
        for rb in self.rebalances.iter_mut() {
            let node = rb.report.node;
            let displaced = match rb.report.kind {
                RebalanceKind::NodeFailure => {
                    if membership.is_alive(node) {
                        false // it rejoined since; nothing is displaced now
                    } else {
                        let mut revived = membership.alive().to_vec();
                        revived[node] = true;
                        router.route(fp, &revived) == Some(node)
                    }
                }
                RebalanceKind::NodeJoin => {
                    membership.is_alive(node)
                        && router.route(fp, membership.alive()) == Some(node)
                }
                RebalanceKind::SnapshotRestore => false,
            };
            if displaced {
                rb.report.rehashed_requests += 1;
            }
        }
    }

    /// If `fp` is a key some rebalance made unreachable, charge the new
    /// flight being opened for it to that rebalance (settled at the
    /// flight's completion).
    fn charge_if_tracked(&mut self, fp: Fingerprint) {
        if let Some(idx) = self.rebalances.iter().position(|rb| rb.tracked.contains(&fp)) {
            self.rebalances[idx].tracked.remove(&fp);
            self.remiss_open.insert(fp, idx);
        }
    }
}

impl FleetHooks for ClusterHooks<'_, '_> {
    fn on_start(&mut self, flight: &SimFlight, start_s: f64, fair: DispatchSnapshot) -> f64 {
        let req = &self.trace[flight.leader_seq as usize];
        let task = &self.tasks[req.task_index];
        let c = &self.config.service;
        let node = self.node;
        // The flight leaves the backlog: release its tenant's quota slot.
        let nc = &mut self.per_node[self.node];
        nc.backlog_by_tenant[flight.tenant] =
            nc.backlog_by_tenant[flight.tenant].saturating_sub(1);
        let base = c.base_workflow(req.gpu);
        self.obs.enter(Stage::WarmLookup);
        let choice = warm_choice_across(
            self.caches,
            c,
            &task.id(),
            req.gpu.key,
            self.membership.alive(),
            self.node,
            self.config.warm_locality_margin,
        );
        self.obs.exit(Stage::WarmLookup);
        let fp = flight.fingerprint;
        let leader = flight.leader_seq;
        let margin = self.config.warm_locality_margin;
        // Owned copies of what the emission needs, so the shard borrow can
        // end before the event closure runs. Built only when a sink is
        // recording: the untraced hot path must not pay the fingerprint
        // Display round-trip or the gpu-key clone (the hex form is rendered
        // at most once per event, inside the closure below).
        let own_speedup = choice.own_speedup;
        let remote = choice.remote;
        let pick_info: Option<(usize, f64, Fingerprint, String)> = if self.obs.enabled() {
            choice
                .pick
                .map(|(owner, e)| (owner, e.best_speedup, e.fingerprint, e.gpu_key.clone()))
        } else {
            None
        };
        let (wf, cross) = match choice.pick {
            Some((owner, entry)) => {
                // The causality contract: a warm seed's producing flight —
                // on any node — completed no later than this start.
                if let Some(done) = self.visible_at.get(&entry.fingerprint) {
                    debug_assert!(
                        *done <= start_s,
                        "warm seed {} completes at {done} > consumer start {start_s}",
                        entry.fingerprint,
                    );
                }
                (c.warm_start_from(base, entry), owner != self.node)
            }
            None => (base, false),
        };
        self.obs.emit(|| {
            let ev = TraceEvent::new(start_s, "warm.lookup", node)
                .field("fp", Json::str(fp.to_string()))
                .field("leader_seq", Json::num(leader as f64));
            let Some((owner, speedup, source_fp, source_gpu)) = pick_info else {
                return ev.field("picked", Json::str("none"));
            };
            if owner != node {
                // Remote wins: the margin inequality held, transfer billed.
                return ev
                    .field("picked", Json::str("remote"))
                    .field("own_speedup", Json::num(own_speedup.unwrap_or(0.0)))
                    .field("remote_node", Json::num(owner as f64))
                    .field("remote_speedup", Json::num(speedup))
                    .field("margin", Json::num(margin))
                    .field("source_fp", Json::str(source_fp.to_string()))
                    .field("source_gpu", Json::str(source_gpu));
            }
            let ev =
                ev.field("picked", Json::str("own")).field("own_speedup", Json::num(speedup));
            match remote {
                // Own wins against a measured remote: record the losing
                // side so the margin arithmetic can be replayed.
                Some((rn, rs)) => ev
                    .field("remote_node", Json::num(rn as f64))
                    .field("remote_speedup", Json::num(rs))
                    .field("margin", Json::num(margin)),
                None => ev
                    .field("source_fp", Json::str(source_fp.to_string()))
                    .field("source_gpu", Json::str(source_gpu)),
            }
        });
        if cross {
            self.cross_node_warm += 1;
        }
        self.obs.enter(Stage::Workflow);
        let result = match self.memo.take(flight.fingerprint, &wf.warm_start) {
            Some(r) => r,
            // Speculation missed: run inline with the true event-time
            // workflow.
            None => run_task(&wf, task, self.oracle),
        };
        self.obs.exit(Stage::Workflow);
        // A cross-node seed is fetched before the run starts: the transfer
        // rides on the flight's service time.
        let service_s = result.ledger.wall_s
            + if cross { self.config.transfer_latency_s } else { 0.0 };
        let warm = wf.warm_start.is_some();
        let members = flight.members.len();
        let tenant = flight.tenant;
        self.obs.emit(|| {
            TraceEvent::new(start_s, "flight.start", node)
                .field("fp", Json::str(fp.to_string()))
                .field("leader_seq", Json::num(leader as f64))
                .field("service_s", Json::num(service_s))
                .field("warm", Json::Bool(warm))
                .field("cross_node", Json::Bool(cross))
                .field("members", Json::num(members as f64))
                .field("tenant", Json::num(tenant as f64))
                .field("deficit", Json::num(fair.deficit_s))
                .field("vtime", Json::num(fair.vtime_s))
                .field("weight", Json::num(fair.weight))
        });
        self.pending.insert(flight.leader_seq, PendingRun { result, warm });
        service_s
    }

    fn on_complete(&mut self, flight: &SimFlight, done: SimCompletion) {
        let run = self
            .pending
            .remove(&flight.leader_seq)
            .expect("a completion follows its start");
        let req = &self.trace[flight.leader_seq as usize];
        let task = &self.tasks[req.task_index];
        let node = self.node;
        let lint_saved = run.result.lint.checks_saved;
        let correct = run.result.correct;
        let entry = settle_flight_completion(
            &self.config.service,
            &mut self.stats,
            self.cold_cost,
            task,
            req.gpu.key,
            flight,
            done,
            run.warm,
            &run.result,
        );
        let nc = &mut self.per_node[self.node];
        nc.flights_run += 1;
        nc.shared += (flight.members.len() - 1) as u64;
        let cached = entry.is_some();
        self.obs.emit(|| flight_complete_event(node, flight, done, run.warm, correct, cached));
        if lint_saved > 0 {
            let fp = flight.fingerprint;
            let leader = flight.leader_seq;
            self.obs.emit(|| {
                TraceEvent::new(done.completion_s, "lint.short_circuit", node)
                    .field("fp", Json::str(fp.to_string()))
                    .field("leader_seq", Json::num(leader as f64))
                    .field("checks_saved", Json::num(lint_saved as f64))
            });
        }
        // A flight opened to re-run work a failure lost (or a rebalance had
        // in transit) settles that rebalance's re-miss bill here, at its
        // own completion instant.
        if let Some(idx) = self.remiss_open.remove(&flight.fingerprint) {
            let rb = &mut self.rebalances[idx].report;
            rb.remissed_flights += 1;
            rb.remiss_api_usd += run.result.ledger.api_usd;
        }
        // The result refills the shard that owns the key *now*: a draining
        // dead node's flight still answers its members, and its result
        // ships to the key's surviving (or newly joined) owner instead of
        // dying with the unreachable shard. When the owner changed while
        // the flight ran (a membership event mid-flight), the result
        // crosses nodes like any other kernel — it lands one transfer
        // latency after the completion, through the same refill machinery,
        // never instantly.
        if let Some(e) = entry {
            if let Some(owner) = self.router.route(e.fingerprint, self.membership.alive()) {
                if owner == self.node {
                    self.visible_at.insert(e.fingerprint, done.completion_s);
                    if let Some(evicted) = self.caches[owner].insert(e) {
                        self.obs.emit(|| {
                            TraceEvent::new(done.completion_s, "cache.evict", owner)
                                .field("fp", Json::str(evicted.to_string()))
                        });
                    }
                } else {
                    let land_at = done.completion_s + self.config.transfer_latency_s;
                    self.refill_seq += 1;
                    self.pending_refills
                        .insert((land_at.to_bits(), self.refill_seq), (owner, self.node, e));
                }
            }
        }
    }
}

/// Fire every refill landing, start, and completion due by `now` across all
/// node fleets, in global timestamp order — refill landings before fleet
/// events at equal instants, then completions before starts, then node
/// index — so a flight starting on node A at instant `t` observes exactly
/// the side effects of every flight completed, and every transfer landed,
/// by `t`.
///
/// Fleet events come from the persistent global heap
/// (`ClusterHooks::event_heap`), not a per-event scan over every node:
/// selecting the next event is O(log events) however many nodes the
/// cluster has. The heap key `(t bits, kind, node)` is exactly the total
/// order the old scan minimized over (`f64::to_bits` orders like the value
/// for the non-negative finite instants the simulation produces), so the
/// firing sequence — and therefore every reported number — is unchanged.
fn advance_cluster(fleets: &mut [FleetSim], now: f64, hooks: &mut ClusterHooks<'_, '_>) {
    loop {
        // Validate the heap top lazily: an entry is current iff its version
        // stamp still equals its fleet's mutation counter. A stale entry is
        // discarded and its fleet re-armed (at most one stale entry dies
        // per iteration, so the loop terminates).
        let fleet_best = loop {
            match hooks.event_heap.peek() {
                None => break None,
                Some(&Reverse((bits, kind, ni, version))) => {
                    if fleets[ni as usize].version() == version {
                        break Some((bits, kind, ni));
                    }
                    hooks.event_heap.pop();
                    hooks.arm_fleet(fleets, ni as usize);
                }
            }
        };
        let refill_bits = hooks.pending_refills.first_key_value().map(|((bits, _), _)| *bits);
        // kind 0 = refill landing, 1 = completion, 2 = start: a refill at
        // an instant beats any fleet event at the same instant.
        let (t_bits, fire_fleet) = match (refill_bits, fleet_best) {
            (None, None) => break,
            (Some(rb), None) => (rb, None),
            (None, Some((bits, _, ni))) => (bits, Some(ni)),
            (Some(rb), Some((bits, kind, ni))) => {
                if (rb, 0u8) <= (bits, kind) {
                    (rb, None)
                } else {
                    (bits, Some(ni))
                }
            }
        };
        if f64::from_bits(t_bits) > now {
            break;
        }
        match fire_fleet {
            None => {
                let ((bits, _), (node, from, entry)) = hooks
                    .pending_refills
                    .pop_first()
                    .expect("the peeked refill is resident");
                let fp = entry.fingerprint;
                // The transfer completed: the key is no longer re-missable.
                for rb in hooks.rebalances.iter_mut() {
                    rb.tracked.remove(&fp);
                }
                if hooks.membership.is_alive(node) {
                    let at = f64::from_bits(bits);
                    hooks.visible_at.insert(fp, at);
                    hooks.obs.emit(|| {
                        TraceEvent::new(at, "cache.refill", node)
                            .field("fp", Json::str(fp.to_string()))
                            .field("from_node", Json::num(from as f64))
                    });
                    if let Some(evicted) = hooks.caches[node].insert(entry) {
                        hooks.obs.emit(|| {
                            TraceEvent::new(at, "cache.evict", node)
                                .field("fp", Json::str(evicted.to_string()))
                        });
                    }
                }
            }
            Some(ni) => {
                let ni = ni as usize;
                hooks.event_heap.pop();
                hooks.node = ni;
                let fired = fleets[ni].step(now, &mut *hooks);
                debug_assert!(fired, "the peeked event fires");
                hooks.arm_fleet(fleets, ni);
            }
        }
    }
}

/// Drop `ev.node`'s shard: entries are lost (and tracked so their re-runs
/// are billed to this failure), accepted work keeps draining, refills in
/// transit to the dead node die with it. A no-op when the node is already
/// dead or out of range.
fn apply_failure(config: &ClusterConfig, ev: MembershipEvent, hooks: &mut ClusterHooks<'_, '_>) {
    if !hooks.membership.set_alive(ev.node, false) {
        return;
    }
    let mut lost: BTreeSet<Fingerprint> = hooks.caches[ev.node]
        .entries_coldest_first()
        .map(|e| e.fingerprint)
        .collect();
    // Refills still in transit to the dying node are destroyed with it:
    // they are resident nowhere, so they count among this failure's losses,
    // and their eventual re-runs bill the failure — not the join that
    // moved them.
    hooks.pending_refills.retain(|_, (node, _, entry)| {
        if *node == ev.node {
            lost.insert(entry.fingerprint);
            false
        } else {
            true
        }
    });
    // A key is tracked by at most one rebalance: take the destroyed
    // transit keys away from their join before this failure claims them.
    for rb in hooks.rebalances.iter_mut() {
        for fp in &lost {
            rb.tracked.remove(fp);
        }
    }
    let carry = hooks.caches[ev.node].stats.evictions;
    hooks.caches[ev.node] = ResultCache::new(config.service.capacity);
    let nc = &mut hooks.per_node[ev.node];
    nc.evictions_carry += carry - nc.evictions0;
    nc.evictions0 = 0;
    let lost_n = lost.len();
    hooks.obs.emit(|| {
        TraceEvent::new(ev.at_s, "membership.fail", ev.node)
            .field("entries_lost", Json::num(lost_n as f64))
    });
    hooks.rebalances.push(ActiveRebalance {
        report: RebalanceReport {
            kind: RebalanceKind::NodeFailure,
            node: ev.node,
            at_s: ev.at_s,
            cache_entries_lost: lost.len(),
            entries_moved: 0,
            transfer_s: 0.0,
            rehashed_requests: 0,
            remissed_flights: 0,
            remiss_api_usd: 0.0,
        },
        tracked: lost,
    });
}

/// Bring `ev.node` (back) in, empty, and start the planned rebalance: every
/// surviving-shard entry whose key the newcomer now owns is moved out
/// immediately and lands on the joined node one transfer latency later.
/// Until a key's refill lands it is tracked — a request for it in the gap
/// re-misses, billed to this join. A no-op when the node is already alive
/// or out of range.
fn apply_join(config: &ClusterConfig, ev: MembershipEvent, hooks: &mut ClusterHooks<'_, '_>) {
    if !hooks.membership.set_alive(ev.node, true) {
        return;
    }
    let alive: Vec<bool> = hooks.membership.alive().to_vec();
    let router = hooks.router;
    let land_at = ev.at_s + config.transfer_latency_s.max(0.0);
    let mut tracked = BTreeSet::new();
    let mut moved = 0usize;
    for ni in 0..hooks.caches.len() {
        if ni == ev.node || !alive[ni] {
            continue;
        }
        let owned: Vec<Fingerprint> = hooks.caches[ni]
            .entries_coldest_first()
            .filter(|e| router.route(e.fingerprint, &alive) == Some(ev.node))
            .map(|e| e.fingerprint)
            .collect();
        for fp in owned {
            if let Some(entry) = hooks.caches[ni].remove(fp) {
                hooks.refill_seq += 1;
                hooks
                    .pending_refills
                    .insert((land_at.to_bits(), hooks.refill_seq), (ev.node, ni, entry));
                tracked.insert(fp);
                moved += 1;
            }
        }
    }
    hooks.obs.emit(|| {
        TraceEvent::new(ev.at_s, "membership.join", ev.node)
            .field("entries_moved", Json::num(moved as f64))
            .field("lands_at_s", Json::num(land_at))
    });
    hooks.rebalances.push(ActiveRebalance {
        report: RebalanceReport {
            kind: RebalanceKind::NodeJoin,
            node: ev.node,
            at_s: ev.at_s,
            cache_entries_lost: 0,
            entries_moved: moved,
            transfer_s: moved as f64 * config.transfer_latency_s.max(0.0),
            rehashed_requests: 0,
            remissed_flights: 0,
            remiss_api_usd: 0.0,
        },
        tracked,
    });
}

/// Apply every scheduled membership event due by `now`, each at its own
/// instant: everything due strictly by the event instant fires first (the
/// shard is alive for those events), then the change lands. Consulted at
/// every arrival *and* before the final drain, so an event past the last
/// arrival still fires.
fn apply_membership_due(
    events: &[MembershipEvent],
    next: &mut usize,
    config: &ClusterConfig,
    now: f64,
    fleets: &mut [FleetSim],
    hooks: &mut ClusterHooks<'_, '_>,
) {
    while *next < events.len() && events[*next].at_s <= now {
        let ev = events[*next];
        *next += 1;
        advance_cluster(fleets, ev.at_s, hooks);
        // Node-hours up to this instant bill at the pre-change fleet size.
        hooks.accrue_node_seconds(ev.at_s);
        match ev.change {
            MembershipChange::Fail => apply_failure(config, ev, hooks),
            MembershipChange::Join => apply_join(config, ev, hooks),
        }
    }
}

/// Insert `ev` into the due-sorted tail of `events` (positions `from..`),
/// preserving the replay's `(at_s, node, change)` order. Used by the
/// autoscaling loop: a policy's events always land at or after the tick
/// that decided them, so the already-consumed prefix (`..from`) never needs
/// to move.
fn insert_sorted_event(events: &mut Vec<MembershipEvent>, from: usize, ev: MembershipEvent) {
    debug_assert!(from <= events.len());
    let offset = events[from..].partition_point(|e| {
        e.at_s
            .total_cmp(&ev.at_s)
            .then(e.node.cmp(&ev.node))
            .then(e.change.cmp(&ev.change))
            .is_le()
    });
    events.insert(from + offset, ev);
}

/// Requests `(served, slo_ok)` so far: how many of the trace's requests
/// have a recorded latency, and how many of those met their priority
/// class's SLO target. The autoscaling tick signals are deltas of these.
fn slo_counts(
    trace: &[TrafficRequest],
    latencies: &[Option<f64>],
    slo: &crate::service::SloTargets,
) -> (u64, u64) {
    let mut served = 0u64;
    let mut ok = 0u64;
    for (req, lat) in trace.iter().zip(latencies) {
        if let Some(l) = lat {
            served += 1;
            if *l <= slo.target_s(req.priority) {
                ok += 1;
            }
        }
    }
    (served, ok)
}

/// Clamp/normalize a config the way every constructor needs it.
fn normalized(mut config: ClusterConfig) -> ClusterConfig {
    config.nodes = config.nodes.max(1);
    if config.tenants.is_empty() {
        config.tenants.push(TenantSpec::new("default", 1.0));
    }
    // f64::max sends NaN to 0 too, so a poisoned latency or margin cannot
    // produce NaN completion instants (which would never fire as events).
    config.warm_locality_margin = config.warm_locality_margin.max(0.0);
    config.transfer_latency_s = config.transfer_latency_s.max(0.0);
    // Out-of-range dead slots are meaningless; duplicates would double-count
    // nothing but make the list confusing to report.
    let nodes = config.nodes;
    config.initial_dead.retain(|n| *n < nodes);
    config.initial_dead.sort_unstable();
    config.initial_dead.dedup();
    config
}

/// Sorted copy of the config's in-range membership events, instants
/// clamped to `>= 0` (`f64::max` sends NaN to 0 as well — a poisoned
/// instant must fire at the epoch start, not silently never).
fn sorted_events(config: &ClusterConfig) -> Vec<MembershipEvent> {
    let mut events: Vec<MembershipEvent> = config
        .events
        .iter()
        .copied()
        .filter(|e| e.node < config.nodes)
        .map(|mut e| {
            e.at_s = e.at_s.max(0.0);
            e
        })
        .collect();
    events.sort_by(|a, b| {
        a.at_s
            .total_cmp(&b.at_s)
            .then(a.node.cmp(&b.node))
            .then(a.change.cmp(&b.change))
    });
    events
}

/// The membership a cluster starts from at `epoch`: every slot alive,
/// except [`ClusterConfig::initial_dead`] slots and nodes whose *first*
/// scheduled event is a join — they start outside the cluster, entering at
/// their event's (or the autoscaler's) instant.
fn initial_membership(config: &ClusterConfig, epoch: u64) -> Membership {
    let mut first: BTreeMap<usize, MembershipChange> = BTreeMap::new();
    for ev in sorted_events(config) {
        first.entry(ev.node).or_insert(ev.change);
    }
    let start_dead: Vec<usize> = first
        .into_iter()
        .filter(|(_, c)| *c == MembershipChange::Join)
        .map(|(n, _)| n)
        .chain(config.initial_dead.iter().copied().filter(|n| *n < config.nodes))
        .collect();
    Membership::with_dead(config.nodes, &start_dead, epoch)
}

/// Structured rejection of an inconsistent membership-event stream: the
/// offending event's node, instant, and direction. Produced by
/// [`validate_events`] when a scheduled event would not change its node's
/// state — a symptom the schedule was written against a different starting
/// membership than the one the cluster actually has.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MembershipEventError {
    /// The node the invalid event targets.
    pub node: usize,
    /// The (clamped) instant the invalid event is scheduled at.
    pub at_s: f64,
    /// What the invalid event tried to do.
    pub change: MembershipChange,
}

impl std::fmt::Display for MembershipEventError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (verb, state) = match self.change {
            MembershipChange::Fail => ("fail", "dead"),
            MembershipChange::Join => ("join", "alive"),
        };
        write!(
            f,
            "membership event stream invalid: {verb} of node {} at t={}s, but node {} is already {state} at that instant",
            self.node, self.at_s, self.node
        )
    }
}

impl std::error::Error for MembershipEventError {}

/// Check the config's membership-event stream for consistency: walking the
/// in-range events in replay order from the starting membership, every
/// event must actually flip its node's state. The first event that would
/// fail an already-dead node or join an already-alive one is returned as a
/// [`MembershipEventError`]. Out-of-range events are outside the stream
/// (the replay filters them) and cannot invalidate it.
pub fn validate_events(config: &ClusterConfig) -> Result<(), MembershipEventError> {
    let config = normalized(config.clone());
    let membership = initial_membership(&config, 0);
    let mut alive: Vec<bool> = membership.alive().to_vec();
    for ev in sorted_events(&config) {
        let target_alive = ev.change == MembershipChange::Join;
        if alive[ev.node] == target_alive {
            return Err(MembershipEventError { node: ev.node, at_s: ev.at_s, change: ev.change });
        }
        alive[ev.node] = target_alive;
    }
    Ok(())
}

/// The long-lived cluster: a router plus N cache shards, the cluster-wide
/// cold-cost registry (counterfactual pricing is a property of
/// fingerprints, not of which shard served them), and the membership whose
/// epoch versions it all.
pub struct ClusterService {
    /// The deployment parameters the service was built with.
    pub config: ClusterConfig,
    router: Router,
    caches: Vec<ResultCache>,
    cold_cost: BTreeMap<Fingerprint, f64>,
    membership: Membership,
    /// A restore-time rebalance not yet surfaced in a replay report: the
    /// first replay after [`ClusterService::restore`] leads with it.
    restore_rebalance: Option<RebalanceReport>,
}

impl ClusterService {
    /// A cold cluster under `config` (normalized: at least one node and one
    /// tenant, non-negative locality margin). Panics when the scheduled
    /// membership-event stream is inconsistent — use
    /// [`ClusterService::try_new`] to handle that as a value.
    pub fn new(config: ClusterConfig) -> ClusterService {
        ClusterService::try_new(config).unwrap_or_else(|e| panic!("{e}"))
    }

    /// A cold cluster under `config`, or the [`MembershipEventError`]
    /// explaining which scheduled event contradicts the starting membership
    /// (failing an already-dead node / joining an already-alive one).
    pub fn try_new(config: ClusterConfig) -> Result<ClusterService, MembershipEventError> {
        let config = normalized(config);
        validate_events(&config)?;
        let caches = (0..config.nodes)
            .map(|_| ResultCache::new(config.service.capacity))
            .collect();
        let router = Router::new(config.nodes);
        let membership = initial_membership(&config, 0);
        Ok(ClusterService {
            config,
            router,
            caches,
            cold_cost: BTreeMap::new(),
            membership,
            restore_rebalance: None,
        })
    }

    /// The stateless rendezvous router.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Node `n`'s cache shard (introspection/tests).
    pub fn cache(&self, n: usize) -> &ResultCache {
        &self.caches[n]
    }

    /// The current membership (alive set + epoch).
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// Rendezvous epoch of the current membership.
    pub fn epoch(&self) -> u64 {
        self.membership.epoch()
    }

    /// Persist the cluster — every shard, the cold-cost registry, and a
    /// manifest declaring the epoch and node count — into `dir` (created if
    /// absent; see [`crate::cluster::snapshot`] for the layout). Returns
    /// the manifest that was written.
    pub fn snapshot(&self, dir: impl AsRef<Path>) -> Result<snapshot::Manifest> {
        snapshot::save(dir, &self.caches, &self.cold_cost, self.membership.epoch())
    }

    /// Rebuild a warm cluster from a snapshot directory. With the manifest's
    /// node count and an all-alive initial membership, shards load exactly
    /// as saved and the restored cluster replays bit-identically to the one
    /// that was snapshotted. Under a *different* node count (or an initial
    /// membership that keeps some node out), every entry rehashes through
    /// the router — relative recency is preserved per shard, shards
    /// concatenating in index order — and the movement is accounted in the
    /// returned [`RebalanceReport`] (`None` when nothing moved). The
    /// restored membership resumes the manifest's epoch, +1 when the node
    /// count changed (that change is itself a membership event).
    pub fn restore(
        config: ClusterConfig,
        dir: impl AsRef<Path>,
    ) -> Result<(ClusterService, Option<RebalanceReport>)> {
        let config = normalized(config);
        validate_events(&config).map_err(|e| anyhow::anyhow!(e))?;
        let (manifest, shard_caches, cold_cost) =
            snapshot::load(&dir, config.service.capacity)?;
        let epoch0 = manifest.epoch + u64::from(manifest.nodes != config.nodes);
        let membership = initial_membership(&config, epoch0);
        let router = Router::new(config.nodes);
        let alive: Vec<bool> = membership.alive().to_vec();

        let mut moved = 0usize;
        // Entries the per-shard load itself had to drop (a restore capacity
        // below the snapshot's entry counts) are gone before any rehash.
        let mut lost: usize =
            shard_caches.iter().map(|c| c.stats.evictions as usize).sum();
        let same_layout =
            manifest.nodes == config.nodes && membership.alive_count() == config.nodes;
        let caches: Vec<ResultCache> = if same_layout {
            let mut shards = shard_caches;
            // Misplaced entries (e.g. a snapshot taken after a failure-era
            // replay re-homed keys onto survivors) move to their owner.
            let evictions0: u64 = shards.iter().map(|c| c.stats.evictions).sum();
            for i in 0..shards.len() {
                let misplaced: Vec<Fingerprint> = shards[i]
                    .entries_coldest_first()
                    .filter(|e| router.route(e.fingerprint, &alive) != Some(i))
                    .map(|e| e.fingerprint)
                    .collect();
                for fp in misplaced {
                    if let Some(entry) = shards[i].remove(fp) {
                        let owner = router
                            .route(fp, &alive)
                            .expect("an all-alive membership routes every key");
                        shards[owner].insert(entry);
                        moved += 1;
                    }
                }
            }
            // A move can overflow the target shard's capacity: the evicted
            // entries are genuinely gone, so they count as losses, not as
            // successful moves.
            let squeezed: u64 =
                shards.iter().map(|c| c.stats.evictions).sum::<u64>() - evictions0;
            lost += squeezed as usize;
            shards
        } else {
            let mut fresh: Vec<ResultCache> = (0..config.nodes)
                .map(|_| ResultCache::new(config.service.capacity))
                .collect();
            for (i, shard) in shard_caches.iter().enumerate() {
                for e in shard.entries_coldest_first() {
                    match router.route(e.fingerprint, &alive) {
                        Some(owner) => {
                            if owner != i {
                                moved += 1;
                            }
                            fresh[owner].insert(e.clone());
                        }
                        None => lost += 1,
                    }
                }
            }
            // Rehashing into fewer (or fuller) shards can exceed capacity:
            // whatever the LRU dropped on the way in was not preserved.
            let squeezed: u64 = fresh.iter().map(|c| c.stats.evictions).sum();
            lost += squeezed as usize;
            fresh
        };

        let report = if moved > 0 || lost > 0 || manifest.nodes != config.nodes {
            Some(RebalanceReport {
                kind: RebalanceKind::SnapshotRestore,
                node: manifest.nodes,
                at_s: 0.0,
                cache_entries_lost: lost,
                entries_moved: moved,
                transfer_s: moved as f64 * config.transfer_latency_s.max(0.0),
                rehashed_requests: 0,
                remissed_flights: 0,
                remiss_api_usd: 0.0,
            })
        } else {
            None
        };
        let svc = ClusterService {
            config,
            router,
            caches,
            cold_cost,
            membership,
            restore_rebalance: report.clone(),
        };
        Ok((svc, report))
    }

    /// Replay a traffic trace through the cluster. One event-driven loop
    /// mirrors [`crate::service::KernelService::replay`] per node —
    /// per-arrival admission, single-flight joins, completion-instant side
    /// effects — plus routing, tenancy, membership events (failures and
    /// joins with planned rebalance), and locality-aware cross-node warm
    /// starts. Deterministic per (config, trace); OS `threads` and the
    /// `window` batch size change wall-clock only.
    pub fn replay(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
    ) -> ClusterReport {
        let mut sink = NullSink;
        let mut obs = Observer::new(&mut sink);
        self.replay_impl(trace, tasks, oracle, None, &mut obs)
    }

    /// [`ClusterService::replay`] with a flight recorder attached: every
    /// admission decision, cross-shard warm lookup, flight span, refill
    /// landing, membership change, and eviction is emitted through `obs`
    /// at its simulated instant. With a [`crate::trace::NullSink`]
    /// observer this is exactly `replay`; with a
    /// [`crate::trace::Recorder`] the recorded stream is itself
    /// deterministic across OS thread counts and window sizes.
    pub fn replay_observed(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
        obs: &mut Observer<'_>,
    ) -> ClusterReport {
        self.replay_impl(trace, tasks, oracle, None, obs)
    }

    /// [`ClusterService::replay`] with a closed-loop autoscaler in the
    /// loop: at every due decision tick the replay pauses simulated time,
    /// snapshots the fleet's rolling signals, and lets `run`'s policy
    /// schedule membership events (fails at the tick instant, joins one
    /// provisioning delay later) that merge into the same sorted event
    /// stream scripted events use — so policy decisions are priced by the
    /// identical rebalance machinery. Ticks fire between trace arrivals
    /// (the first at `tick_s`, none after the last arrival), and every
    /// signal is simulated-time arithmetic, so the replay keeps the
    /// bit-identity contracts across OS `threads` and `window` sizes; under
    /// a policy that never acts it is bit-identical to plain `replay`.
    /// `run.actions` holds the policy's decisions afterwards.
    pub fn replay_autoscaled(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
        run: &mut AutoscaleRun,
    ) -> ClusterReport {
        let mut sink = NullSink;
        let mut obs = Observer::new(&mut sink);
        self.replay_impl(trace, tasks, oracle, Some(run), &mut obs)
    }

    /// [`ClusterService::replay_autoscaled`] with a flight recorder
    /// attached: on top of everything [`ClusterService::replay_observed`]
    /// records, each decision tick emits an `autoscale.tick` event with
    /// the signals the policy saw and an `autoscale.decide` event per
    /// membership event it scheduled.
    pub fn replay_autoscaled_observed(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
        run: &mut AutoscaleRun,
        obs: &mut Observer<'_>,
    ) -> ClusterReport {
        self.replay_impl(trace, tasks, oracle, Some(run), obs)
    }

    fn replay_impl(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
        mut autoscale: Option<&mut AutoscaleRun>,
        obs: &mut Observer<'_>,
    ) -> ClusterReport {
        let nodes = self.config.nodes;
        let n_tenants = self.config.tenants.len();
        let window = self.config.service.window.max(1);
        let sim_workers = self.config.service.sim_workers.max(1);
        let queue_depth = self.config.service.queue_depth;
        let hit_latency_s = self.config.service.hit_latency_s;
        let threads = self.config.service.threads;
        let quotas_on = self.config.tenant_quotas;
        let quotas = fair_share_quotas(queue_depth, &self.config.tenants);
        debug_assert!(
            trace.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
            "trace must be sorted by arrival time"
        );

        // Shard eviction counters at replay start (delta basis), snapshotted
        // before the caches are mutably loaned to the hooks.
        let evictions0: Vec<u64> = self.caches.iter().map(|c| c.stats.evictions).collect();
        let config = &self.config;
        let router = self.router;
        let caches = &mut self.caches;
        let cold_cost = &mut self.cold_cost;
        // Mutable: the autoscaler inserts policy events into the unconsumed
        // tail as its ticks fire.
        let mut events = sorted_events(config);
        let mut next_event = 0usize;
        // A restore-time rebalance surfaces in the first replay's report
        // (its keys are all placed, so nothing is tracked as re-missable).
        let restore_rb = self.restore_rebalance.take();

        // Dispatch weights come from the same tenant specs admission quotas
        // use — metering and fairness agree on who deserves what.
        let dispatch_weights: Vec<f64> = config.tenants.iter().map(|t| t.weight).collect();
        let mut fleets: Vec<FleetSim> =
            (0..nodes).map(|_| FleetSim::new(sim_workers)).collect();
        for (ni, fleet) in fleets.iter_mut().enumerate() {
            fleet.set_service_multiplier(config.node_multiplier(ni));
            fleet.set_fair_dispatch(config.service.fair_dispatch);
            fleet.set_tenant_weights(&dispatch_weights);
        }
        // Intern once, probe by id: each distinct (task, gpu) pair is
        // hashed exactly once, and the admission loop reads the per-request
        // column instead of recomputing digests per arrival.
        obs.enter(Stage::Fingerprint);
        let fps = intern_fingerprints(&config.service, trace, tasks);
        obs.exit(Stage::Fingerprint);

        let mut rejected = 0u64;
        let mut rejected_by_class = [0u64; 3];
        let mut tenant_requests = vec![0usize; n_tenants];
        let mut tenant_rejected = vec![0u64; n_tenants];
        let mut tenant_quota_shed = vec![0u64; n_tenants];
        let mut tenant_throttled = vec![0u64; n_tenants];
        // One cluster-wide front door: the limiter sits ahead of routing,
        // so a throttled request never touches any node.
        let mut limiter = RateLimiter::new(RatePolicy::from_config(
            config.service.tenant_rate,
            config.service.tenant_burst,
        ));

        let mut hooks = ClusterHooks {
            config,
            trace,
            tasks,
            oracle,
            router,
            caches,
            cold_cost,
            stats: ReplayStats::new(trace.len()),
            memo: RunMemo::default(),
            pending: BTreeMap::new(),
            visible_at: BTreeMap::new(),
            per_node: (0..nodes)
                .map(|i| NodeCounters {
                    requests: 0,
                    hits: 0,
                    shared: 0,
                    flights_run: 0,
                    rejected: 0,
                    peak_depth: 0,
                    backlog_by_tenant: vec![0; n_tenants],
                    peak_backlog_by_tenant: vec![0; n_tenants],
                    evictions0: evictions0[i],
                    evictions_carry: 0,
                })
                .collect(),
            membership: self.membership.clone(),
            node: 0,
            cross_node_warm: 0,
            rebalances: Vec::new(),
            remiss_open: BTreeMap::new(),
            pending_refills: BTreeMap::new(),
            refill_seq: 0,
            event_heap: BinaryHeap::new(),
            node_seconds: 0.0,
            node_seconds_at: 0.0,
            obs: &mut *obs,
        };
        if let Some(rb) = restore_rb {
            hooks.rebalances.push(ActiveRebalance { report: rb, tracked: BTreeSet::new() });
        }

        for (w0, win) in trace.chunks(window).enumerate().map(|(i, w)| (i * window, w)) {
            // ---- speculation: batch-run predicted misses on OS threads ---
            hooks.obs.enter(Stage::Speculation);
            {
                let caches: &[ResultCache] = hooks.caches;
                let alive: Vec<bool> = hooks.membership.alive().to_vec();
                let fleets = &fleets;
                let c = &config.service;
                let margin = config.warm_locality_margin;
                // Sweep speculations that never became flights (their
                // request hit, joined, or was shed) so the memo stays
                // bounded by the backlog, not the trace.
                hooks.memo.retain(|fp| {
                    fleets.iter().any(|f| f.is_waiting(fp) || f.is_running(fp))
                });
                speculate_window(
                    &mut hooks.memo,
                    threads,
                    tasks,
                    oracle,
                    win,
                    &fps[w0..w0 + win.len()],
                    |fp, req| {
                        let ni = router.route(fp, &alive)?;
                        if caches[ni].peek(fp).is_some()
                            || fleets[ni].is_waiting(fp)
                            || fleets[ni].is_running(fp)
                        {
                            return None;
                        }
                        // A batch request arriving into a full backlog will
                        // be shed — don't burn a speculative run on it.
                        if req.priority == Priority::Batch
                            && fleets[ni].depth() >= queue_depth
                        {
                            return None;
                        }
                        let base = c.base_workflow(req.gpu);
                        Some(
                            match warm_candidate_across(
                                caches,
                                c,
                                &tasks[req.task_index].id(),
                                req.gpu.key,
                                &alive,
                                ni,
                                margin,
                            ) {
                                Some((_, entry)) => c.warm_start_from(base, entry),
                                None => base,
                            },
                        )
                    },
                );
            }
            hooks.obs.exit(Stage::Speculation);

            // ---- admission: event-driven, one arrival at a time ----------
            hooks.obs.enter(Stage::Admission);
            for (off, req) in win.iter().enumerate() {
                let seq = (w0 + off) as u64;
                let now = req.arrival_s;
                let t = req.tenant.min(n_tenants - 1);
                // Autoscaler decision ticks due by this arrival fire first,
                // each at its own instant: scheduled events due by the tick
                // land, the cluster advances to the tick, the policy
                // observes the fleet exactly as it stands at that simulated
                // moment, and whatever it schedules merges into the sorted
                // event tail (a fail at the tick instant is consumed by the
                // very next `apply_membership_due` below; a join lands one
                // provisioning delay later). Firing a tick with a policy
                // that emits nothing only advances the cluster to an
                // instant `<= now` — a prefix of the advance below — so a
                // non-acting policy leaves the replay bit-identical.
                if let Some(run) = autoscale.as_deref_mut() {
                    while let Some(tick_at) = run.next_due(now) {
                        apply_membership_due(
                            &events,
                            &mut next_event,
                            config,
                            tick_at,
                            &mut fleets,
                            &mut hooks,
                        );
                        advance_cluster(&mut fleets, tick_at, &mut hooks);
                        let alive: Vec<bool> = hooks.membership.alive().to_vec();
                        let busy: Vec<f64> = fleets.iter().map(|f| f.busy_s()).collect();
                        let depths: Vec<usize> = fleets.iter().map(|f| f.depth()).collect();
                        let (served, slo_ok) =
                            slo_counts(trace, &hooks.stats.latencies, &config.service.slo);
                        let decisions = run.observe(
                            tick_at,
                            &alive,
                            &busy,
                            &depths,
                            sim_workers,
                            served,
                            slo_ok,
                            seq as usize,
                        );
                        if let Some(sig) = run.last_signals.clone() {
                            hooks.obs.emit(|| {
                                TraceEvent::new(tick_at, "autoscale.tick", 0)
                                    .field("alive_nodes", Json::num(sig.alive_nodes as f64))
                                    .field(
                                        "backlog_total",
                                        Json::num(sig.backlog_total as f64),
                                    )
                                    .field(
                                        "mean_utilization",
                                        Json::Num(sig.mean_utilization),
                                    )
                                    .field("slo_attainment", Json::Num(sig.slo_attainment))
                                    .field(
                                        "served_window",
                                        Json::num(sig.served_window as f64),
                                    )
                                    .field(
                                        "arrivals_window",
                                        Json::num(sig.arrivals_window as f64),
                                    )
                            });
                        }
                        for ev in decisions {
                            hooks.obs.emit(|| {
                                TraceEvent::new(tick_at, "autoscale.decide", ev.node)
                                    .field(
                                        "action",
                                        Json::str(match ev.change {
                                            MembershipChange::Fail => "fail",
                                            MembershipChange::Join => "join",
                                        }),
                                    )
                                    .field("lands_at_s", Json::Num(ev.at_s))
                            });
                            insert_sorted_event(&mut events, next_event, ev);
                        }
                    }
                }
                // Membership events due by this arrival land at their own
                // instants (graceful drain for a failing node's accepted
                // work; refills in flight for a joining one). Starts between
                // an event and this arrival already see the new membership.
                hooks.obs.enter(Stage::EventHeap);
                apply_membership_due(
                    &events,
                    &mut next_event,
                    config,
                    now,
                    &mut fleets,
                    &mut hooks,
                );
                // Fire every refill/start/completion due by `now`,
                // cluster-wide, so this arrival observes exactly the events
                // landed by its own instant.
                advance_cluster(&mut fleets, now, &mut hooks);
                hooks.obs.exit(Stage::EventHeap);
                hooks.obs.enter(Stage::Fingerprint);
                let task = &tasks[req.task_index];
                let fp = fps[seq as usize];
                hooks.obs.exit(Stage::Fingerprint);
                hooks.count_rehashed(fp);
                // Every arrival is this tenant's traffic, even one the
                // cluster cannot route (served + rejected == requests must
                // hold per tenant).
                tenant_requests[t] += 1;
                // Front door first: a throttled request never reaches
                // routing, any shard, or admission control.
                if let RateDecision::Throttle { tokens, retry_at_s } = limiter.check(t, now) {
                    rejected += 1;
                    rejected_by_class[req.priority as usize] += 1;
                    tenant_rejected[t] += 1;
                    tenant_throttled[t] += 1;
                    hooks.obs.emit(|| {
                        admit_event(now, 0, seq, fp, req, task, 0, "shed")
                            .field("reason", Json::str("rate"))
                            .field("tokens", Json::num(tokens))
                            .field("retry_at_s", Json::num(retry_at_s))
                    });
                    continue;
                }
                let ni = match router.route(fp, hooks.membership.alive()) {
                    Some(n) => n,
                    None => {
                        // Every node is dead: shed unconditionally.
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        hooks.obs.emit(|| {
                            admit_event(now, 0, seq, fp, req, task, 0, "shed")
                                .field("reason", Json::str("routing"))
                        });
                        continue;
                    }
                };
                hooks.per_node[ni].requests += 1;
                let fleet = &mut fleets[ni];
                // Whether this arrival mutated the fleet (join or submit) —
                // those decisions invalidate the node's event-heap entry, so
                // the fleet is re-armed below.
                let mut fleet_mutated = true;
                // Single-flight joins first: identical work waiting or on a
                // worker is shared, not redone. Joiners settle with the
                // flight at its completion.
                let joined_waiting = fleet.join_waiting(fp, seq, now, req.priority);
                if joined_waiting || fleet.join_running(fp, seq, now) {
                    let outcome =
                        if joined_waiting { "join-waiting" } else { "join-running" };
                    let depth = fleet.depth();
                    hooks
                        .obs
                        .emit(|| admit_event(now, ni, seq, fp, req, task, depth, outcome));
                } else if let Some(entry) = hooks.caches[ni].get(fp) {
                    fleet_mutated = false;
                    if let Some(done) = hooks.visible_at.get(&fp) {
                        debug_assert!(
                            *done <= now,
                            "cache hit on {fp}: producing flight completes at {done} > arrival {now}",
                        );
                    }
                    hooks.stats.latencies[seq as usize] = Some(hit_latency_s);
                    hooks.stats.api_cold += entry.cold_api_usd;
                    hooks.per_node[ni].hits += 1;
                    let depth = fleet.depth();
                    hooks.obs.emit(|| {
                        admit_event(now, ni, seq, fp, req, task, depth, "hit")
                            .field("latency_s", Json::num(hit_latency_s))
                    });
                } else {
                    // Miss: admission control. The global batch-shed
                    // applies first (as on a single node), then the
                    // tenant's fair-share quota — both only against
                    // requests opening a *new* flight; joins are always
                    // free.
                    let over = fleet.depth() >= queue_depth;
                    if over && req.priority == Priority::Batch {
                        fleet_mutated = false;
                        hooks.per_node[ni].rejected += 1;
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        let depth = fleet.depth();
                        hooks.obs.emit(|| {
                            admit_event(now, ni, seq, fp, req, task, depth, "shed")
                                .field("reason", Json::str("depth"))
                        });
                    } else if over
                        && quotas_on
                        && hooks.per_node[ni].backlog_by_tenant[t] >= quotas[t]
                    {
                        fleet_mutated = false;
                        hooks.per_node[ni].rejected += 1;
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        tenant_quota_shed[t] += 1;
                        let depth = fleet.depth();
                        let backlog = hooks.per_node[ni].backlog_by_tenant[t];
                        let quota = quotas[t];
                        hooks.obs.emit(|| {
                            admit_event(now, ni, seq, fp, req, task, depth, "shed")
                                .field("reason", Json::str("quota"))
                                .field("backlog", Json::num(backlog as f64))
                                .field("quota", Json::num(quota as f64))
                        });
                    } else {
                        // A new flight for a key some rebalance made
                        // unreachable is that rebalance's re-miss.
                        hooks.charge_if_tracked(fp);
                        fleet.submit(SimFlight {
                            fingerprint: fp,
                            priority: req.priority,
                            leader_seq: seq,
                            tenant: t,
                            arrival_s: now,
                            members: MemberList::one(seq, now),
                        });
                        hooks.per_node[ni].backlog_by_tenant[t] += 1;
                        let nc = &mut hooks.per_node[ni];
                        nc.peak_backlog_by_tenant[t] =
                            nc.peak_backlog_by_tenant[t].max(nc.backlog_by_tenant[t]);
                        let depth = fleet.depth();
                        hooks
                            .obs
                            .emit(|| admit_event(now, ni, seq, fp, req, task, depth, "enqueue"));
                    }
                }
                // Every admission decision samples this node's backlog —
                // hits, joins, and sheds included.
                let depth_now = fleets[ni].depth();
                let nc = &mut hooks.per_node[ni];
                nc.peak_depth = nc.peak_depth.max(depth_now);
                if fleet_mutated {
                    hooks.arm_fleet(&fleets, ni);
                }
            }
            hooks.obs.exit(Stage::Admission);
        }
        // Drain: serve everything still waiting, running, or in transit at
        // end of trace. A membership event past the last arrival still
        // fires here — the drain advances simulated time through it.
        hooks.obs.enter(Stage::EventHeap);
        apply_membership_due(
            &events,
            &mut next_event,
            config,
            f64::INFINITY,
            &mut fleets,
            &mut hooks,
        );
        advance_cluster(&mut fleets, f64::INFINITY, &mut hooks);
        hooks.obs.exit(Stage::EventHeap);
        debug_assert!(hooks.pending.is_empty(), "every started flight completed");
        debug_assert!(hooks.pending_refills.is_empty(), "every refill landed");

        hooks.obs.enter(Stage::Report);
        let ReplayStats {
            latencies,
            api_spent,
            api_cold,
            flights_run,
            warm_started,
            warm_correct,
            shared,
            cold_rounds,
            warm_rounds,
            lint_short_circuits,
        } = hooks.stats;
        let served: Vec<f64> = latencies.iter().filter_map(|l| *l).collect();
        debug_assert_eq!(
            served.len() + rejected as usize,
            trace.len(),
            "every request is served or rejected"
        );
        let slo = config.service.slo;
        let per_priority = per_priority_report(trace, &latencies, &slo, &rejected_by_class);

        let hits: u64 = hooks.per_node.iter().map(|s| s.hits).sum();
        let evictions: u64 = hooks
            .per_node
            .iter()
            .enumerate()
            .map(|(i, s)| s.evictions_carry + hooks.caches[i].stats.evictions - s.evictions0)
            .sum();
        let busy_s: f64 = fleets.iter().map(|f| f.busy_s()).sum();
        let makespan = fleets.iter().map(|f| f.makespan_s()).fold(0.0f64, f64::max);
        // Close the alive-node-seconds integral at the makespan (or at the
        // last membership instant, if that fell later than any work).
        hooks.accrue_node_seconds(makespan);
        let node_hours = hooks.node_seconds / 3600.0;
        let wait_s: f64 = fleets.iter().map(|f| f.total_queue_wait_s()).sum();
        let served_flights: usize = fleets.iter().map(|f| f.flights_served()).sum();
        let total_workers = nodes * sim_workers;
        let gpu_hours = busy_s / 3600.0;

        let per_node: Vec<NodeReport> = hooks
            .per_node
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let node_makespan = fleets[i].makespan_s();
                NodeReport {
                    node: i,
                    alive: hooks.membership.is_alive(i),
                    requests: s.requests,
                    cache_hits: s.hits,
                    shared: s.shared,
                    flights_run: s.flights_run,
                    rejected: s.rejected,
                    evictions: s.evictions_carry + hooks.caches[i].stats.evictions
                        - s.evictions0,
                    hit_rate: if s.requests == 0 {
                        0.0
                    } else {
                        (s.hits + s.shared) as f64 / s.requests as f64
                    },
                    utilization: if node_makespan > 0.0 {
                        fleets[i].busy_s() / (sim_workers as f64 * node_makespan)
                    } else {
                        0.0
                    },
                    peak_queue_depth: s.peak_depth,
                    cache_entries: hooks.caches[i].len(),
                }
            })
            .collect();

        // One pass over the trace bins every tenant's served latencies and
        // SLO-within counts at once — the old path re-filtered the full
        // trace twice per tenant, an O(tenants × requests) report step.
        // Per-tenant latencies accumulate in arrival order, exactly what
        // the per-tenant filter produced, and `percentile` sorts a copy
        // internally — bit-identical.
        let mut tenant_lat: Vec<Vec<f64>> = vec![Vec::new(); n_tenants];
        let mut tenant_within: Vec<usize> = vec![0; n_tenants];
        for (r, l) in trace.iter().zip(&latencies) {
            if let Some(v) = *l {
                let t = r.tenant.min(n_tenants - 1);
                tenant_lat[t].push(v);
                if v <= slo.target_s(r.priority) {
                    tenant_within[t] += 1;
                }
            }
        }
        let per_tenant: Vec<TenantReport> = config
            .tenants
            .iter()
            .enumerate()
            .map(|(t, spec)| {
                let lat = &tenant_lat[t];
                TenantReport {
                    tenant: spec.name.clone(),
                    weight: spec.weight,
                    requests: tenant_requests[t],
                    served: lat.len(),
                    rejected: tenant_rejected[t],
                    quota_shed: tenant_quota_shed[t],
                    throttled: tenant_throttled[t],
                    peak_queue_depth: hooks
                        .per_node
                        .iter()
                        .map(|nc| nc.peak_backlog_by_tenant[t])
                        .max()
                        .unwrap_or(0),
                    p50_latency_s: percentile(lat, 50.0),
                    p95_latency_s: percentile(lat, 95.0),
                    p99_latency_s: percentile(lat, 99.0),
                    slo_attainment: if lat.is_empty() {
                        1.0
                    } else {
                        tenant_within[t] as f64 / lat.len() as f64
                    },
                }
            })
            .collect();

        let overall = ServiceReport {
            requests: trace.len(),
            flights_run,
            cache_hits: hits,
            shared,
            evictions,
            rejected,
            warm_started,
            warm_correct,
            hit_rate: if trace.is_empty() {
                0.0
            } else {
                (hits + shared) as f64 / trace.len() as f64
            },
            p50_latency_s: percentile(&served, 50.0),
            p95_latency_s: percentile(&served, 95.0),
            p99_latency_s: percentile(&served, 99.0),
            mean_latency_s: crate::util::stats::mean(&served),
            mean_queue_wait_s: if served_flights == 0 {
                0.0
            } else {
                wait_s / served_flights as f64
            },
            peak_queue_depth: hooks.per_node.iter().map(|s| s.peak_depth).max().unwrap_or(0),
            utilization: if makespan > 0.0 {
                busy_s / (total_workers as f64 * makespan)
            } else {
                0.0
            },
            per_priority,
            api_usd_spent: api_spent,
            api_usd_saved: api_cold - api_spent,
            api_usd_cold: api_cold,
            mean_rounds_to_best_cold: crate::util::stats::mean(&cold_rounds),
            mean_rounds_to_best_warm: crate::util::stats::mean(&warm_rounds),
            gpu_hours,
            requests_per_gpu_hour: if gpu_hours > 0.0 {
                trace.len() as f64 / gpu_hours
            } else {
                0.0
            },
            lint_short_circuits,
            rate_limited: tenant_throttled.iter().sum(),
        };

        let epoch = hooks.membership.epoch();
        self.membership = hooks.membership.clone();
        let report = ClusterReport {
            overall,
            nodes,
            epoch,
            per_node,
            per_tenant,
            cross_node_warm: hooks.cross_node_warm,
            node_hours,
            quota_shed: tenant_quota_shed.iter().sum(),
            rebalances: hooks.rebalances.into_iter().map(|rb| rb.report).collect(),
        };
        hooks.obs.exit(Stage::Report);
        report
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::gpu;
    use crate::kernel::KernelConfig;
    use crate::service::traffic::{generate, TrafficConfig};
    use crate::tasks;
    use crate::workflow::NoOracle;

    #[test]
    fn fair_shares_follow_weights_with_a_floor() {
        let tenants = vec![TenantSpec::new("a", 3.0), TenantSpec::new("b", 1.0)];
        assert_eq!(fair_share_quotas(8, &tenants), vec![6, 2]);
        // Tiny weights still get one slot; unbounded depth disables quotas.
        let skew = vec![TenantSpec::new("big", 100.0), TenantSpec::new("tiny", 0.0001)];
        assert_eq!(fair_share_quotas(4, &skew), vec![3, 1]);
        assert_eq!(
            fair_share_quotas(usize::MAX, &tenants),
            vec![usize::MAX, usize::MAX]
        );
        // Degenerate weights fall back to "no quota" rather than panicking.
        let zeros = vec![TenantSpec::new("z", 0.0)];
        assert_eq!(fair_share_quotas(8, &zeros), vec![usize::MAX]);
    }

    #[test]
    fn requests_partition_across_nodes_and_tenants() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig {
                requests: 300,
                tenant_mix: vec![("a".to_string(), 1.0), ("b".to_string(), 1.0)],
                ..TrafficConfig::default()
            },
        );
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 3,
            tenants: vec![TenantSpec::new("a", 1.0), TenantSpec::new("b", 1.0)],
            service: ServiceConfig {
                threads: 2,
                window: 16,
                ..ServiceConfig::default()
            },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.nodes, 3);
        assert_eq!(r.per_node.len(), 3);
        assert_eq!(r.per_tenant.len(), 2);
        assert_eq!(
            r.per_node.iter().map(|n| n.requests).sum::<usize>(),
            r.overall.requests,
            "routing partitions the trace across shards"
        );
        assert!(
            r.per_node.iter().filter(|n| n.requests > 0).count() >= 2,
            "rendezvous hashing spreads this trace over multiple nodes"
        );
        assert_eq!(
            r.per_tenant.iter().map(|t| t.requests).sum::<usize>(),
            r.overall.requests
        );
        for t in &r.per_tenant {
            assert_eq!(t.served as u64 + t.rejected, t.requests as u64);
            assert!((0.0..=1.0).contains(&t.slo_attainment));
        }
        assert_eq!(
            r.overall.cache_hits + r.overall.shared + r.overall.flights_run as u64
                + r.overall.rejected,
            r.overall.requests as u64,
            "every request is a hit, a follower, a flight, or shed"
        );
        assert!(r.rebalances.is_empty());
        assert_eq!(r.epoch, 0, "no membership event fired");
        assert_eq!(r.quota_shed, 0, "quotas are off by default");
    }

    #[test]
    fn failure_after_the_last_arrival_fires_during_the_drain() {
        // The failure instant falls past every arrival: the final drain
        // still advances simulated time through it, so the shard drop (and
        // its entry-loss accounting) is reported instead of silently
        // skipped.
        let suite = tasks::kernelbench();
        let probe_cfg = ServiceConfig { threads: 1, ..ServiceConfig::default() };
        let anchor = (0..suite.len())
            .find(|i| {
                let wf = probe_cfg.base_workflow(gpu::by_key("rtx6000").unwrap());
                let r = run_task(&wf, &suite[*i], &NoOracle);
                r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
            })
            .expect("some task solves cold on rtx6000");
        let trace = vec![TrafficRequest {
            task_index: anchor,
            gpu: gpu::by_key("rtx6000").unwrap(),
            priority: Priority::Standard,
            tenant: 0,
            arrival_s: 0.0,
        }];
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 1,
            // Long after the lone flight completes (~26 simulated minutes).
            events: vec![MembershipEvent::fail(0, 100_000.0)],
            service: probe_cfg,
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.overall.flights_run, 1, "the pre-failure flight served normally");
        assert_eq!(r.rebalances.len(), 1, "the drain reaches the failure instant");
        let rb = &r.rebalances[0];
        assert_eq!(rb.kind, RebalanceKind::NodeFailure);
        assert_eq!(rb.node, 0);
        assert_eq!(rb.cache_entries_lost, 1, "the completed flight's entry was resident");
        assert_eq!(r.epoch, 1);
        assert!(!r.per_node[0].alive);
        assert_eq!(r.per_node[0].cache_entries, 0);
    }

    #[test]
    fn all_nodes_dead_sheds_everything() {
        let suite = tasks::kernelbench();
        let trace = vec![TrafficRequest {
            task_index: 0,
            gpu: gpu::by_key("rtx6000").unwrap(),
            priority: Priority::Standard,
            tenant: 0,
            arrival_s: 10.0,
        }];
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 1,
            events: vec![MembershipEvent::fail(0, 0.0)],
            service: ServiceConfig { threads: 1, ..ServiceConfig::default() },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.overall.rejected, 1, "an unroutable request is shed");
        assert_eq!(r.overall.flights_run, 0);
        assert!(!r.per_node[0].alive);
        // The unroutable shed still counts as the tenant's traffic.
        assert_eq!(r.per_tenant[0].requests, 1);
        assert_eq!(r.per_tenant[0].rejected, 1);
        assert_eq!(r.per_tenant[0].served, 0);
    }

    #[test]
    fn a_node_whose_first_event_is_a_join_starts_outside_the_cluster() {
        let config = normalized(ClusterConfig {
            nodes: 3,
            events: vec![
                MembershipEvent::join(2, 500.0),
                MembershipEvent::fail(1, 100.0),
                MembershipEvent::join(1, 900.0),
            ],
            ..ClusterConfig::default()
        });
        let m = initial_membership(&config, 0);
        assert!(m.is_alive(0));
        assert!(m.is_alive(1), "node 1 fails first, so it starts alive");
        assert!(!m.is_alive(2), "node 2's first event is a join: it starts out");
        assert_eq!(m.epoch(), 0, "initial deadness is not a membership change");
        // Out-of-range events are ignored entirely.
        let config = normalized(ClusterConfig {
            nodes: 2,
            events: vec![MembershipEvent::join(9, 1.0)],
            ..ClusterConfig::default()
        });
        assert_eq!(initial_membership(&config, 0).alive_count(), 2);
    }

    fn locality_entry(fp: u64, gpu: &str, speedup: f64) -> CacheEntry {
        CacheEntry {
            fingerprint: Fingerprint(fp),
            task_id: "L1-95".to_string(),
            gpu_key: gpu.to_string(),
            strategy: "CudaForge".to_string(),
            coder: "OpenAI-o3".to_string(),
            judge: "OpenAI-o3".to_string(),
            best_speedup: speedup,
            best_config: KernelConfig::naive(),
            api_usd: 0.30,
            cold_api_usd: 0.30,
            wall_s: 1590.0,
            rounds_to_best: 6,
        }
    }

    #[test]
    fn locality_margin_keeps_marginally_better_seeds_local() {
        let c = ServiceConfig::default();
        let mut own = ResultCache::new(8);
        own.insert(locality_entry(1, "a100", 2.0));
        let mut remote = ResultCache::new(8);
        remote.insert(locality_entry(2, "h100", 2.2));
        let caches = vec![own, remote];
        let alive = [true, true];

        // Margin 0: any strictly faster remote wins the transfer.
        let (node, e) =
            warm_candidate_across(&caches, &c, "L1-95", "rtx6000", &alive, 0, 0.0).unwrap();
        assert_eq!((node, e.fingerprint), (1, Fingerprint(2)));
        // A 25% margin: 2.2 < 2.0 * 1.25, so the own-shard seed wins.
        let (node, e) =
            warm_candidate_across(&caches, &c, "L1-95", "rtx6000", &alive, 0, 0.25).unwrap();
        assert_eq!((node, e.fingerprint), (0, Fingerprint(1)));
        // From the other node's perspective its own seed is the fast one:
        // locality never pays the transfer.
        let (node, _) =
            warm_candidate_across(&caches, &c, "L1-95", "rtx6000", &alive, 1, 0.25).unwrap();
        assert_eq!(node, 1);
        // A dead own shard cannot donate: the remote wins regardless.
        let (node, _) =
            warm_candidate_across(&caches, &c, "L1-95", "rtx6000", &[false, true], 0, 9.0)
                .unwrap();
        assert_eq!(node, 1);
        // No candidate anywhere.
        assert!(warm_candidate_across(&caches, &c, "L9-99", "rtx6000", &alive, 0, 0.0)
            .is_none());
    }

    #[test]
    fn redundant_events_are_structured_errors_not_silent_noops() {
        // Failing a node twice without a join in between: the second fail
        // finds the node already dead.
        let config = ClusterConfig {
            nodes: 2,
            events: vec![MembershipEvent::fail(1, 100.0), MembershipEvent::fail(1, 200.0)],
            ..ClusterConfig::default()
        };
        let err = validate_events(&config).unwrap_err();
        assert_eq!(
            err,
            MembershipEventError { node: 1, at_s: 200.0, change: MembershipChange::Fail }
        );
        let msg = err.to_string();
        assert!(msg.contains("fail of node 1"), "error names the node: {msg}");
        assert!(msg.contains("t=200"), "error names the instant: {msg}");
        assert!(msg.contains("already dead"), "error names the state: {msg}");
        assert!(ClusterService::try_new(config).is_err());

        // Joining an alive node: node 0 starts alive (its first event is
        // not a join), so the join contradicts the starting membership.
        let config = ClusterConfig {
            nodes: 2,
            events: vec![MembershipEvent::fail(0, 50.0), MembershipEvent::join(0, 10.0)],
            ..ClusterConfig::default()
        };
        let err = validate_events(&config).unwrap_err();
        assert_eq!(
            err,
            MembershipEventError { node: 0, at_s: 10.0, change: MembershipChange::Join }
        );
        assert!(err.to_string().contains("already alive"));

        // Failing a slot that starts outside the cluster.
        let config = ClusterConfig {
            nodes: 3,
            initial_dead: vec![2],
            events: vec![MembershipEvent::fail(2, 5.0)],
            ..ClusterConfig::default()
        };
        let err = validate_events(&config).unwrap_err();
        assert_eq!(err.node, 2);
        assert_eq!(err.change, MembershipChange::Fail);
    }

    #[test]
    fn consistent_streams_and_out_of_range_events_validate() {
        // fail → join → fail on one node is a legal lifecycle; an
        // out-of-range event is filtered before validation, not an error.
        let config = ClusterConfig {
            nodes: 2,
            events: vec![
                MembershipEvent::fail(1, 100.0),
                MembershipEvent::join(1, 400.0),
                MembershipEvent::fail(1, 900.0),
                MembershipEvent::join(7, 50.0),
            ],
            ..ClusterConfig::default()
        };
        assert!(validate_events(&config).is_ok());
        assert!(ClusterService::try_new(config).is_ok());
        // A join-first node starts dead, so its join is consistent.
        let config = ClusterConfig {
            nodes: 2,
            events: vec![MembershipEvent::join(1, 300.0)],
            ..ClusterConfig::default()
        };
        assert!(validate_events(&config).is_ok());
    }

    #[test]
    fn initial_dead_slots_start_outside_the_cluster() {
        let config = normalized(ClusterConfig {
            nodes: 4,
            initial_dead: vec![3, 1, 3, 9],
            ..ClusterConfig::default()
        });
        assert_eq!(config.initial_dead, vec![1, 3], "sorted, deduped, in range");
        let m = initial_membership(&config, 0);
        assert!(m.is_alive(0));
        assert!(!m.is_alive(1));
        assert!(m.is_alive(2));
        assert!(!m.is_alive(3));
        assert_eq!(m.epoch(), 0);
    }

    #[test]
    fn node_multiplier_defaults_to_identity() {
        let mut config = ClusterConfig { nodes: 3, ..ClusterConfig::default() };
        assert_eq!(config.node_multiplier(0), 1.0);
        assert_eq!(config.node_multiplier(7), 1.0);
        config.node_service_multipliers = vec![4.0, f64::NAN, -2.0];
        assert_eq!(config.node_multiplier(0), 4.0);
        assert_eq!(config.node_multiplier(1), 1.0, "NaN falls back to identity");
        assert_eq!(config.node_multiplier(2), 1.0, "non-positive falls back to identity");
    }

    #[test]
    fn insert_sorted_event_keeps_replay_order_in_the_tail() {
        let mut events = vec![
            MembershipEvent::fail(0, 10.0),
            MembershipEvent::fail(1, 50.0),
            MembershipEvent::join(0, 90.0),
        ];
        // The first event is already consumed; insert into the tail.
        insert_sorted_event(&mut events, 1, MembershipEvent::join(1, 70.0));
        assert_eq!(events[2], MembershipEvent::join(1, 70.0));
        // Same instant and node: Fail sorts before Join, as in sorted_events.
        insert_sorted_event(&mut events, 1, MembershipEvent::fail(1, 70.0));
        assert_eq!(events[2], MembershipEvent::fail(1, 70.0));
        assert_eq!(events[3], MembershipEvent::join(1, 70.0));
        assert!(events[1..]
            .windows(2)
            .all(|p| p[0].at_s.total_cmp(&p[1].at_s).is_le()));
    }

    #[test]
    fn node_hours_integrate_the_alive_count_over_the_span() {
        // One node, one request served at t = 0 in ~26.5 simulated minutes,
        // then a failure at 100 000 s: the span runs to the failure instant
        // (the last membership event, past the makespan), all of it with
        // one node alive.
        let suite = tasks::kernelbench();
        let trace = vec![TrafficRequest {
            task_index: 0,
            gpu: gpu::by_key("rtx6000").unwrap(),
            priority: Priority::Standard,
            tenant: 0,
            arrival_s: 0.0,
        }];
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 1,
            events: vec![MembershipEvent::fail(0, 100_000.0)],
            service: ServiceConfig { threads: 1, ..ServiceConfig::default() },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.node_hours, 100_000.0 / 3600.0, "1 node x 100 000 s, then 0 nodes");

        // No events: node-hours are simply nodes x makespan.
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 2,
            service: ServiceConfig { threads: 1, ..ServiceConfig::default() },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        let makespan_h = r.overall.gpu_hours / r.overall.utilization / 8.0 / 2.0;
        assert!(
            (r.node_hours - 2.0 * makespan_h).abs() < 1e-6,
            "2 nodes x makespan ({} vs {})",
            r.node_hours,
            2.0 * makespan_h
        );
    }
}
